"""Fused IVF list scan: gather + L2 + per-block partial top-k, one pass.

FAISS's IVF scan kernels walk the probed inverted lists, compute distances
on the fly and keep a per-thread-block heap of the k best.  The TPU
adaptation (DESIGN.md §3) mirrors `l2_topk.py`'s two-level scheme but
replaces the dense catalog tile with a *gathered* one: the candidate id
table (B, P) — the concatenation of each query's probed inverted lists,
padded with -1 — indexes into the catalog held resident in VMEM, so the
gathered embeddings never round-trip through HBM.  Each (BQ, BP) tile of
the candidate table emits its k smallest distances (iterative masked-min
extraction) plus their *positions along the P axis*; the host-side wrapper
(ops.ivf_scan_topk) merges the per-block partials with one `lax.top_k`
and maps positions back to catalog ids.

Memory: the catalog block is (N, d) in VMEM — fine up to N*d ≈ 4M floats
(~16 MB/core, DESIGN.md §4); larger catalogs shard row-wise over the
`model` mesh axis before this kernel sees them.

Invalid slots (id < 0: list padding or dedup sentinels) read row 0 but are
masked to +inf before selection, so they can never displace a real
candidate; queries with fewer than k valid candidates surface +inf
distances, which the wrapper turns into id = -1 (underflow contract shared
with IVFFlatIndex.query).

Mutable catalogs (DESIGN.md §10) reuse the same convention: the wrapper
(`ops.ivf_scan_topk(..., valid=mask)`) folds candidate ids whose catalog
row is tombstoned into the -1 sentinel *before* the scan, so removed
objects can never surface from stale inverted lists — the kernel itself
needs no mutation awareness.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.l2_topk import extract_block_topk

BQ = 8
BP = 128
_INF = float("inf")  # python literal: avoids captured-constant tracing in Pallas


def _ivf_scan_kernel(k: int, q_ref, x_ref, cand_ref, od_ref, oi_ref):
    q = q_ref[...].astype(jnp.float32)        # (BQ, d)
    x = x_ref[...].astype(jnp.float32)        # (N, d) catalog, VMEM-resident
    cand = cand_ref[...]                      # (BQ, BP) int32, -1 = invalid
    n = x.shape[0]

    safe = jnp.clip(cand, 0, n - 1)
    embs = jnp.take(x, safe.reshape(-1), axis=0).reshape(
        cand.shape + (x.shape[1],)
    )                                         # (BQ, BP, d) gathered rows
    diff = embs - q[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)         # (BQ, BP)
    d = jnp.where(cand < 0, _INF, d)

    j = pl.program_id(1)
    base = j * BP
    od_ref[...], oi_ref[...] = extract_block_topk(d, base, k)


def ivf_scan_pallas(
    q: jax.Array, x: jax.Array, cand: jax.Array, k: int, *,
    interpret: bool = False
):
    """Per-block partial results over gathered candidates.

    q (B, d), x (N, d), cand (B, P) int32 (-1 = invalid slot).
    Returns (dists (B, nblocks*k), positions (B, nblocks*k)) where positions
    index the P axis of `cand`; callers merge with lax.top_k and gather the
    catalog ids with take_along_axis (see ops.ivf_scan_topk).
    """
    b, d = q.shape
    n = x.shape[0]
    bb, p = cand.shape
    assert b == bb, (b, bb)
    assert b % BQ == 0 and p % BP == 0 and k <= BP
    grid = (b // BQ, p // BP)
    nb = p // BP
    return pl.pallas_call(
        functools.partial(_ivf_scan_kernel, k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BQ, d), lambda i, j: (i, 0)),
            pl.BlockSpec((n, d), lambda i, j: (0, 0)),
            pl.BlockSpec((BQ, BP), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((BQ, k), lambda i, j: (i, j)),
            pl.BlockSpec((BQ, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nb * k), jnp.float32),
            jax.ShapeDtypeStruct((b, nb * k), jnp.int32),
        ],
        interpret=interpret,
    )(q, x, cand.astype(jnp.int32))
