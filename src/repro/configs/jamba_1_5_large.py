"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave + MoE
(arXiv:2403.19887).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16 experts
top-2 on every other layer.  Scan unit = 8 layers (attention at slot 4),
9 units.  Mamba mixer: d_state=64, d_inner=16384.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    layer_pattern="jamba",
    attn_every=8,
    d_state=64,
    ssm_head_dim=64,
    expand=2,
    n_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    moe_every=2,
    pos_emb="none",  # jamba uses no positional encoding (mamba provides order)
    fsdp=True,
    optimizer="adafactor",
)

SMOKE = ModelConfig(
    name="jamba-1.5-large-smoke",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    layer_pattern="jamba", attn_every=8, d_state=16, ssm_head_dim=16,
    expand=2, n_experts=4, experts_per_token=2, moe_d_ff=128, moe_every=2,
    pos_emb="none", ssd_chunk=16, optimizer="adafactor",
    capacity_factor=0.0,  # dropless for exact decode-consistency tests
)
