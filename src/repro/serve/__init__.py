"""Serving substrate: prefill/decode engine with KV/SSM caches, continuous
batching, the AÇAI semantic cache tier, and the resilient remote tier
(fault-injected backend + retry/hedge/deadline/degrade, DESIGN.md §11)."""

from repro.serve.engine import ServeEngine, generate, make_decode_step, make_prefill
from repro.serve.remote import (FaultSpec, FaultyRemote, OracleRemote,
                                RemoteBackend, parse_outage_windows,
                                payload_ok)
from repro.serve.resilience import (CircuitBreaker, RemoteSession,
                                    ResilienceConfig, ResilientPolicy,
                                    RetryConfig, replay_resilient,
                                    simulate_request)
from repro.serve.semantic_cache import SemanticCachedLM, embed_prompt

__all__ = ["CircuitBreaker", "FaultSpec", "FaultyRemote", "OracleRemote",
           "RemoteBackend", "RemoteSession", "ResilienceConfig",
           "ResilientPolicy", "RetryConfig", "SemanticCachedLM",
           "ServeEngine", "embed_prompt", "generate", "make_decode_step",
           "make_prefill", "parse_outage_windows", "payload_ok",
           "replay_resilient", "simulate_request"]
