"""NSW graph index — the HNSW-style local-catalog index, TPU-adapted.

HNSW's pointer-chasing search is hostile to jit; we keep the navigable-
small-world *semantics* (greedy beam search over a neighbour graph, entry
point = medoid) but store the graph as a dense (N, degree) table and run a
fixed-width, fixed-step beam with masked gathers (DESIGN.md §3).  Recall is
controlled by (degree, beam, steps, expand) just like HNSW's (M, efSearch).

Batched-first (DESIGN.md §6): `query` carries the whole mini-batch's beams
as (B, beam) arrays through one fori_loop — per step it expands the
`expand` best unexpanded beam entries of every query at once (top-k,
gather, dedup and re-top-k all along axis 1), so the MXU sees (B, E·deg)
tiles instead of a per-query scalar loop.

Build: exact kNN graph + long-range shortcuts (random far edges), the
classic NSW construction, done once in numpy at setup.  (Reverse-edge
symmetrization of the shortcut slots was measured and REFUTED: replacing
the random far edges with incoming-kNN edges drops amazon-trace recall
0.84 -> 0.75 — the shortcuts are what lets the beam cross clusters.)

Mutable catalog (DESIGN.md §10): `add` is the classic incremental NSW
insertion — the new node's out-edges are its current beam-search kNN plus
random shortcut edges, and a couple of its neighbours each give one edge
slot back to the new node so it becomes reachable; `remove` tombstones
(dead nodes stay routable until refresh, the standard mark-deleted HNSW
semantics, but can never surface as answers); `refresh` rebuilds the graph
and the entry points over the live rows.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import (MutableRows, _flat_set, _rows_write,
                              arrays_bytes, check_finite_queries, pad_ids,
                              pad_rows, run_device, track_jit)
from repro.kernels import ops


def build_nsw_graph(emb: np.ndarray, degree: int = 16, shortcuts: int = 2,
                    seed: int = 0, chunk: int = 1024) -> np.ndarray:
    n = emb.shape[0]
    rng = np.random.default_rng(seed)
    knn = min(degree - shortcuts, n - 1)
    graph = np.empty((n, degree), np.int32)
    cn = (emb ** 2).sum(1)
    for s in range(0, n, chunk):
        q = emb[s:s + chunk]
        d = (q ** 2).sum(1)[:, None] - 2 * q @ emb.T + cn[None]
        np.fill_diagonal(d[:, s:s + q.shape[0]], np.inf)
        part = np.argpartition(d, knn, axis=1)[:, :knn]
        graph[s:s + chunk, :knn] = part
    graph[:, knn:] = rng.integers(0, n, (n, degree - knn))
    return graph


@track_jit("nsw_query")
@partial(jax.jit, static_argnames=("k", "beam", "steps", "expand", "masked"))
def _nsw_query(q, emb, graph, entry_points, valid, k: int, beam: int,
               steps: int, expand: int, masked: bool):
    """(B, d) -> (dists (B, k), ids (B, k)); ids = -1 on underflow.

    `masked` threads the tombstone mask: dead nodes keep routing the beam
    (their edges are intact) but carry dist = +inf, so they are expanded
    last and can never surface as answers."""
    q = jnp.atleast_2d(q)
    b = q.shape[0]
    deg = graph.shape[1]
    e = expand
    rows = jnp.arange(b)[:, None]

    seeds = jnp.resize(entry_points, (beam,))            # (beam,)
    beam_ids = jnp.broadcast_to(seeds[None, :], (b, beam))
    beam_d = jnp.sum(
        (emb[seeds][None, :, :] - q[:, None, :]) ** 2, -1)
    if masked:
        beam_d = jnp.where(valid[seeds][None, :], beam_d, jnp.inf)
    # mark duplicate seeds so they are not re-expanded
    nentry = entry_points.shape[0]
    dup0 = jnp.concatenate(
        [jnp.zeros((nentry,), bool), jnp.ones((beam - nentry,), bool)]
    ) if beam > nentry else jnp.zeros((beam,), bool)
    beam_d = jnp.where(dup0[None, :], jnp.inf, beam_d)
    expanded = jnp.broadcast_to(dup0[None, :], (b, beam))

    def step(_, carry):
        ids, dist, exp = carry                          # all (b, beam)
        # expand the e best unexpanded beam entries of every query
        cand_d = jnp.where(exp, jnp.inf, dist)
        _, sel = jax.lax.top_k(-cand_d, e)                    # (b, e)
        exp = exp.at[rows, sel].set(True)
        sel_ids = jnp.take_along_axis(ids, sel, axis=1)
        nbrs = graph[sel_ids].reshape(b, e * deg)
        nd = jnp.sum(
            (emb[nbrs] - q[:, None, :]) ** 2, axis=-1)
        if masked:
            nd = jnp.where(valid[nbrs], nd, jnp.inf)
        all_ids = jnp.concatenate([ids, nbrs], axis=1)
        all_d = jnp.concatenate([dist, nd], axis=1)
        all_exp = jnp.concatenate(
            [exp, jnp.zeros((b, e * deg), bool)], axis=1)
        # dedup: keep the first occurrence of each id (sorted by id,
        # mark repeats with +inf) then take the best `beam`
        order = jnp.argsort(all_ids, axis=1)
        sid = jnp.take_along_axis(all_ids, order, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((b, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1)
        dupmask = jnp.zeros_like(dup).at[rows, order].set(dup)
        all_d = jnp.where(dupmask, jnp.inf, all_d)
        neg, pos = jax.lax.top_k(-all_d, beam)
        return (jnp.take_along_axis(all_ids, pos, axis=1), -neg,
                jnp.take_along_axis(all_exp, pos, axis=1))

    ids, dist, _ = jax.lax.fori_loop(
        0, steps, step, (beam_ids, beam_d, expanded))
    # the beam can only ever hold `beam` candidates: k beyond it is
    # structural underflow — pad with the protocol's -1 / +inf slots
    # (so e.g. cfg.c_remote > beam degrades instead of crashing)
    kk = min(k, beam)
    neg, pos = jax.lax.top_k(-dist, kk)
    out_ids = jnp.take_along_axis(ids, pos, axis=1)
    out_d = -neg
    out_ids = jnp.where(jnp.isfinite(neg), out_ids, -1)
    if kk < k:
        out_d = jnp.pad(out_d, ((0, 0), (0, k - kk)),
                        constant_values=jnp.inf)
        out_ids = jnp.pad(out_ids, ((0, 0), (0, k - kk)),
                          constant_values=-1)
    return out_d, out_ids


class NSWIndex(MutableRows):
    exact_distances = True  # candidates scored with exact L2
    # answer-cache capability flags (repro.serve.answer_cache): insertion
    # rewires existing nodes' adjacency (reverse links) and the first
    # tombstone flips beam masking, so mutations can change answers far
    # from the mutated rows — the cache must flush, not radius-check.
    answer_unstable_add = True
    answer_unstable_remove = True

    # how many of a new node's neighbours donate one edge slot back to it
    # (the incremental insertion's bidirectional-link half)
    _REV_LINKS = 2

    def __init__(self, embeddings, degree: int = 16, beam: int = 32,
                 steps: int = 12, expand: int = 2, seed: int = 0):
        self._init_rows(embeddings)
        self.beam, self.steps, self.degree = beam, steps, degree
        self.expand = max(1, min(expand, beam))
        self.seed = seed
        self._rng = np.random.default_rng(seed + 1)  # insertion randomness
        self._build_structures()

    def _compute_structures(self):
        """Rebuild graph + entry points over the live rows (restores the
        build-quality kNN graph after incremental drift / deletions).
        Pure — serving keeps the stale graph until `_install_structures`."""
        live = self.live_rows()
        emb_np = np.asarray(self.embeddings)[live]
        graph_live = build_nsw_graph(emb_np, self.degree, seed=self.seed)
        graph = np.zeros((self.capacity, self.degree), np.int32)
        graph[live] = live[graph_live]               # remap to slab row ids
        # entry points = catalog points nearest to k-means centroids: the
        # static-shape stand-in for HNSW's upper navigation layers — ensures
        # every density mode seeds the beam (DESIGN.md §3).
        from repro.index.kmeans import kmeans as _kmeans

        nentry = min(self.beam, len(live))
        emb_live = (self.embeddings if len(live) == self.capacity
                    else self.embeddings[jnp.asarray(live)])
        cents, _ = _kmeans(jax.random.PRNGKey(self.seed), emb_live, nentry)
        d2 = ops.pairwise_l2_xla(cents, emb_live)
        near = np.asarray(jnp.argmin(d2, axis=1))
        return (jnp.asarray(graph), jnp.asarray(live[near], jnp.int32))

    def _install_structures(self, structures) -> None:
        self.graph, self.entry_points = structures

    # -- mutation -----------------------------------------------------------

    def add(self, vectors) -> np.ndarray:
        """Incremental NSW insertion: out-edges = beam-search kNN over the
        pre-insert graph + random shortcut edges; `_REV_LINKS` neighbours
        each donate one edge slot back so the new nodes become reachable.

        Device-resident fast path: the pre-insert kNN query runs on the
        width-padded batch (fixed shapes, no per-batch-size retrace), the
        new out-rows are assembled on the host and land in the (cap, deg)
        edge table via a donated contiguous row write, and the reverse
        links via one donated flat scatter — no numpy graph master."""
        vec_np = np.atleast_2d(np.asarray(vectors, np.float32))
        live_before = self.live_rows()
        # neighbours from the *current* structures (the classic sequential
        # insertion queries the graph as built so far; querying once for
        # the whole batch keeps in-batch nodes unlinked to each other)
        knn = min(self.degree - 2, max(len(live_before) - 1, 1))
        b = vec_np.shape[0]
        qpad = pad_rows(vec_np)
        _, nbr = self.query(qpad, knn)
        nbr = np.asarray(nbr)[:b]                             # (B, knn)
        ids = self._append_rows(vec_np)
        if self.graph.shape[0] < self.capacity:               # slab grew
            self.graph = jnp.pad(
                self.graph,
                ((0, self.capacity - self.graph.shape[0]), (0, 0)))
        # padded lanes land on unused slots past the append (the slab
        # keeps a full write window of headroom); zeros are unreachable
        rows = np.zeros((qpad.shape[0], self.degree), np.int32)
        rev_flat: list[int] = []            # reverse-link scatter entries
        rev_vals: list[int] = []
        for row, (i, nb) in enumerate(zip(ids, nbr)):
            nb = nb[nb >= 0]
            if len(nb) == 0:  # first-ever node: all self-loops
                rows[row] = i
                continue
            out = np.full((self.degree,), i, np.int32)        # self-loop pad
            out[:len(nb)] = nb
            # shortcut slots: random live nodes (long-range edges, same
            # role as the build-time random far edges)
            n_short = self.degree - len(nb)
            if n_short > 0 and len(live_before):
                out[len(nb):] = self._rng.choice(live_before, size=n_short)
            rows[row] = out
            # reverse half: a few neighbours each give one slot back
            for j in nb[:self._REV_LINKS]:
                slot = int(self._rng.integers(self.degree))
                rev_flat.append(int(j) * self.degree + slot)
                rev_vals.append(int(i))
        self.graph = run_device(_rows_write, self.graph, jnp.asarray(rows),
                                np.int32(ids[0]))
        if rev_flat:
            oob = self.graph.size
            self.graph = run_device(
                _flat_set, self.graph,
                pad_ids(np.asarray(rev_flat, np.int32), oob),
                pad_ids(np.asarray(rev_vals, np.int32), -1))
        return ids

    # -- queries ------------------------------------------------------------

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings, self.graph, self.entry_points,
                            self.valid)

    def query(self, q: jax.Array, k: int):
        check_finite_queries(q, "NSWIndex.query")
        # dead nodes keep routing until refresh (mark-deleted semantics),
        # so the mask is needed as soon as any row is tombstoned; unlinked
        # slab rows beyond n_slots are unreachable (no in-edges).
        return _nsw_query(q, self.embeddings, self.graph, self.entry_points,
                          self.valid, k, self.beam, self.steps, self.expand,
                          masked=self._live != self._n_slots)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
