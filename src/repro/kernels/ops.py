"""Jitted public wrappers for the Pallas kernels.

Handle padding to block multiples, dtype promotion, backend dispatch
(interpret=True automatically on non-TPU backends so the same call sites
run in CI/CPU and on real hardware), and the partial-top-k merge.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import l2 as l2_kernel
from repro.kernels import l2_topk as l2_topk_kernel
from repro.kernels import pq_adc as pq_adc_kernel
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(a: jax.Array, mult: int, value=0.0) -> jax.Array:
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=value)


@partial(jax.jit, static_argnames=("interpret",))
def pairwise_l2(q: jax.Array, x: jax.Array, *, interpret: bool | None = None):
    """(Q, D) x (N, D) -> (Q, N) squared L2 via the Pallas kernel."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = q.shape[0], x.shape[0]
    qp = _pad_rows(q, l2_kernel.BQ)
    xp = _pad_rows(x, l2_kernel.BN)
    out = l2_kernel.pairwise_l2_pallas(qp, xp, interpret=interp)
    return out[:qq, :n]


@partial(jax.jit, static_argnames=("interpret",))
def pq_adc(lut: jax.Array, codes: jax.Array, *, interpret: bool | None = None):
    """ADC scan: lut (Q, M, C) x codes (N, M) -> (Q, N)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = lut.shape[0], codes.shape[0]
    lp = _pad_rows(lut, pq_adc_kernel.BQ)
    cp = _pad_rows(codes, pq_adc_kernel.BN)
    out = pq_adc_kernel.pq_adc_pallas(lp, cp, interpret=interp)
    return out[:qq, :n]


@partial(jax.jit, static_argnames=("k", "interpret"))
def topk_l2(q: jax.Array, x: jax.Array, k: int, *, interpret: bool | None = None):
    """Fused blocked distance+top-k: returns (dists (Q,k), ids (Q,k))."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = q.shape[0], x.shape[0]
    qp = _pad_rows(q, l2_topk_kernel.BQ)
    xp = _pad_rows(x, l2_topk_kernel.BN)
    pd, pi = l2_topk_kernel.l2_topk_pallas(qp, xp, k, n_valid=n, interpret=interp)
    neg, pos = jax.lax.top_k(-pd, k)
    ids = jnp.take_along_axis(pi, pos, axis=1)
    return (-neg)[:qq], ids[:qq]


# jnp fallbacks, exported for benchmarking kernel vs XLA-fused baseline.
pairwise_l2_xla = jax.jit(ref.pairwise_l2_ref)
pq_adc_xla = jax.jit(ref.pq_adc_ref)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                   "written_upto", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    written_upto=None, interpret: bool | None = None):
    """Pallas flash attention: q (B,S,H,D), k/v (B,T,KV,D) -> (B,S,H,Dv)."""
    from repro.kernels import flash_attention as fa

    interp = (not _on_tpu()) if interpret is None else interpret
    s = q.shape[1]
    bq = min(fa.BQ, s)
    bk = min(fa.BK, k.shape[1])
    pad = (-s) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset,
                                    written_upto=written_upto,
                                    bq=bq, bk=bk, interpret=interp)
    return out[:, :s]
