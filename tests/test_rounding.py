"""DepRound + CoupledRounding properties (paper Lemma 2/3, Theorem F.1)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import rounding as R


def _feasible_y(rng, n, h):
    z = rng.random(n)
    z = np.minimum(z / z.sum() * h, 1.0)
    for _ in range(8):
        z = np.clip(z + (h - z.sum()) * (1 - z) / max((1 - z).sum(), 1e-9), 0, 1)
    return z.astype(np.float32)


def test_depround_cardinality_exact():
    rng = np.random.default_rng(0)
    for n, h in [(32, 5), (100, 20), (7, 3)]:
        y = jnp.array(_feasible_y(rng, n, h))
        keys = jax.random.split(jax.random.PRNGKey(1), 200)
        xs = np.array(jax.vmap(lambda k: R.depround(k, y))(keys))
        assert set(np.unique(xs)) <= {0.0, 1.0}
        np.testing.assert_array_equal(xs.sum(1), h)


def test_depround_marginals():
    rng = np.random.default_rng(1)
    n, h, trials = 64, 12, 4000
    y = jnp.array(_feasible_y(rng, n, h))
    keys = jax.random.split(jax.random.PRNGKey(2), trials)
    xs = np.array(jax.vmap(lambda k: R.depround(k, y))(keys))
    err = np.abs(xs.mean(0) - np.array(y)).max()
    assert err < 4.0 / np.sqrt(trials), err  # ~4 sigma


def test_depround_negative_correlation():
    """Property (3): E prod_{i in S}(1 - x_i) <= prod (1 - y_i)."""
    rng = np.random.default_rng(2)
    n, h, trials = 48, 10, 4000
    y = _feasible_y(rng, n, h)
    keys = jax.random.split(jax.random.PRNGKey(3), trials)
    xs = np.array(jax.vmap(lambda k: R.depround(k, jnp.array(y)))(keys))
    viol = 0
    for _ in range(40):
        s = rng.choice(n, size=5, replace=False)
        lhs = np.prod(1 - xs[:, s], axis=1).mean()
        rhs = np.prod(1 - y[s])
        if lhs > rhs + 4 * np.sqrt(rhs * (1 - rhs) / trials + 1e-9):
            viol += 1
    assert viol <= 2, viol


def test_coupled_rounding_theorem_f1():
    """E[x_{t+1}] = y_{t+1} and E||x_{t+1}-x_t||_1 = ||y_{t+1}-y_t||_1."""
    rng = np.random.default_rng(3)
    n, h, trials = 64, 12, 3000
    y0 = _feasible_y(rng, n, h)
    y1 = np.clip(y0 + rng.normal(0, 0.08, n).astype(np.float32), 0.01, 0.99)
    keys = jax.random.split(jax.random.PRNGKey(4), trials)
    x0 = np.array(
        jax.vmap(lambda k: R.depround(k, jnp.array(y0)))(keys[: trials // 2])
    )
    x0 = np.concatenate([x0, x0])
    keys2 = jax.random.split(jax.random.PRNGKey(5), trials)
    x1 = np.array(
        jax.vmap(lambda k, x: R.coupled_rounding(k, x, jnp.array(y0), jnp.array(y1)))(
            keys2, jnp.array(x0)
        )
    )
    assert np.abs(x1.mean(0) - y1).max() < 5.0 / np.sqrt(trials)
    move = np.abs(x1 - x0).sum(1).mean()
    target = np.abs(y1 - y0).sum()
    assert move == pytest.approx(target, rel=0.15)


def test_independent_rounding_occupancy_concentration():
    """Chernoff Eq. (81): occupancy within (1 ± delta) h w.h.p."""
    rng = np.random.default_rng(4)
    n, h = 2000, 200
    y = _feasible_y(rng, n, h)
    keys = jax.random.split(jax.random.PRNGKey(6), 200)
    xs = np.array(jax.vmap(lambda k: R.independent_rounding(k, jnp.array(y)))(keys))
    occ = xs.sum(1)
    assert np.abs(occ.mean() - h) < 0.05 * h
    assert (np.abs(occ - h) < 0.25 * h).mean() > 0.99


def test_movement_counts_fetches_only():
    x_old = jnp.array([1.0, 0.0, 1.0, 0.0])
    x_new = jnp.array([0.0, 1.0, 1.0, 1.0])
    assert float(R.movement(x_new, x_old)) == 2.0
