"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Queries and keys/values are produced through low-rank bottlenecks; the KV
cache stores only the compressed latent (kv_lora_rank) plus a shared RoPE
key (qk_rope_dim) per token — 512+64 floats instead of
2*n_heads*head_dim = 32768 for the 128-head config: the 57x cache
compression that makes deepseek-v3 decode feasible.

Two decode paths:
  * materialize: expand K/V from the latent every step (paper-faithful
    baseline; recompute cost ~ 2*T*rank*heads*dim).
  * absorbed: fold W_uk into the query and W_uv into the output projection
    so attention runs directly in latent space (the DeepSeek serving trick;
    enabled via cfg-level perf flag in the serve engine — see §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, dense_init, dtype_of,
                                 rms_norm)


def init_mla(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d, h = cfg.d_model, cfg.n_heads
    qr = cfg.q_lora_rank or d
    kr = cfg.kv_lora_rank
    nope, rope_d, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wkv_a": dense_init(ks[2], d, kr + rope_d, dt),
        "kv_norm": jnp.ones((kr,), dt),
        "wk_b": dense_init(ks[3], kr, h * nope, dt),
        "wv_b": dense_init(ks[4], kr, h * vdim, dt),
        "wo": dense_init(ks[5], h * vdim, d, dt),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, qr, dt)
        p["q_norm"] = jnp.ones((qr,), dt)
        p["wq_b"] = dense_init(ks[1], qr, h * (nope + rope_d), dt)
    else:
        p["wq"] = dense_init(ks[0], d, h * (nope + rope_d), dt)
    return p


def mla_attention(p, x, positions, cfg: ModelConfig, cache=None,
                  cache_len=None):
    """cache: {'ckv': (B, S_max, kv_rank), 'krope': (B, S_max, rope_d)}."""
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope_d, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank

    if cfg.q_lora_rank:
        q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]                                     # (B,S,kr+rope)
    ckv, krope = kv[..., :kr], kv[..., kr:]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache is not None:
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_len, axis=1)
        krope_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope.astype(cache["krope"].dtype), cache_len, axis=1)
        new_cache = {"ckv": ckv_all, "krope": krope_all}
        t = ckv_all.shape[1]
    else:
        ckv_all, krope_all, new_cache, t = ckv, krope, None, s

    if cfg.mla_absorbed_decode and cache is not None and s == 1:
        # Absorbed decode (§Perf): fold W_uk into the query and W_uv into
        # the output so attention runs directly against the latent cache —
        # per-token cost O(T * kv_rank * H) instead of
        # O(T * kv_rank * H * (nope + v)) from re-materialising K/V.
        wk_b = p["wk_b"].reshape(kr, h, nope)
        wv_b = p["wv_b"].reshape(kr, h, vdim)
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           wk_b.astype(jnp.float32))          # (b,1,h,kr)
        logits = (
            jnp.einsum("bshr,btr->bhst", q_abs,
                       ckv_all.astype(jnp.float32))
            + jnp.einsum("bshp,btp->bhst", q_rope.astype(jnp.float32),
                         krope_all.astype(jnp.float32))
        ) / jnp.sqrt(nope + rope_d)
        written = jnp.arange(t)[None, None, None, :] < cache_len + s
        logits = jnp.where(written, logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1)
        ctx_lat = jnp.einsum("bhst,btr->bshr", w,
                             ckv_all.astype(jnp.float32))     # (b,1,h,kr)
        out = jnp.einsum("bshr,rhv->bshv", ctx_lat,
                         wv_b.astype(jnp.float32))
        out = out.reshape(b, s, h * vdim).astype(x.dtype) @ p["wo"]
        return out, new_cache

    # materialize K/V from the latent (paper-faithful baseline path; the
    # absorbed-weights decode variant is the §Perf optimization).  Decode
    # steps expand in float32: the bf16 rounding of the re-materialised
    # K/V is exactly what separates this path from the absorbed decode
    # (which contracts in latent space in f32).  This doubles the decode
    # step's transient (B, T, H, ·) K/V buffers — acceptable because this
    # materialised decode is the reference path (serving uses the absorbed
    # variant, which never expands K/V at all); prefill, where the buffers
    # are live across the whole sequence anyway, keeps the model dtype.
    lat = (ckv_all.astype(jnp.float32) if cache is not None and s == 1
           else ckv_all)
    k_nope = (lat @ p["wk_b"].astype(lat.dtype)).reshape(b, t, h, nope)
    v = (lat @ p["wv_b"].astype(lat.dtype)).reshape(b, t, h, vdim)
    krope_b = jnp.broadcast_to(krope_all[:, :, None, :].astype(k_nope.dtype),
                               (b, t, h, rope_d))
    k_full = jnp.concatenate([k_nope, krope_b], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    from repro.models.layers import attention_core

    q_offset = 0 if cache is None else cache_len
    written = None if cache is None else cache_len + s
    out = attention_core(q_full.astype(k_full.dtype), k_full, v, q_offset,
                         cfg, written_upto=written)
    out = out.reshape(b, s, h * vdim).astype(x.dtype) @ p["wo"]
    return out, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, s_max: int, dtype):
    return {
        "ckv": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype),
    }
