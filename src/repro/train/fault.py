"""Fault tolerance + straggler mitigation for long-running training.

* `TrainLoop` — checkpoint-every-N steps, automatic resume-from-latest on
  (re)start, bounded restart budget.  Failures are whatever the step
  function raises (on real fleets: device loss / preemption surfaced as
  XlaRuntimeError; in tests: injected exceptions).
* `StragglerMonitor` — EMA step timing; flags steps slower than
  `threshold x` the running median.  On TPU pods, persistent stragglers
  are handled by checkpoint + restart without the slow host (elastic
  resume on a smaller mesh — `checkpoint.restore` already re-shards);
  the monitor provides the detection signal and the decision log.
* `reshard` — move a whole state tree onto a new mesh (elastic scaling).
"""

from __future__ import annotations

import collections
import logging
import time
from typing import Callable, Optional

import jax

from repro.train import checkpoint

log = logging.getLogger("repro.fault")


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 50,
                 quiet: bool = False):
        self.threshold = threshold
        self.times = collections.deque(maxlen=window)
        self.flagged: list[tuple[int, float]] = []
        # quiet: flag + record without log spam (the resilient serving
        # tier reuses the monitor per request; its counters report)
        self.quiet = quiet

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        # true median: even-length windows average the two middle samples
        # (upper-middle alone biases the threshold high, hiding stragglers)
        ts = sorted(self.times)
        mid = len(ts) // 2
        med = ts[mid] if len(ts) % 2 else 0.5 * (ts[mid - 1] + ts[mid])
        slow = len(self.times) >= 5 and seconds > self.threshold * med
        if slow:
            self.flagged.append((step, seconds))
            if not self.quiet:
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, seconds, med)
        return slow


def reshard(tree, shardings):
    """Elastic re-shard: device_put every leaf onto the new sharding tree."""
    return jax.tree.map(jax.device_put, tree, shardings)


class TrainLoop:
    """Restartable training loop around a pure step function."""

    def __init__(self, step_fn: Callable, state, ckpt_dir: str,
                 ckpt_every: int = 50, max_restarts: int = 3,
                 monitor: Optional[StragglerMonitor] = None):
        self.step_fn = step_fn
        self.state = state
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = monitor or StragglerMonitor()
        self.restarts = 0

    def _resume_step(self) -> int:
        latest = checkpoint.latest_step(self.ckpt_dir)
        if latest is None:
            return 0
        self.state = checkpoint.restore(self.ckpt_dir, latest, self.state)
        log.info("resumed from step %d", latest)
        return latest

    def run(self, num_steps: int, batch_fn: Callable):
        """Runs to `num_steps`, restarting from the latest checkpoint on
        failure (up to max_restarts)."""
        step = self._resume_step()
        while step < num_steps:
            try:
                t0 = time.time()
                self.state = self.step_fn(self.state, batch_fn(step), step)
                jax.block_until_ready(self.state)
                self.monitor.record(step, time.time() - t0)
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    checkpoint.save(self.ckpt_dir, step, self.state)
            except Exception:  # noqa: BLE001 — restart path
                self.restarts += 1
                log.exception("step %d failed (restart %d/%d)", step,
                              self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                step = self._resume_step()
        return self.state
