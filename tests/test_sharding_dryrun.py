"""Sharding specs + a real multi-device pjit execution in a subprocess.

The main test process must keep the default 1-device CPU; multi-device
runs happen in a child process that sets XLA_FLAGS before importing jax —
the same discipline as launch/dryrun.py.
"""

import json
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models import init_params
from repro.sharding import specs as S


def test_param_pspecs_cover_all_leaves():
    mesh_shape = {"data": 16, "model": 16}
    for name, cfg in SMOKE_ARCHS.items():
        params_shape = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        pspecs = S.param_pspecs(cfg, params_shape, mesh_shape)
        n_leaves = len(jax.tree.leaves(params_shape))
        n_specs = len(jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        assert n_leaves == n_specs, name


def test_pspec_divisibility_respected():
    """Every sharded dim must be divisible by its mesh-axis size."""
    from repro.configs import ARCHS
    mesh_shape = {"pod": 2, "data": 16, "model": 16}
    for name, cfg in ARCHS.items():
        params_shape = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        pspecs = S.param_pspecs(cfg, params_shape, mesh_shape)
        for leaf, spec in zip(
                jax.tree.leaves(params_shape),
                jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))):
            for dim, ax in zip(leaf.shape, spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= mesh_shape[a]
                assert dim % size == 0, (name, leaf.shape, tuple(spec))


_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import SMOKE_ARCHS
    from repro.launch.mesh import mesh_shape_dict
    from repro.models import init_params
    from repro.sharding import specs as S
    from repro.sharding.ctx import mesh_context
    from repro.train import OptConfig, make_train_step
    from repro.train.optimizer import init_opt
    from repro.train.batching import synthetic_batch
    from repro.configs.shapes import ShapeSpec

    cfg = SMOKE_ARCHS["mixtral-8x22b"]
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    msd = mesh_shape_dict(mesh)
    params = init_params(jax.random.PRNGKey(0), cfg)
    pspecs = S.param_pspecs(cfg, jax.eval_shape(lambda: init_params(
        jax.random.PRNGKey(0), cfg)), msd)
    param_sh = S.as_shardings(mesh, pspecs)
    params = jax.tree.map(jax.device_put, params, param_sh)
    opt = init_opt(cfg.optimizer, params)
    batch = synthetic_batch(cfg, ShapeSpec("train", 16, 4, "train"))
    with mesh_context(mesh, ("data",)):
        step = jax.jit(make_train_step(cfg, OptConfig(name=cfg.optimizer)))
        p, o, m = step(params, opt, batch, 0)
        loss1 = float(m.loss)
        p, o, m = step(p, o, batch, 1)
        loss2 = float(m.loss)
    print(json.dumps({"loss1": loss1, "loss2": loss2,
                      "n_dev": jax.device_count()}))
""")


def test_multidevice_pjit_execution_subprocess():
    out = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_dev"] == 8
    assert res["loss2"] < res["loss1"] * 1.5  # finite and sane
