"""Product quantization codec + IVF-PQ index (the paper's remote-catalog
index: ~30 bytes/object à la FAISS IVFPQ, Sec. III).

The ADC scan runs through repro.kernels.ops.pq_adc — the one-hot-matmul TPU
adaptation of the GPU shared-memory gather (DESIGN.md §3).  An optional
exact re-rank of the top candidates (refine factor) recovers recall, which
is standard FAISS practice and what AÇAI needs to estimate true server-side
dissimilarity costs.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import arrays_bytes
from repro.index.ivf import build_invlists
from repro.index.kmeans import kmeans
from repro.kernels import ops


class PQCodec:
    """M sub-spaces x 256-centroid codebooks."""

    def __init__(self, data: jax.Array, m: int = 8, nbits: int = 8,
                 train_iters: int = 12, seed: int = 0):
        n, d = data.shape
        assert d % m == 0, (d, m)
        self.m, self.dsub, self.ksub = m, d // m, 2 ** nbits
        sub = jnp.asarray(data, jnp.float32).reshape(n, m, self.dsub)
        keys = jax.random.split(jax.random.PRNGKey(seed), m)
        ksub = min(self.ksub, n)
        cents, _ = jax.vmap(lambda k, x: kmeans(k, x, ksub, train_iters))(
            keys, sub.transpose(1, 0, 2)
        )
        if ksub < self.ksub:  # pad tiny training sets
            pad = jnp.repeat(cents[:, :1], self.ksub - ksub, axis=1)
            cents = jnp.concatenate([cents, pad], axis=1)
        self.codebooks = cents  # (m, ksub, dsub)

    @partial(jax.jit, static_argnames=("self",))
    def encode(self, data: jax.Array) -> jax.Array:
        n, d = data.shape
        sub = data.reshape(n, self.m, self.dsub).transpose(1, 0, 2)
        d2 = jax.vmap(ops.pairwise_l2_xla)(sub, self.codebooks)  # (m, n, ksub)
        return jnp.argmin(d2, axis=-1).T.astype(jnp.int32)       # (n, m)

    @partial(jax.jit, static_argnames=("self",))
    def decode(self, codes: jax.Array) -> jax.Array:
        gathered = jax.vmap(lambda cb, c: cb[c], in_axes=(0, 1))(
            self.codebooks, codes
        )  # (m, n, dsub)
        return gathered.transpose(1, 0, 2).reshape(codes.shape[0], -1)

    @partial(jax.jit, static_argnames=("self",))
    def adc_lut(self, q: jax.Array) -> jax.Array:
        """(B, d) -> (B, m, ksub) per-subspace distance tables."""
        b = q.shape[0]
        sub = q.reshape(b, self.m, self.dsub).transpose(1, 0, 2)  # (m, B, dsub)
        lut = jax.vmap(ops.pairwise_l2_xla)(sub, self.codebooks)  # (m, B, ksub)
        return lut.transpose(1, 0, 2)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


class IVFPQIndex:
    """Coarse IVF + PQ-coded residual-free storage + optional exact refine."""

    def __init__(self, embeddings, nlist: int = 64, nprobe: int = 8,
                 m: int = 8, refine: int = 4, seed: int = 0):
        self.embeddings = jnp.asarray(embeddings, jnp.float32)
        self.nlist, self.nprobe, self.refine = nlist, nprobe, refine
        # with refine the final top-k is exactly re-ranked; without it the
        # returned distances are ADC approximations (re-rank downstream)
        self.exact_distances = bool(refine and refine > 1)
        key = jax.random.PRNGKey(seed)
        self.centroids, assign = kmeans(key, self.embeddings, nlist)
        self.invlists = jnp.asarray(
            build_invlists(np.asarray(assign), nlist), jnp.int32
        )
        self.codec = PQCodec(self.embeddings, m=m, seed=seed + 1)
        self.codes = self.codec.encode(self.embeddings)  # (N, m)

    @property
    def n(self) -> int:
        return self.embeddings.shape[0]

    def memory_bytes(self) -> int:
        """Everything resident at query time, like every other backend:
        the float32 slab (the refine re-rank gathers from it) plus the PQ
        structures.  The paper's ~30 B/object compressed accounting is
        `compressed_bytes()`."""
        return arrays_bytes(self.embeddings, self.codes,
                            self.codec.codebooks, self.centroids,
                            self.invlists)

    def compressed_bytes(self) -> int:
        """PQ-only footprint (codes + codebooks + coarse layer): what a
        deployment that drops the float32 slab (refine=0, re-rank
        downstream) would hold — the paper's ~30 B/object figure."""
        return arrays_bytes(self.codes, self.codec.codebooks,
                            self.centroids, self.invlists)

    @partial(jax.jit, static_argnames=("self", "k"))
    def query(self, q: jax.Array, k: int):
        q = jnp.atleast_2d(q)
        b = q.shape[0]
        dc = ops.pairwise_l2_xla(q, self.centroids)
        _, probe = jax.lax.top_k(-dc, self.nprobe)
        cand = self.invlists[probe].reshape(b, -1)          # (B, P)
        valid = cand >= 0
        safe = jnp.clip(cand, 0, None)

        lut = self.codec.adc_lut(q)                          # (B, m, ksub)
        codes = self.codes[safe]                             # (B, P, m)
        # per-query ADC over its own candidate rows
        d_adc = jax.vmap(lambda l, c: ops.pq_adc(l[None], c)[0])(lut, codes)
        d_adc = jnp.where(valid, d_adc, jnp.inf)

        if self.refine and self.refine > 1:
            r = min(self.refine * k, d_adc.shape[1])
            neg, pos = jax.lax.top_k(-d_adc, r)              # approx top-r
            rid = jnp.take_along_axis(cand, pos, axis=1)
            rid = jnp.where(jnp.isfinite(neg), rid, -1)
            # exact re-rank through the fused gather+L2+top-k scan
            return ops.ivf_scan_auto(q, self.embeddings, rid, k)

        neg, pos = jax.lax.top_k(-d_adc, k)
        ids = jnp.take_along_axis(cand, pos, axis=1)
        return -neg, jnp.where(jnp.isfinite(neg), ids, -1)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
