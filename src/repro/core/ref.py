"""Pure-numpy reference oracle for the AÇAI core math.

Implements the paper's equations literally (loops, no vectorisation
tricks) over the FULL catalog, as an independent cross-check for the
fixed-candidate-set jnp implementation in repro.core.gain.

  * cost_integral       — Eq. (5) by direct simulation
  * gain_integral       — Eq. (6)
  * gain_fractional     — Eq. (7) with explicit K^r / sigma / alpha
  * lower_bound         — Eq. (15)
  * subgrad_fd          — two-sided finite differences on Eq. (7)
  * best_answer_bruteforce — Eq. (2) by subset enumeration (tiny instances)
"""

from __future__ import annotations

import itertools

import numpy as np


def _augmented(d: np.ndarray, c_f: float):
    """Return (costs, is_remote, obj) for 2N entries, sorted by cost."""
    n = d.shape[0]
    costs = np.concatenate([d, d + c_f])
    is_remote = np.concatenate([np.zeros(n, bool), np.ones(n, bool)])
    obj = np.concatenate([np.arange(n), np.arange(n)])
    order = np.argsort(costs, kind="stable")
    return costs[order], is_remote[order], obj[order]


def cost_integral(d: np.ndarray, x: np.ndarray, k: int, c_f: float) -> float:
    """C(r, x) of Eq. (5): walk pi^r, take available entries until k served."""
    costs, is_remote, obj = _augmented(d, c_f)
    total, served = 0.0, 0
    for c, rem, o in zip(costs, is_remote, obj):
        if served >= k:
            break
        avail = (1 - x[o]) if rem else x[o]
        if avail >= 0.5:
            total += c
            served += 1
    assert served == k, "catalog too small for k"
    return float(total)


def empty_cost(d: np.ndarray, k: int, c_f: float) -> float:
    return float(np.sort(d)[:k].sum() + k * c_f)


def gain_integral(d: np.ndarray, x: np.ndarray, k: int, c_f: float) -> float:
    return empty_cost(d, k, c_f) - cost_integral(d, x, k, c_f)


def _sorted_entries(d: np.ndarray, y: np.ndarray, c_f: float):
    n = d.shape[0]
    costs = np.concatenate([d, d + c_f])
    weights = np.concatenate([y, 1.0 - y])
    is_remote = np.concatenate([np.zeros(n, bool), np.ones(n, bool)])
    order = np.argsort(costs, kind="stable")
    return costs[order], weights[order], is_remote[order]


def gain_fractional(d: np.ndarray, y: np.ndarray, k: int, c_f: float) -> float:
    """G(r, y) of Eq. (7), literal transcription."""
    costs, weights, is_remote = _sorted_entries(d, y, c_f)
    sigma = np.cumsum(is_remote.astype(float))
    k_r = int(np.argmax(sigma >= k)) + 1  # 1-based K^r
    s = np.cumsum(weights)
    total = 0.0
    for i in range(1, k_r):  # i = 1 .. K^r - 1 (1-based)
        alpha = costs[i] - costs[i - 1]
        total += alpha * min(k - sigma[i - 1], s[i - 1] - sigma[i - 1])
    return float(total)


def lower_bound(d: np.ndarray, y: np.ndarray, k: int, c_f: float) -> float:
    """L(r, y) of Eq. (15)."""
    n = d.shape[0]
    costs = np.concatenate([d, d + c_f])
    weights = np.concatenate([y, 1.0 - y])
    is_remote = np.concatenate([np.zeros(n, bool), np.ones(n, bool)])
    obj = np.concatenate([np.arange(n), np.arange(n)])
    order = np.argsort(costs, kind="stable")
    costs, weights, is_remote, obj = (
        costs[order], weights[order], is_remote[order], obj[order]
    )
    pos_of_remote = {obj[p]: p for p in range(2 * n) if is_remote[p]}
    sigma = np.cumsum(is_remote.astype(float))
    k_r = int(np.argmax(sigma >= k)) + 1
    total = 0.0
    for i in range(1, k_r):  # prefix length i (1-based)
        alpha = costs[i] - costs[i - 1]
        c = k - sigma[i - 1]
        prod = 1.0
        for p in range(i):
            if not is_remote[p] and pos_of_remote[obj[p]] >= i:
                prod *= 1.0 - weights[p] / c
        total += alpha * c * (1.0 - prod)
    return float(total)


def subgrad_fd(
    d: np.ndarray, y: np.ndarray, k: int, c_f: float, eps: float = 1e-5
) -> np.ndarray:
    """Two-sided finite-difference gradient of Eq. (7) — exact at generic y
    (G is piecewise linear; non-differentiable only where some S_i = k)."""
    g = np.zeros_like(y)
    for i in range(y.shape[0]):
        yp, ym = y.copy(), y.copy()
        yp[i] += eps
        ym[i] -= eps
        g[i] = (gain_fractional(d, yp, k, c_f) - gain_fractional(d, ym, k, c_f)) / (
            2 * eps
        )
    return g


def best_answer_bruteforce(
    d: np.ndarray, x: np.ndarray, k: int, c_f: float
) -> float:
    """min_{|B| = k} C(r, B) of Eq. (2) by enumeration (use only for N<=12)."""
    n = d.shape[0]
    best = np.inf
    for subset in itertools.combinations(range(n), k):
        c = sum(d[o] if x[o] >= 0.5 else d[o] + c_f for o in subset)
        best = min(best, c)
    return float(best)


def project_capped_simplex_bisect(
    z: np.ndarray, h: float, kind: str = "negentropy", iters: int = 200
) -> np.ndarray:
    """Oracle Bregman projection onto {y in [0,1]^N : sum y = h} by bisection.

    negentropy: y = min(1, z * s)        (s > 0)
    euclidean : y = clip(z - tau, 0, 1)
    """
    if kind == "negentropy":
        lo, hi = 0.0, (h / max(z.sum(), 1e-30)) * 1e6 + 1e6

        def total(s):
            return np.minimum(1.0, z * s).sum()

        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            if total(mid) < h:
                lo = mid
            else:
                hi = mid
        return np.minimum(1.0, z * 0.5 * (lo + hi))
    if kind == "euclidean":
        lo, hi = z.min() - 1.0 - h, z.max() + 1.0

        def total_tau(tau):
            return np.clip(z - tau, 0.0, 1.0).sum()

        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            if total_tau(mid) > h:
                lo = mid
            else:
                hi = mid
        return np.clip(z - 0.5 * (lo + hi), 0.0, 1.0)
    raise ValueError(kind)
