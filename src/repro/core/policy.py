"""AÇAI policy: request serving + OMA cache updates (paper Sec. IV).

Two entry points:

* `make_replay(...)` — a fully-jitted `lax.scan` over a request trace,
  carrying (y_t, x_t, key).  This is the benchmark/experiment hot path:
  per request it (1) builds the candidate set from the two indexes,
  (2) serves per Eq. (2) from x_t, (3) computes the subgradient Eq. (55)
  at y_t, (4) applies OMA + projection, (5) rounds to x_{t+1}.

* `AcaiCache` — an object wrapper over the same jitted step for the serving
  tier (repro.serve.semantic_cache) where requests arrive one by one.

Candidate sets: the union of kNN(r, local catalog) and kNN(r, remote
catalog) as returned by the two (approximate) indexes, deduplicated by
masking (duplicates get cost BIG and weight 0 so they are exactly neutral
in the augmented-catalog accounting — see repro.core.gain).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gain as gain_lib
from repro.core import oma as oma_lib
from repro.core import rounding as rounding_lib
from repro.core.costs import BIG_COST, pairwise_dissimilarity


class StepMetrics(NamedTuple):
    gain_int: jax.Array    # G(r_t, x_t) — what the system actually earns
    gain_frac: jax.Array   # G(r_t, y_t) — fractional gain (analysis)
    cost: jax.Array        # C(r_t, x_t)
    served_local: jax.Array  # how many of the k answers came from the cache
    fetched: jax.Array     # cache-update traffic (# objects fetched)
    occupancy: jax.Array   # sum x_t


class CacheState(NamedTuple):
    y: jax.Array  # (N,) fractional state
    x: jax.Array  # (N,) physical cache indicator
    t: jax.Array  # step counter
    key: jax.Array


def dedup_mask(ids: jax.Array, n: int) -> jax.Array:
    """valid[i] = ids[i] is a real id (< n) and its first occurrence."""
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    dup_sorted = jnp.concatenate(
        [jnp.zeros((1,), bool), sorted_ids[1:] == sorted_ids[:-1]]
    )
    dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)
    return (ids < n) & ~dup


def exact_candidate_fn(
    catalog: jax.Array, c_remote: int, c_local: int, metric: str = "sqeuclidean"
) -> Callable:
    """Candidate generator backed by exact (flat) search on both sides.

    Models *perfect-recall* indexes; the approximate variants live in
    repro.index.candidates (same signature) and plug in here.
    """
    n = catalog.shape[0]

    def fn(r: jax.Array, x: jax.Array):
        d_full = pairwise_dissimilarity(r[None, :], catalog, metric)[0]
        _, ids_remote = jax.lax.top_k(-d_full, c_remote)
        d_cached = jnp.where(x > 0.5, d_full, jnp.inf)
        _, ids_local = jax.lax.top_k(-d_cached, c_local)
        ids = jnp.concatenate([ids_remote, ids_local])
        valid = dedup_mask(ids, n)
        # a "local" candidate slot is only valid if that object is cached
        cached_ok = jnp.concatenate(
            [jnp.ones((c_remote,), bool), x[ids_local] > 0.5]
        )
        valid = valid & cached_ok
        d = jnp.where(valid, d_full[jnp.clip(ids, 0, n - 1)], BIG_COST)
        return ids, d, valid

    return fn


@dataclasses.dataclass(frozen=True)
class AcaiConfig:
    h: int                      # cache capacity (objects)
    k: int = 10                 # answers per request
    c_f: float = 1.0            # fetching cost
    c_remote: int = 64          # remote-index candidates (>= k!)
    c_local: int = 16           # local-index candidates
    oma: oma_lib.OMAConfig = dataclasses.field(default_factory=oma_lib.OMAConfig)


def _round_state(cfg: AcaiConfig, key, y_new, y_old, x_old, t):
    mode = cfg.oma.rounding
    if mode == "coupled":
        return rounding_lib.coupled_rounding(key, x_old, y_old, y_new)
    if mode == "independent":
        return rounding_lib.independent_rounding(key, y_new)
    if mode == "depround":
        # Re-round every M requests (Alg. 1 lines 7-9), freeze in between.
        return jax.lax.cond(
            (t % cfg.oma.round_every) == 0,
            lambda _: rounding_lib.depround(key, y_new),
            lambda _: x_old,
            None,
        )
    raise ValueError(mode)


def make_step(cfg: AcaiConfig, candidate_fn: Callable) -> Callable:
    """Build the jitted per-request step: (state, r) -> (state', metrics)."""

    def step(state: CacheState, r: jax.Array):
        key, k_round = jax.random.split(state.key)
        ids, d, valid = candidate_fn(r, state.x)
        x_cand = jnp.where(valid, state.x[jnp.clip(ids, None, state.x.shape[0] - 1)], 0.0)
        y_cand = jnp.where(valid, state.y[jnp.clip(ids, None, state.y.shape[0] - 1)], 0.0)

        served = gain_lib.serve(d, x_cand, cfg.k, cfg.c_f)
        gain_frac, g_cand = gain_lib.gain_and_subgradient(d, y_cand, cfg.k, cfg.c_f)

        g_full = (
            jnp.zeros_like(state.y)
            .at[jnp.clip(ids, None, state.y.shape[0] - 1)]
            .add(jnp.where(valid, g_cand, 0.0))
        )
        y_new = oma_lib.oma_update(state.y, g_full, cfg.h, cfg.oma)
        x_new = _round_state(cfg, k_round, y_new, state.y, state.x, state.t)

        metrics = StepMetrics(
            gain_int=served.gain,
            gain_frac=gain_frac,
            cost=served.cost,
            served_local=jnp.sum(served.from_cache.astype(jnp.int32)),
            fetched=rounding_lib.movement(x_new, state.x),
            occupancy=jnp.sum(x_new),
        )
        return CacheState(y_new, x_new, state.t + 1, key), metrics

    return step


def init_state(n: int, cfg: AcaiConfig, seed: int = 0, start: str = "uniform") -> CacheState:
    """start='uniform': y_1 = argmin Phi (Alg. 1 line 1); 'empty': cold cache."""
    y = oma_lib.uniform_state(n, cfg.h)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    if start == "uniform":
        x = rounding_lib.depround(k0, y)
    else:
        x = jnp.zeros((n,), jnp.float32)
    return CacheState(y=y, x=x, t=jnp.zeros((), jnp.int32), key=key)


def make_replay(cfg: AcaiConfig, candidate_fn: Callable) -> Callable:
    """Whole-trace replay: (state, requests (T,d)) -> (state', StepMetrics (T,))."""
    step = make_step(cfg, candidate_fn)

    @jax.jit
    def replay(state: CacheState, requests: jax.Array):
        return jax.lax.scan(step, state, requests)

    return replay


class AcaiCache:
    """Object API over the jitted step, for the online serving tier."""

    def __init__(self, catalog: jax.Array, cfg: AcaiConfig, candidate_fn=None, seed=0):
        self.cfg = cfg
        self.catalog = catalog
        fn = candidate_fn or exact_candidate_fn(catalog, cfg.c_remote, cfg.c_local)
        self._step = jax.jit(make_step(cfg, fn))
        self.state = init_state(catalog.shape[0], cfg, seed=seed)

    def serve_update(self, r: jax.Array) -> StepMetrics:
        self.state, metrics = self._step(self.state, r)
        return metrics

    @property
    def cached_ids(self):
        return jnp.nonzero(self.state.x > 0.5)[0]

    def normalized_gain(self, total_gain: float, t: int) -> float:
        """NAG of Eq. (11)."""
        return float(total_gain) / (self.cfg.k * self.cfg.c_f * max(t, 1))
