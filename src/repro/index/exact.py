"""Exact (flat) kNN index — the recall=1 reference and the local-catalog
workhorse (h <= a few thousand objects: a flat MXU scan beats any structure).

Mutable catalog (DESIGN.md §10): the embedding table is a capacity slab
with a tombstone mask — `add` appends (doubling growth), `remove` flips
the mask, `refresh` is a no-op (the masked scan is already exact over the
live rows).  The slab and mask are runtime jit arguments, so mutation at
fixed capacity never retraces the query.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.index.base import (MutableRows, arrays_bytes,
                              check_finite_queries, track_jit)
from repro.kernels import ops


@track_jit("flat_query")
@partial(jax.jit, static_argnames=("k", "kernel", "masked"))
def _flat_query(q: jax.Array, emb: jax.Array, valid: jax.Array, k: int,
                kernel: str, masked: bool):
    """(B, d) x (cap, d) slab -> (dists (B, k), ids (B, k)).

    `masked=False` (the fresh-build fast path: every slab row live) skips
    the tombstone mask entirely, so an unmutated index is bitwise the
    pre-mutable-catalog scan."""
    q = jnp.atleast_2d(q)
    v = valid if masked else None
    if kernel == "auto":
        return ops.topk_l2_auto(q, emb, k, v)
    if kernel == "pallas":
        return ops.topk_l2(q, emb, k, valid=v)
    d = ops.pairwise_l2_xla(q, emb)
    if v is not None:
        d = jnp.where(v[None, :], d, jnp.inf)
    neg, ids = jax.lax.top_k(-d, k)
    if v is not None:
        ids = jnp.where(jnp.isfinite(neg), ids, -1)
    return -neg, ids


class FlatIndex(MutableRows):
    """Brute-force index.  kernel='xla' uses the fused-XLA distance path,
    'pallas' the Pallas kernel (interpret-mode on CPU), 'auto' dispatches
    by backend (pallas on TPU) via ops.topk_l2_auto."""

    exact_distances = True  # query() distances need no re-rank

    def __init__(self, embeddings: jax.Array, kernel: str = "auto"):
        self._init_rows(embeddings)
        self.kernel = kernel

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings, self.valid)

    def query(self, q: jax.Array, k: int):
        check_finite_queries(q, "FlatIndex.query")
        # masked only once a row has ever died or the slab has spare
        # capacity — the fresh-build path stays bitwise identical
        masked = self._live != self.capacity
        return _flat_query(q, self.embeddings, self.valid, k, self.kernel,
                           masked)

    def __hash__(self):  # allow use as a static jit argument
        return id(self)

    def __eq__(self, other):
        return self is other
