"""Blocked pairwise squared-L2 Pallas TPU kernel.

The paper's hottest loop is the distance computation behind every kNN query
(both indexes, every request).  GPU FAISS implements it as a fused GEMM +
norm epilogue; the TPU adaptation below tiles the (Q, N) output into
MXU-aligned (BQ, BN) blocks, streams the (BQ, D) query tile and (BN, D)
catalog tile into VMEM, runs the contraction on the MXU in fp32, and fuses
the ||q||^2 / ||x||^2 epilogue in-register:

    out[i, j] = max(0, ||q_i||^2 - 2 q_i . x_j + ||x_j||^2)

Block shapes: BQ = BN = 128 (MXU native), full D per tile (embedding dims
here are <= 512, so a (128, 512) fp32 tile is 256 KiB — comfortably within
the ~16 MiB VMEM budget even with double buffering).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BN = 128


def _l2_kernel(q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)   # (BQ, D)
    x = x_ref[...].astype(jnp.float32)   # (BN, D)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                     # (BQ, BN) on the MXU
    qn = jnp.sum(q * q, axis=1, keepdims=True)      # (BQ, 1)
    xn = jnp.sum(x * x, axis=1)[None, :]            # (1, BN)
    o_ref[...] = jnp.maximum(qn - 2.0 * dots + xn, 0.0)


def pairwise_l2_pallas(
    q: jax.Array, x: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """(Q, D) x (N, D) -> (Q, N) squared L2.  Q, N must be multiples of the
    block sizes (the ops.py wrapper pads)."""
    qq, d = q.shape
    n, d2 = x.shape
    assert d == d2, (d, d2)
    assert qq % BQ == 0 and n % BN == 0, (qq, n)
    grid = (qq // BQ, n // BN)
    return pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BQ, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BQ, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qq, n), jnp.float32),
        interpret=interpret,
    )(q, x)
