"""Serving substrate: prefill/decode engine with KV/SSM caches, continuous
batching, and the AÇAI semantic cache tier."""

from repro.serve.engine import ServeEngine, generate, make_decode_step, make_prefill
from repro.serve.semantic_cache import SemanticCachedLM, embed_prompt

__all__ = ["SemanticCachedLM", "ServeEngine", "embed_prompt", "generate",
           "make_decode_step", "make_prefill"]
