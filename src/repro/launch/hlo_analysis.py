"""Loop-aware analysis of post-SPMD compiled HLO text.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, which
under-reports FLOPs/bytes by the trip count (layer scans, grad-accum scans,
flash-attention scans).  This module parses `compiled.as_text()` into its
computation graph, reads trip counts from the `known_trip_count`
backend_config on while ops (fallback: the loop-condition constant), and
accumulates:

  * dot FLOPs         2 * prod(result_dims) * prod(contracted lhs dims)
  * HBM byte traffic  output bytes of every materialising instruction in
                      top-level/loop-body computations (fusion internals
                      excluded — they live in registers/VMEM; the fusion's
                      own output is counted at the call site)
  * collective bytes  per class (all-reduce / all-gather / reduce-scatter /
                      all-to-all / collective-permute), per shard

All quantities are PER DEVICE (the SPMD module is the per-partition
program).  Validated in tests against hand-computed scan programs.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "u16": 2,
                "s16": 2, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_SIMPLE_TYPE_RE = re.compile(r"^([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")


def _split_instr(line: str):
    """'%n = TYPE opcode(...)' -> (name, type_str, opcode) — robust to
    tuple types containing /*index=k*/ comments and layout annotations."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rest = m.groups()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str, tail = rest[: i + 1], rest[i + 1:]
                    break
        else:
            return None
    else:
        mt = _SIMPLE_TYPE_RE.match(rest)
        if not mt:
            return None
        type_str, tail = mt.group(1), rest[mt.end():]
    mo = _OPCODE_RE.match(tail)
    if not mo:
        return None
    return name, type_str, mo.group(1)
_NO_TRAFFIC_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
                   "constant", "iota", "after-all", "partition-id",
                   "replica-id"}


def _dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _bytes_of_type(type_str: str) -> float:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return float(total)


@dataclass
class Computation:
    name: str
    symbols: dict = field(default_factory=dict)     # instr name -> type str
    whiles: list = field(default_factory=list)      # (body, cond, trip)
    calls: list = field(default_factory=list)
    dot_flops: float = 0.0
    out_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: {c: 0.0
                                                      for c in COLLECTIVES})
    coll_counts: dict = field(default_factory=lambda: {c: 0
                                                       for c in COLLECTIVES})
    max_constant: int = 1


def parse_hlo(text: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    body_lines: list[str] = []
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{") and ") -> " in line:
                is_entry = line.startswith("ENTRY")
                name_m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
                if name_m:
                    cur = Computation(name_m.group(1))
                    body_lines = []
                    if is_entry:
                        entry = cur.name
            continue
        if line == "}" or line.startswith("} "):
            _analyse(cur, body_lines)
            comps[cur.name] = cur
            cur = None
            continue
        body_lines.append(line)
    if cur is not None:
        _analyse(cur, body_lines)
        comps[cur.name] = cur
    return comps, entry


def _analyse(comp: Computation, lines: list[str]):
    # pass 1: symbol table
    for line in lines:
        m = _split_instr(line)
        if m:
            comp.symbols[m[0]] = m[1]

    for line in lines:
        m = _split_instr(line)
        if not m:
            continue
        name, type_str, opcode = m
        for c in re.findall(r"constant\((\d+)\)", line):
            comp.max_constant = max(comp.max_constant, int(c))
        if opcode == "while":
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            trip = None
            mt = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', line)
            if mt:
                trip = int(mt.group(1))
            if body and cond:
                comp.whiles.append((body.group(1), cond.group(1), trip))
            comp.out_bytes += _bytes_of_type(type_str)
            continue
        if "calls=" in line or "to_apply=" in line:
            for target in re.findall(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                     line):
                comp.calls.append(target)
        if opcode == "dot":
            args = line.split("dot(", 1)[1].split(")", 1)[0]
            opnames = re.findall(r"%([\w\.\-]+)", args)
            lhs_type = comp.symbols.get(opnames[0], "") if opnames else ""
            ldims = _dims(lhs_type)
            res = 1
            for d in _dims(type_str):
                res *= d
            contracted = 1
            mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            if mc and mc.group(1) and ldims:
                for i in mc.group(1).split(","):
                    contracted *= ldims[int(i)]
            comp.dot_flops += 2.0 * res * contracted
        coll_hit = False
        for coll in COLLECTIVES:
            if opcode in (coll, coll + "-start"):
                arg_types = [comp.symbols.get(o, "") for o in re.findall(
                    r"%([\w\.\-]+)", line.split("(", 1)[1])]
                total = sum(_bytes_of_type(t) for t in arg_types if t)
                if total == 0:
                    total = _bytes_of_type(type_str)
                comp.coll_bytes[coll] += total
                comp.coll_counts[coll] += 1
                coll_hit = True
                break
        if coll_hit:
            continue
        if opcode not in _NO_TRAFFIC_OPS:
            comp.out_bytes += _bytes_of_type(type_str)


@dataclass
class HloSummary:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = None
    coll_counts: dict = None
    loops: list = None


def summarize(text: str) -> HloSummary:
    comps, entry = parse_hlo(text)
    s = HloSummary(coll_bytes={c: 0.0 for c in COLLECTIVES},
                   coll_counts={c: 0 for c in COLLECTIVES}, loops=[])
    if entry is None:
        return s

    fusion_targets = set()
    for comp in comps.values():
        fusion_targets.update(comp.calls)

    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for body, cond, trip in comp.whiles:
            if trip is None:
                trip = comps[cond].max_constant if cond in comps else 1
            s.loops.append((body, trip))
            visit(body, m * trip)
        for callee in comp.calls:
            visit(callee, m)

    visit(entry, 1.0)

    for name, m in mult.items():
        comp = comps[name]
        s.flops += m * comp.dot_flops
        for c in COLLECTIVES:
            s.coll_bytes[c] += m * comp.coll_bytes[c]
            s.coll_counts[c] += int(round(m * comp.coll_counts[c]))
        if name not in fusion_targets or name == entry:
            s.bytes += m * comp.out_bytes
    return s
