import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_instance(rng, n=24, k=4, c_f=0.7, scale=2.0):
    d = (rng.random(n) * scale).astype(np.float32)
    y = rng.random(n).astype(np.float32)
    x = (rng.random(n) < 0.4).astype(np.float32)
    return d, y, x, k, c_f
