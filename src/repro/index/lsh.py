"""Random-hyperplane LSH index (multi-table, dense padded buckets)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class LSHIndex:
    exact_distances = True  # candidates scored with exact L2

    def __init__(self, embeddings, tables: int = 8, bits: int = 10,
                 cap: int | None = None, seed: int = 0):
        emb = np.asarray(embeddings, np.float32)
        n, d = emb.shape
        rng = np.random.default_rng(seed)
        self.planes = rng.normal(size=(tables, bits, d)).astype(np.float32)
        self.tables, self.bits = tables, bits
        nb = 2 ** bits
        sig = (np.einsum("tbd,nd->tnb", self.planes, emb) > 0)
        codes = (sig * (1 << np.arange(bits))[None, None, :]).sum(-1)  # (t, n)
        counts = np.stack([np.bincount(codes[t], minlength=nb)
                           for t in range(tables)])
        cap = int(counts.max()) if cap is None else cap
        table = np.full((tables, nb, cap), -1, np.int32)
        cursor = np.zeros((tables, nb), np.int32)
        for t in range(tables):
            for i, b in enumerate(codes[t]):
                c = cursor[t, b]
                if c < cap:
                    table[t, b, c] = i
                    cursor[t, b] = c + 1
        self.buckets = jnp.asarray(table)
        self.planes_j = jnp.asarray(self.planes)
        self.embeddings = jnp.asarray(emb)

    @partial(jax.jit, static_argnames=("self", "k"))
    def query(self, q: jax.Array, k: int):
        q = jnp.atleast_2d(q)
        b = q.shape[0]
        sig = jnp.einsum("tbd,nd->ntb", self.planes_j, q) > 0  # (B, t, bits)
        weights = (1 << jnp.arange(self.bits, dtype=jnp.int32))
        codes = jnp.sum(sig.astype(jnp.int32) * weights[None, None, :], -1)
        cand = jax.vmap(
            lambda c: self.buckets[jnp.arange(self.tables), c].reshape(-1)
        )(codes)                                                # (B, t*cap)
        valid = cand >= 0
        embs = self.embeddings[jnp.clip(cand, 0, None)]
        diff = embs - q[:, None, :]
        d = jnp.sum(diff * diff, axis=-1)
        d = jnp.where(valid, d, jnp.inf)
        # the same object sits in multiple tables' buckets: dedup per query
        order = jnp.argsort(cand, axis=1)
        sid = jnp.take_along_axis(cand, order, axis=1)
        dup_sorted = jnp.concatenate(
            [jnp.zeros((b, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1
        )
        dup = jnp.zeros_like(dup_sorted)
        dup = jax.vmap(lambda dd, oo, ds: dd.at[oo].set(ds))(dup, order, dup_sorted)
        d = jnp.where(dup, jnp.inf, d)
        neg, pos = jax.lax.top_k(-d, k)
        ids = jnp.take_along_axis(cand, pos, axis=1)
        return -neg, jnp.where(jnp.isfinite(neg), ids, -1)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
