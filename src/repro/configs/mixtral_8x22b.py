"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention
(arXiv:2401.04088).

56L d_model=6144 48H (GQA kv=8) expert d_ff=16384 vocab=32768.
SWA window 4096 -> sub-quadratic: long_500k decode runs with a
window-bounded ring-buffer KV cache.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=16384,
    sliding_window=4096,
    rope_theta=1e6,
    fsdp=True,
    optimizer="adafactor",
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=4, experts_per_token=2, moe_d_ff=128, sliding_window=32,
    capacity_factor=0.0,  # dropless for exact decode-consistency tests
    optimizer="adafactor",
)
