"""NSW graph index — the HNSW-style local-catalog index, TPU-adapted.

HNSW's pointer-chasing search is hostile to jit; we keep the navigable-
small-world *semantics* (greedy beam search over a neighbour graph, entry
point = medoid) but store the graph as a dense (N, degree) table and run a
fixed-width, fixed-step beam with masked gathers (DESIGN.md §3).  Recall is
controlled by (degree, beam, steps) just like HNSW's (M, efSearch).

Build: exact kNN graph + long-range shortcuts (random far edges), the
classic NSW construction, done once in numpy at setup.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def build_nsw_graph(emb: np.ndarray, degree: int = 16, shortcuts: int = 2,
                    seed: int = 0, chunk: int = 1024) -> np.ndarray:
    n = emb.shape[0]
    rng = np.random.default_rng(seed)
    knn = min(degree - shortcuts, n - 1)
    graph = np.empty((n, degree), np.int32)
    cn = (emb ** 2).sum(1)
    for s in range(0, n, chunk):
        q = emb[s:s + chunk]
        d = (q ** 2).sum(1)[:, None] - 2 * q @ emb.T + cn[None]
        np.fill_diagonal(d[:, s:s + q.shape[0]], np.inf)
        part = np.argpartition(d, knn, axis=1)[:, :knn]
        graph[s:s + chunk, :knn] = part
    graph[:, knn:] = rng.integers(0, n, (n, degree - knn))
    return graph


class NSWIndex:
    exact_distances = True  # candidates scored with exact L2

    def __init__(self, embeddings, degree: int = 16, beam: int = 32,
                 steps: int = 12, seed: int = 0):
        emb = np.asarray(embeddings, np.float32)
        self.embeddings = jnp.asarray(emb)
        self.graph = jnp.asarray(build_nsw_graph(emb, degree, seed=seed))
        self.beam, self.steps, self.degree = beam, steps, degree
        # entry points = catalog points nearest to k-means centroids: the
        # static-shape stand-in for HNSW's upper navigation layers — ensures
        # every density mode seeds the beam (DESIGN.md §3).
        from repro.index.kmeans import kmeans as _kmeans

        nentry = min(beam, emb.shape[0])
        cents, _ = _kmeans(jax.random.PRNGKey(seed), self.embeddings, nentry)
        d2 = ops.pairwise_l2_xla(cents, self.embeddings)
        self.entry_points = jnp.argmin(d2, axis=1).astype(jnp.int32)  # (nentry,)

    @partial(jax.jit, static_argnames=("self", "k"))
    def query(self, q: jax.Array, k: int):
        q = jnp.atleast_2d(q)

        def one(qv):
            beam_ids = jnp.resize(self.entry_points, (self.beam,))
            beam_d = jnp.sum(
                (self.embeddings[beam_ids] - qv[None, :]) ** 2, axis=-1
            )
            # mark duplicate seeds so they are not re-expanded
            dup0 = jnp.concatenate(
                [jnp.zeros((self.entry_points.shape[0],), bool),
                 jnp.ones((self.beam - self.entry_points.shape[0],), bool)]
            ) if self.beam > self.entry_points.shape[0] else jnp.zeros(
                (self.beam,), bool
            )
            beam_d = jnp.where(dup0, jnp.inf, beam_d)
            expanded = dup0

            def step(_, carry):
                ids, dist, exp = carry
                # pick the best unexpanded beam entry
                cand_d = jnp.where(exp, jnp.inf, dist)
                j = jnp.argmin(cand_d)
                exp = exp.at[j].set(True)
                nbrs = self.graph[ids[j]]                     # (degree,)
                nd = jnp.sum(
                    (self.embeddings[nbrs] - qv[None, :]) ** 2, axis=-1
                )
                all_ids = jnp.concatenate([ids, nbrs])
                all_d = jnp.concatenate([dist, nd])
                all_exp = jnp.concatenate(
                    [exp, jnp.zeros((self.degree,), bool)]
                )
                # dedup: keep the first occurrence of each id (sorted by id,
                # mark repeats with +inf) then take the best `beam`
                order = jnp.argsort(all_ids)
                sid = all_ids[order]
                dup = jnp.concatenate(
                    [jnp.zeros((1,), bool), sid[1:] == sid[:-1]]
                )
                dupmask = jnp.zeros_like(dup).at[order].set(dup)
                all_d = jnp.where(dupmask, jnp.inf, all_d)
                neg, pos = jax.lax.top_k(-all_d, self.beam)
                return all_ids[pos], -neg, all_exp[pos]

            ids, dist, _ = jax.lax.fori_loop(
                0, self.steps, step, (beam_ids, beam_d, expanded)
            )
            neg, pos = jax.lax.top_k(-dist, k)
            return -neg, ids[pos]

        d, ids = jax.vmap(one)(q)
        return d, ids

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
