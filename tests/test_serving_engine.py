"""Online serving engine (DESIGN.md §12): arrival determinism, batch-
former window semantics, admission-control accounting, interleaved
catalog mutation, and the bitwise fixed-window pin against
make_replay_batched."""

import numpy as np
import pytest

from repro.core import trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel
from repro.core.churn import warm_size
from repro.core.policy import StepMetrics, shed_only_metrics
from repro.core.trace import rolling_catalog_events
from repro.serve.arrivals import (ArrivalSpec, ClosedLoopSource,
                                  OpenLoopSource, arrival_times, make_source)
from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                               OnlineServingEngine, ServiceModel,
                               fixed_window_engine, serve_trace_online)

TINY = PA.TINY_POLICY_KWARGS


class StubPolicy:
    """Minimal CachePolicy: unit gain per request, records every batch it
    served (rids recovered from the request payloads) — isolates engine
    semantics from real policy math."""

    k, c_f, h = 1, 1.0, 4

    def __init__(self):
        self.batches = []

    def serve_update_batch(self, rs, ts=None) -> StepMetrics:
        rs = np.atleast_2d(rs)
        self.batches.append([int(r[0]) for r in rs])
        b = rs.shape[0]
        base = shed_only_metrics(b)
        return base._replace(gain_int=np.ones(b), shed=np.zeros(b, np.int32))


def id_requests(t: int, d: int = 4) -> np.ndarray:
    """Trace whose request vectors carry their own rid in component 0."""
    reqs = np.zeros((t, d), np.float32)
    reqs[:, 0] = np.arange(t)
    return reqs


def tiny_setup(n=256, d=16, t=128, seed=0):
    catalog, reqs, _ = trace.sift_like(n, d, t, seed=seed)
    return catalog, reqs, CostModel(c_f=1.0)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_open_loop_arrivals_deterministic_and_sane():
    for kind in ("poisson", "flash_crowd"):
        spec = ArrivalSpec(kind=kind, rate_rps=1000.0, seed=5)
        a, b = arrival_times(spec, 2000), arrival_times(spec, 2000)
        assert np.array_equal(a, b), kind  # same seed = same schedule
        assert (np.diff(a) >= 0).all()
        other = arrival_times(ArrivalSpec(kind=kind, rate_rps=1000.0,
                                          seed=6), 2000)
        assert not np.array_equal(a, other)
        # mean rate within 10% of the nominal 1 req/ms
        assert a[-1] / len(a) == pytest.approx(1.0, rel=0.1), kind


def test_flash_crowd_concentrates_in_bursts():
    spec = ArrivalSpec(kind="flash_crowd", rate_rps=1000.0,
                       burst_factor=8.0, burst_every_ms=250.0,
                       burst_width_ms=50.0, seed=5)
    t = arrival_times(spec, 5000)
    duty = spec.burst_width_ms / spec.burst_every_ms
    in_burst = ((t % spec.burst_every_ms) < spec.burst_width_ms).mean()
    # 8x modulation at 20% duty -> ~2/3 of arrivals inside bursts
    assert in_burst > 2 * duty


def test_arrival_prefix_stability():
    """The first T arrivals do not depend on how long the schedule is —
    a longer trace extends, never reshuffles, the prefix."""
    spec = ArrivalSpec(kind="poisson", rate_rps=500.0, seed=9)
    assert np.array_equal(arrival_times(spec, 100),
                          arrival_times(spec, 400)[:100])


def test_closed_loop_schedule_independent_of_drain_order():
    """Per-user think draws are keyed (seed, user, cycle): completing the
    first wave in different orders yields the same next-arrival times."""
    spec = ArrivalSpec(kind="closed_loop", users=3, think_ms=4.0, seed=2)

    def next_wave(order):
        src = ClosedLoopSource(spec, 9)
        first = [src.pop() for _ in range(3)]  # (time, rid) per user
        for idx in order:
            src.on_complete(first[idx][1], 10.0)
        out = {}
        while src.peek() is not None:
            tm, rid = src.pop()
            out[rid] = tm
        return out

    assert next_wave([0, 1, 2]) == next_wave([2, 0, 1])


def test_closed_loop_concurrency_bounded_by_users():
    """At most `users` requests are ever outstanding: the source never
    offers a new arrival for a user whose request is in flight."""
    spec = ArrivalSpec(kind="closed_loop", users=2, think_ms=1.0, seed=0)
    src = ClosedLoopSource(spec, 10)
    t1 = src.pop()
    t2 = src.pop()
    assert src.peek() is None  # both users busy -> nothing arrives
    src.on_complete(t1[1], 5.0)
    assert src.peek() is not None
    src.on_complete(t2[1], 5.0)
    served = 2
    while src.peek() is not None:
        tm, rid = src.pop()
        src.on_complete(rid, tm + 1.0)
        served += 1
    assert served == 10  # budget exhausted exactly


def test_closed_loop_double_complete_is_ignored():
    spec = ArrivalSpec(kind="closed_loop", users=1, think_ms=0.0, seed=0)
    src = ClosedLoopSource(spec, 5)
    _, rid = src.pop()
    src.on_complete(rid, 1.0)
    src.on_complete(rid, 2.0)  # stale duplicate: no second reschedule
    nxt = src.pop()
    assert nxt[0] == 1.0
    assert src._heap == []


def test_arrival_spec_validation():
    with pytest.raises(ValueError):
        ArrivalSpec(kind="uniform")
    with pytest.raises(ValueError):
        ArrivalSpec(kind="poisson", rate_rps=0.0)
    with pytest.raises(ValueError):
        ArrivalSpec(kind="flash_crowd", burst_width_ms=300.0,
                    burst_every_ms=250.0)
    with pytest.raises(ValueError):
        arrival_times(ArrivalSpec(kind="closed_loop"), 10)
    with pytest.raises(ValueError):
        OpenLoopSource(np.array([2.0, 1.0]))
    spec = ArrivalSpec(kind="poisson", rate_rps=10.0, seed=1)
    assert ArrivalSpec.from_dict(spec.to_dict()) == spec


# ---------------------------------------------------------------------------
# batch former window semantics
# ---------------------------------------------------------------------------

def run_stub(times, former, admission=AdmissionConfig(), t=None, **kw):
    pol = StubPolicy()
    t = len(times) if t is None else t
    eng = OnlineServingEngine(pol, former=former, admission=admission,
                              service=ServiceModel(base_ms=2.0,
                                                   per_request_ms=0.5))
    res = eng.run(id_requests(t), np.asarray(times, float), **kw)
    return pol, res


def test_size_trigger_dispatches_full_batches():
    # 8 simultaneous arrivals, window long enough to never fire
    pol, res = run_stub([0.0] * 8, BatchFormerConfig(max_batch=4,
                                                     max_wait_ms=100.0))
    assert pol.batches == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert res["batch_hist"] == {4: 2}
    # first batch forms at t=0; second waits for the server (4 ms)
    assert res["form_ms"][0] == 0.0
    assert res["form_ms"][4] == pytest.approx(4.0)


def test_timeout_trigger_fires_at_max_wait():
    # arrivals that never fill the batch: the window timer dispatches them
    pol, res = run_stub([0.0, 1.0, 50.0, 120.0],
                        BatchFormerConfig(max_batch=8, max_wait_ms=5.0))
    assert pol.batches[0] == [0, 1]
    assert res["form_ms"][0] == pytest.approx(5.0)  # oldest waited max_wait
    assert res["queue_ms"][1] == pytest.approx(4.0)
    # the mid-trace straggler waits out its own window (more arrivals
    # could still come); the final arrival leaves instantly via drain
    assert res["form_ms"][2] == pytest.approx(55.0)
    assert res["form_ms"][3] == pytest.approx(120.0)


def test_no_starvation_past_window_when_idle():
    """With arrivals too sparse to fill batches, every request forms
    within max_wait of its arrival (the server is never the bottleneck
    at this load): nothing starves waiting for co-batchers."""
    times = np.arange(20) * 40.0  # far apart vs service ~2.5ms
    pol, res = run_stub(times, BatchFormerConfig(max_batch=8,
                                                 max_wait_ms=6.0))
    assert (res["queue_ms"] <= 6.0 + 1e-9).all()
    assert res["batch_hist"] == {1: 20}


def test_drain_trigger_flushes_partial_tail():
    # pure size trigger (fixed window), 10 requests, batch 4: the last 2
    # can only leave via the drain trigger
    pol, res = run_stub([0.0] * 10, BatchFormerConfig(max_batch=4,
                                                      max_wait_ms=None))
    assert pol.batches == [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9]]
    assert res["served"] == 10 and res["shed_total"] == 0


def test_fifo_order_preserved():
    pol, _ = run_stub(np.sort(np.random.default_rng(0).uniform(0, 50, 16)),
                      BatchFormerConfig(max_batch=3, max_wait_ms=2.0))
    flat = [r for b in pol.batches for r in b]
    assert flat == sorted(flat)  # dispatch never reorders arrivals


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_queue_cap_sheds_overflow_and_books_metrics():
    # 10 simultaneous arrivals into a cap-4 queue, batch 2
    pol, res = run_stub([0.0] * 10,
                        BatchFormerConfig(max_batch=2, max_wait_ms=None),
                        AdmissionConfig(queue_cap=4))
    assert res["shed_total"] == 6
    assert set(r for r in res["shed_reasons"] if r) == {"queue_full"}
    shed = res["shed"]
    assert res["served"] + res["shed_total"] == res["requests"] == 10
    # shed rows: zero gain, shed counter 1; served rows: the stub's gain
    assert (res["gain"][shed] == 0).all()
    assert (res["gain"][~shed] == 1).all()
    m = res["metrics"]
    assert (np.asarray(m.shed)[shed] == 1).all()
    assert (np.asarray(m.shed)[~shed] == 0).all()
    # latency fields for shed rows collapse to the arrival instant
    assert (res["latency_ms"][shed] == 0).all()


def test_deadline_shedding_at_formation():
    """A deep backlog against a tight deadline: requests whose predicted
    completion overruns arrival+deadline are shed at formation, with the
    shed instant recorded."""
    pol, res = run_stub(
        [0.0] * 30,
        BatchFormerConfig(max_batch=2, max_wait_ms=None),
        AdmissionConfig(deadline_ms=10.0))
    assert res["shed_total"] > 0
    assert set(r for r in res["shed_reasons"] if r) == {"deadline"}
    shed = res["shed"]
    # served requests actually met the deadline the shed ones couldn't
    assert (res["latency_ms"][~shed] <= 10.0 + 1e-9).all()
    # every shed happened at its formation attempt, not at arrival
    assert (res["form_ms"][shed] >= 0).all()
    assert res["served"] + res["shed_total"] == 30


def test_goodput_counts_only_in_slo_served():
    _, res = run_stub([0.0] * 8,
                      BatchFormerConfig(max_batch=2, max_wait_ms=None),
                      slo_ms=7.0)
    # batches of 2 take 3ms each: completions at 3,6,9,12 -> 4 of 8 good
    assert res["goodput_slo"] == pytest.approx(0.5)
    assert res["served"] == 8


def test_config_validation():
    with pytest.raises(ValueError):
        BatchFormerConfig(max_batch=0)
    with pytest.raises(ValueError):
        BatchFormerConfig(max_wait_ms=-1.0)
    with pytest.raises(ValueError):
        AdmissionConfig(queue_cap=0)
    with pytest.raises(ValueError):
        AdmissionConfig(deadline_ms=0.0)
    with pytest.raises(ValueError):
        ServiceModel(base_ms=0.0, per_request_ms=0.0)


# ---------------------------------------------------------------------------
# bitwise offline equivalence (the drift pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arrival_kind", ["poisson", "flash_crowd"])
def test_fixed_window_bitwise_equals_offline_replay(arrival_kind):
    """The acceptance pin: fixed-window engine == make_replay_batched,
    bitwise, on per-request gain AND final policy state (y, x) — for any
    arrival process (FIFO size-B chunks are the offline partition)."""
    catalog, reqs, cm = tiny_setup()
    spec = PA.PolicySpec("acai", TINY["acai"])
    B = TINY["acai"]["batch"]
    pol_on = PA.build_policy(spec, catalog, cm, seed=0)
    pol_off = PA.build_policy(spec, catalog, cm, seed=0)
    arr = ArrivalSpec(kind=arrival_kind, rate_rps=3000.0, seed=4)
    res = fixed_window_engine(pol_on, B).run(reqs, arr)
    ref = pol_off.replay(reqs)
    assert np.array_equal(res["gain"], np.asarray(ref["gain"]))
    assert np.array_equal(np.asarray(pol_on.cache.state.y),
                          np.asarray(pol_off.cache.state.y))
    assert np.array_equal(np.asarray(pol_on.cache.state.x),
                          np.asarray(pol_off.cache.state.x))


def test_fixed_window_engine_run_is_reproducible():
    """Same seed, fresh policies: the whole result (timestamps included)
    replays identically — the virtual clock never reads wall time."""
    catalog, reqs, cm = tiny_setup(t=64)
    arr = ArrivalSpec(kind="poisson", rate_rps=2000.0, seed=3)
    outs = []
    for _ in range(2):
        pol = PA.build_policy(PA.PolicySpec("sim_lru", TINY["sim_lru"]),
                              catalog, cm, seed=0)
        outs.append(serve_trace_online(
            pol, reqs, arr,
            former=BatchFormerConfig(max_batch=4, max_wait_ms=3.0)))
    for key in ("gain", "arrival_ms", "form_ms", "done_ms", "latency_ms"):
        assert np.array_equal(outs[0][key], outs[1][key]), key


# ---------------------------------------------------------------------------
# every registered policy serves through the engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", PA.registered_policies())
def test_engine_conformance_all_policies(name):
    catalog, reqs, cm = tiny_setup(t=48)
    pol = PA.build_policy(PA.PolicySpec(name, TINY[name]), catalog, cm,
                          seed=0)
    res = serve_trace_online(
        pol, reqs, ArrivalSpec(kind="poisson", rate_rps=2500.0, seed=1),
        former=BatchFormerConfig(max_batch=8, max_wait_ms=4.0),
        admission=AdmissionConfig(queue_cap=32), slo_ms=25.0)
    assert res["requests"] == 48
    assert res["served"] + res["shed_total"] == 48
    served = ~res["shed"]
    # gains finite everywhere, and exactly zero on shed rows (cls_lru's
    # exploration serves can legitimately book negative gain)
    assert np.isfinite(res["gain"]).all()
    assert (res["gain"][~served] == 0).all()
    # timestamps monotone per request
    assert (res["form_ms"] >= res["arrival_ms"] - 1e-9).all()
    assert (res["done_ms"] >= res["form_ms"] - 1e-9).all()
    assert 0.0 <= res["goodput_slo"] <= 1.0


# ---------------------------------------------------------------------------
# interleaved catalog mutation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["acai", "sim_lru"])
def test_interleaved_churn_never_serves_removed_ids(name):
    """rolling_catalog events applied between formed batches: the run
    completes, every event fires, and no removed object survives in the
    policy's cache state (composes test_mutable_index's invariant with
    the live queue)."""
    catalog, reqs, cm = tiny_setup(n=256, t=96)
    events = rolling_catalog_events(256, 96, churn_rate=0.15, warm=0.5)
    assert events, "churn schedule unexpectedly empty"
    w = warm_size(256, 0.5)
    pol = PA.build_policy(PA.PolicySpec(name, TINY[name]), catalog[:w], cm,
                          seed=0)
    res = serve_trace_online(
        pol, reqs, ArrivalSpec(kind="poisson", rate_rps=1500.0, seed=6),
        former=BatchFormerConfig(max_batch=8, max_wait_ms=4.0),
        catalog=catalog, events=events)
    assert res["events_applied"] == len(events)
    assert res["served"] == 96 and res["shed_total"] == 0
    removed = np.concatenate([np.asarray(ev[2]) for ev in events])
    if name == "acai":
        y = np.asarray(pol.cache.state.y)
        x = np.asarray(pol.cache.state.x)
        assert float(np.abs(y[removed]).sum()) == 0.0
        assert float(np.abs(x[removed]).sum()) == 0.0
    else:
        cached = set(pol.policy.cached_object_ids().tolist())
        assert not set(removed.tolist()) & cached


def test_insert_events_require_catalog():
    catalog, reqs, cm = tiny_setup(t=32)
    pol = StubPolicy()
    eng = OnlineServingEngine(pol)
    with pytest.raises(ValueError, match="catalog"):
        eng.run(id_requests(32), np.zeros(32),
                events=[(4, np.array([1]), np.array([], np.int64))])


def test_tail_events_drain_after_last_batch():
    """Events scheduled past the final dispatched request still fire
    (the replay_with_churn tail-drain rule)."""
    catalog, reqs, cm = tiny_setup(n=64, t=16)
    pol = PA.build_policy(PA.PolicySpec("sim_lru", TINY["sim_lru"]),
                          catalog[:32], cm, seed=0)
    events = [(15, np.array([32]), np.array([0])),
              (500, np.array([33]), np.array([1]))]  # beyond the trace
    res = serve_trace_online(pol, reqs, np.zeros(16), catalog=catalog,
                             events=events)
    assert res["events_applied"] == 2
    assert not {0, 1} & set(pol.policy.cached_object_ids().tolist())
