"""Lloyd k-means in JAX (used to train IVF coarse quantizers + PQ codebooks)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key: jax.Array, data: jax.Array, k: int, iters: int = 12):
    """Returns (centroids (k, d), assignments (n,))."""
    n = data.shape[0]
    init_idx = jax.random.choice(key, n, shape=(k,), replace=False)
    cents = data[init_idx]

    def body(_, cents):
        d = ops.pairwise_l2_xla(data, cents)  # (n, k)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=data.dtype)  # (n, k)
        sums = onehot.T @ data                                 # (k, d)
        counts = jnp.sum(onehot, axis=0)[:, None]
        new = sums / jnp.maximum(counts, 1.0)
        # keep the old centroid for empty clusters
        return jnp.where(counts > 0, new, cents)

    cents = jax.lax.fori_loop(0, iters, body, cents)
    assign = jnp.argmin(ops.pairwise_l2_xla(data, cents), axis=1)
    return cents, assign
