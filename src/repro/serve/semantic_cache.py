"""Semantic cache: AÇAI as the retrieval tier in front of LM inference.

The deployment the paper motivates (refs [3]-[6], [20], [49]): an edge
server receives prompts, embeds them, and runs a similarity search over a
catalog of previously computed results.  AÇAI decides per-object whether
to serve from the local store (cost = dissimilarity only) or compute /
fetch remotely (cost = dissimilarity + c_f, where c_f is calibrated to the
inference cost), and updates the local store with OMA.

`embed_prompt` derives the request embedding from the LM's own token
embedding table (mean pooled + normalised) — no extra encoder needed.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import oma as oma_lib
from repro.core import policy as acai
from repro.models.config import ModelConfig


def embed_prompt(params, tokens: jax.Array) -> jax.Array:
    """(S,) int32 -> (d,) normalised mean-pooled embedding."""
    e = params["embed"][tokens].astype(jnp.float32)
    v = jnp.mean(e, axis=0)
    return v / jnp.maximum(jnp.linalg.norm(v), 1e-6)


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    served_local: int = 0
    generated: int = 0
    total_gain: float = 0.0


class SemanticCachedLM:
    """AÇAI similarity cache wrapping a generate() callable."""

    def __init__(self, params, cfg: ModelConfig, catalog_embs: jax.Array,
                 catalog_payloads: list, generate_fn: Callable,
                 h: int = 64, k: int = 4, c_f: Optional[float] = None,
                 eta: Optional[float] = None, seed: int = 0, mesh=None,
                 index_spec=None):
        from repro.core.costs import calibrate_fetch_cost

        self.params, self.cfg = params, cfg
        self.payloads = catalog_payloads
        self.generate_fn = generate_fn
        c_f = c_f if c_f is not None else float(
            calibrate_fetch_cost(catalog_embs, kth=min(50, len(catalog_payloads) - 1)))
        # index_spec: remote-catalog index selection (repro.index.base
        # IndexSpec; also accepts the flat-dict / backend-name forms, with
        # "exact" resolving to None) — one knob from the CLI down to the
        # candidate generator; None = exact candidates.
        from repro.index.base import resolve_spec

        index_spec = resolve_spec(index_spec)
        acfg = acai.AcaiConfig(
            h=h, k=k, c_f=c_f, c_remote=max(4 * k, 16), c_local=max(k, 8),
            oma=oma_lib.OMAConfig(eta=eta if eta is not None else 0.05 / c_f),
            index=index_spec)
        # mesh: shard the catalog scan + OMA over the mesh's `model` axis
        # (repro.core.distributed.make_step_sharded) — the multi-device
        # serving path; None = the single-device batched pipeline.
        self.cache = acai.AcaiCache(catalog_embs, acfg, seed=seed, mesh=mesh)
        self.stats = ServeStats()
        self._embed_batch = jax.jit(jax.vmap(embed_prompt, in_axes=(None, 0)))

    def query(self, prompt_tokens: jax.Array):
        """Returns (payloads, metrics): the k most similar cached results,
        each tagged local/remote; remote ones trigger generation."""
        r = embed_prompt(self.params, prompt_tokens)
        m = self.cache.serve_update(r)
        self.stats.requests += 1
        self.stats.served_local += int(m.served_local)
        self.stats.total_gain += float(m.gain_int)
        if int(m.served_local) < self.cache.cfg.k:
            # at least one object must be produced/fetched remotely
            self.stats.generated += 1
            _ = self.generate_fn(prompt_tokens)
        return m

    def query_batch(self, prompts: list):
        """Batched entry point: embeds a whole request batch, runs one
        AÇAI mini-batch step (single OMA + rounding update, DESIGN.md §6)
        and triggers generation for each request not fully served locally.
        Returns StepMetrics with a (B,) leading axis."""
        if len({p.shape[0] for p in prompts}) == 1:
            # equal-length prompts: one vmapped embed dispatch
            rs = self._embed_batch(self.params, jnp.stack(prompts))
        else:
            rs = jnp.stack([embed_prompt(self.params, p) for p in prompts])
        m = self.cache.serve_update_batch(rs)
        served = [int(s) for s in m.served_local]
        self.stats.requests += len(prompts)
        self.stats.served_local += sum(served)
        self.stats.total_gain += float(jnp.sum(m.gain_int))
        for p, s in zip(prompts, served):
            if s < self.cache.cfg.k:
                self.stats.generated += 1
                _ = self.generate_fn(p)
        return m

    @property
    def nag(self) -> float:
        return self.cache.normalized_gain(self.stats.total_gain,
                                          self.stats.requests)
