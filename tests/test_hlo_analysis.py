"""Loop-aware HLO analyzer vs hand-computed programs (dry-run substrate)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import summarize

SDS = jax.ShapeDtypeStruct((64, 64), jnp.float32)


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    s = summarize(_text(lambda a, b: a @ b, SDS, SDS))
    assert s.flops == 2 * 64 ** 3


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=9)[0]
    s = summarize(_text(f, SDS, SDS))
    assert s.flops == 9 * 2 * 64 ** 3
    assert any(trip == 9 for _, trip in s.loops)


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            inner = jax.lax.scan(lambda ci, _: (ci @ w, None), c, None,
                                 length=3)[0]
            return inner, None
        return jax.lax.scan(outer, x, None, length=5)[0]
    s = summarize(_text(f, SDS, SDS))
    assert s.flops == 15 * 2 * 64 ** 3


def test_bytes_scale_with_loop():
    def f(x):
        return jax.lax.scan(lambda c, _: (jnp.tanh(c), None), x, None,
                            length=4)[0]
    s4 = summarize(_text(f, SDS))

    def f8(x):
        return jax.lax.scan(lambda c, _: (jnp.tanh(c), None), x, None,
                            length=8)[0]
    s8 = summarize(_text(f8, SDS))
    assert s8.bytes > s4.bytes


def test_remat_increases_flops():
    w = SDS

    def loss(p, x):
        h = x
        for _ in range(3):
            h = jnp.tanh(h @ p)
        return jnp.sum(h)

    plain = summarize(_text(jax.grad(loss), SDS, SDS))
    remat = summarize(_text(jax.grad(jax.checkpoint(loss)), SDS, SDS))
    assert remat.flops >= plain.flops
