"""Online Mirror Ascent — Algorithm 1 of the paper.

The full AÇAI update for one request:
  1. receive subgradient g_t of G(r_t, y_t)            (repro.core.gain)
  2. dual ascent step through the mirror map            (repro.core.mirror)
  3. Bregman projection onto conv(X) = capped simplex   (repro.core.projection)
  4. every M requests: randomised rounding to x in X    (repro.core.rounding)

The learning-rate default follows Theorem IV.1's optimum
  eta* = (1/L) sqrt(2 D / (h T)),   L = c_d^k + c_f,   D = h log(N/h).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import mirror as mirror_maps
from repro.core import projection

Y_FLOOR = 1e-12  # keeps y in the (open) domain of the entropy map


@dataclasses.dataclass(frozen=True)
class OMAConfig:
    eta: float = 1e-2
    mirror: str = mirror_maps.NEGENTROPY
    rounding: str = "coupled"  # 'depround' | 'coupled' | 'independent'
    round_every: int = 1       # the paper's M
    projection_topk: int = 0   # 0 = exact full sort; >0 = accelerated top-A


def theoretical_eta(c_dk: float, c_f: float, h: int, n: int, horizon: int) -> float:
    """eta* of Theorem IV.1 (App. E, Eq. (78))."""
    big_l = c_dk + c_f
    big_d = h * math.log(max(n / max(h, 1), 1.0 + 1e-9))
    return (1.0 / big_l) * math.sqrt(2.0 * big_d / (max(h, 1) * max(horizon, 1)))


def project(z: jax.Array, h, cfg: OMAConfig) -> jax.Array:
    if cfg.mirror == mirror_maps.NEGENTROPY:
        if cfg.projection_topk:
            y = projection.capped_simplex_negentropy_topk(z, h, cfg.projection_topk)
        else:
            y = projection.capped_simplex_negentropy(z, h)
        return jnp.clip(y, Y_FLOOR, 1.0)
    y = projection.capped_simplex_euclidean(z, h)
    return jnp.clip(y, 0.0, 1.0)


def oma_update(y: jax.Array, g: jax.Array, h, cfg: OMAConfig) -> jax.Array:
    """One OMA step (lines 3-6 of Algorithm 1)."""
    z = mirror_maps.dual_ascent_step(y, g, cfg.eta, cfg.mirror)
    return project(z, h, cfg)


def uniform_state(n: int, h: int, dtype=jnp.float32) -> jax.Array:
    """y_1 = argmin_{conv(X)} Phi(y) = (h/N, ..., h/N)  (Lemma 8)."""
    return jnp.full((n,), h / n, dtype)
