"""Corollary IV.1.1: AÇAI as an offline (1-1/e)-approximation solver.

Run OMA over a trace, average the fractional iterates y_t, round the
average with DepRound, and compare the static allocation's gain against
(a) the popularity heuristic and (b) AÇAI's own online gain — the averaged
iterate should be a near-(1-1/e)-optimal *static* configuration.

The workload comes from the TraceSpec registry and the OMA knobs from an
AcaiConfig exactly as `PolicySpec("acai", ...)` would build it (this
example intentionally stays one level below `build_policy` to expose the
averaged iterate, which the CachePolicy step contract does not surface).

  PYTHONPATH=src python examples/offline_allocation.py
  PYTHONPATH=src python examples/offline_allocation.py --tiny
"""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TraceSpec, build_trace
from repro.core import gain as G
from repro.core import policy, rounding
from repro.core.costs import calibrate_fetch_cost
from repro.core.policy_api import PolicySpec, acai_config_from_spec


def static_gain(catalog, x, requests, k, c_f):
    vals = []
    for r in requests[::10]:
        d = jnp.sum((catalog - jnp.array(r)[None, :]) ** 2, -1)
        vals.append(float(G.gain_value(d, jnp.array(x), k, c_f)))
    return float(np.mean(vals))


def main(tiny: bool = False):
    n, t, h, k = (400, 400, 24, 4) if tiny else (3000, 4000, 100, 10)
    catalog_np, requests, _ = build_trace(
        TraceSpec("sift_like", {"n": n, "d": 32, "t": t, "seed": 0}))
    catalog = jnp.array(catalog_np)
    c_f = float(calibrate_fetch_cost(catalog, kth=min(50, n - 1)))

    # the serialized spec form of the same configuration (c_f rides in the
    # spec, so the record is self-contained)
    spec = PolicySpec("acai", {"h": h, "k": k, "c_f": c_f})
    cfg = acai_config_from_spec(spec)
    fn = policy.exact_candidate_fn(catalog, cfg.c_remote, cfg.c_local)
    step = policy.make_step(cfg, fn)

    # replay while accumulating the average fractional state y_bar
    @jax.jit
    def replay(state, reqs):
        def body(carry, r):
            st, ysum = carry
            st, m = step(st, r)
            return (st, ysum + st.y), m.gain_int
        (st, ysum), gains = jax.lax.scan(
            body, (state, jnp.zeros_like(state.y)), reqs)
        return st, ysum / reqs.shape[0], gains

    state = policy.init_state(n, cfg)
    state, y_bar, gains = replay(state, jnp.array(requests))
    online_avg = float(np.mean(np.array(gains)))

    # round the averaged iterate -> static allocation (Corollary IV.1.1)
    x_bar = rounding.depround(jax.random.PRNGKey(1), y_bar)
    g_acai = static_gain(catalog, x_bar, requests, k, c_f)

    # popularity heuristic comparator
    near = np.array(jnp.argmin(
        jnp.sum((catalog[None, ::1] - jnp.array(requests[:500, None])) ** 2,
                -1), axis=1))
    top = np.bincount(near, minlength=n).argsort()[::-1][:h]
    x_pop = np.zeros(n, np.float32)
    x_pop[top] = 1.0
    g_pop = static_gain(catalog, jnp.array(x_pop), requests, k, c_f)

    norm = k * c_f
    print(f"policy spec: {spec.to_dict()}")
    print(f"static allocation from averaged OMA iterate: {g_acai / norm:.4f}")
    print(f"static popularity-top-h heuristic:           {g_pop / norm:.4f}")
    print(f"AÇAI online average gain:                    {online_avg / norm:.4f}")
    print(f"(1-1/e) reference factor:                    {1 - 1 / np.e:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-fast sizes (CI smoke)")
    main(ap.parse_args().tiny)
