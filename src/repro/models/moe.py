"""Mixture-of-Experts layer: top-k routing, capacity-bounded scatter
dispatch, expert-parallel sharding over the `model` mesh axis.

Covers mixtral (8e top-2), jamba (16e top-2) and deepseek-v3 (1 shared +
256 routed top-8, sigmoid routing à la DeepSeek).  Dispatch uses the
scatter/gather formulation: an (E, C, d) expert buffer — NOT the dense
(T, E, C) GShard one-hot tensor, which is infeasible at 256 experts — so
memory is O(E*C*d) and the SPMD partitioner lowers the token->expert
exchange to all-to-alls when E is sharded.

Load-balancing auxiliary loss follows Switch (fraction-dot-probability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, init_mlp, mlp
from repro.sharding.ctx import annotate, _current as _ctx


def init_moe(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    e_ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 3 + cfg.n_shared_experts)
    d = cfg.d_model
    e = cfg.n_experts
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "wi": (jax.random.normal(ks[1], (e, d, e_ff)) / jnp.sqrt(d)).astype(dt),
        "wg": (jax.random.normal(ks[2], (e, d, e_ff)) / jnp.sqrt(d)).astype(dt),
        "wo": (jax.random.normal(ks[0], (e, e_ff, d)) / jnp.sqrt(e_ff)).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[3], cfg, e_ff * cfg.n_shared_experts)
    return p


def _moe_two_stage(p, xf, cfg: ModelConfig):
    """§Perf variant: per-data-shard dispatch (cfg.moe_dp blocks).

    Token->buffer scatter positions are computed WITHIN each data shard, so
    the scatter is shard-local (no cross-shard indices) and the expert
    einsum runs on a (dp x E) grid that matches the (data x model) mesh
    exactly: the only surviving communication is the output-combine
    all-reduce over `model` — the same pattern as a TP FFN — instead of
    the partitioner's last-resort replicate-and-all-reduce of the whole
    (E, C, d) buffer (~1600x less collective traffic on deepseek-v3)."""
    t, d = xf.shape
    dp = cfg.moe_dp
    e, k = cfg.n_experts, cfg.experts_per_token
    tl = t // dp
    xb = annotate(xf.reshape(dp, tl, d), ("batch", None, None))

    logits = xb.astype(jnp.float32) @ p["router"]            # (dp, tl, E)
    if cfg.attn_type == "mla":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(scores, k)                     # (dp, tl, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    if cfg.capacity_factor <= 0:
        capl = tl * k
    else:
        capl = int(max((tl * k * cfg.capacity_factor) // e, min(tl, 8)))

    flat_e = eidx.reshape(dp, tl * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (dp, tlk, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, -1) - 1
    keep = pos < capl
    tok = jnp.repeat(jnp.arange(tl), k)

    def scatter_block(xfb, fe, po, kp):
        buf = jnp.zeros((e, capl, d), xfb.dtype)
        return buf.at[fe, jnp.clip(po, 0, capl - 1)].add(
            jnp.where(kp[:, None], xfb[tok], 0))

    buf = jax.vmap(scatter_block)(xb, flat_e, pos, keep)      # (dp,E,C,d)
    buf = annotate(buf, ("batch", "model", None, None))

    hidden = jax.nn.silu(jnp.einsum("pecd,edf->pecf", buf, p["wg"])) * \
        jnp.einsum("pecd,edf->pecf", buf, p["wi"])
    hidden = annotate(hidden, ("batch", "model", None, None))
    out_buf = jnp.einsum("pecf,efd->pecd", hidden, p["wo"])
    out_buf = annotate(out_buf, ("batch", "model", None, None))

    def combine_block(ob, fe, po, kp, gv):
        # gather per k-slot and pre-sum over slots: the cross-shard
        # all-reduce then carries (tl, d) once instead of (tl*k, d) —
        # XLA's all-reduce reassociation merges the k partial sums.
        fe_s = fe.reshape(tl, k)
        po_s = jnp.clip(po, 0, capl - 1).reshape(tl, k)
        kp_s = kp.reshape(tl, k)
        acc = jnp.zeros((tl, d), ob.dtype)
        for j in range(k):
            g_j = ob[fe_s[:, j], po_s[:, j]]                  # (tl, d)
            g_j = jnp.where(kp_s[:, j][:, None], g_j, 0)
            acc = acc + g_j * gv[:, j][:, None].astype(ob.dtype)
        return acc

    out = jax.vmap(combine_block)(out_buf, flat_e, pos, keep, gate)
    out = annotate(out, ("batch", None, None)).reshape(t, d)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)

    probs = jax.nn.softmax(logits.reshape(t, e), axis=-1)
    f = jnp.mean(jax.nn.one_hot(eidx.reshape(t, k)[:, 0], e,
                                dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(f * jnp.mean(probs, axis=0))
    return out, aux


def _moe_shard_map(p, xf, cfg: ModelConfig):
    """§Perf final MoE form: explicit shard_map, expert-weights-stationary.

    Activations are data-sharded and model-REPLICATED (the transformer's
    activation layout), expert weights are E-sharded over `model`: so no
    token ever needs to move — each chip runs the slots owned by its local
    experts for its local tokens and a single (T_local, d) bf16 psum over
    `model` combines.  Collective cost per layer = one TP-style all-reduce
    (+ the usual FSDP weight all-gathers) instead of the partitioner's
    replicated-buffer all-reduces: measured 48x less collective traffic on
    deepseek-v3 train_4k (EXPERIMENTS.md §Perf).

    Capacity is enforced per (data-shard, local-expert) — a strictly more
    local variant of the capacity constraint (noted in DESIGN.md §5)."""
    from jax.sharding import PartitionSpec as P

    ctx = _ctx()
    mesh = ctx["mesh"]
    batch_ax = ctx["batch_axes"]
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get("model", 1)
    n_batch = 1
    for a in batch_ax:
        n_batch *= sizes.get(a, 1)
    e_loc = e // n_model
    tl = t // n_batch
    if cfg.capacity_factor <= 0:
        capl = tl * k
    else:
        capl = int(max((tl * k * cfg.capacity_factor) // e, min(tl, 8)))
    fsdp = cfg.fsdp

    def local_fn(xl, router, wi, wg, wo):
        # xl (tl, d); router (d/F, E); wi/wg (e_loc, d/F, f); wo (e_loc, f, d/F)
        if fsdp:
            router = jax.lax.all_gather(router, "data", axis=0, tiled=True)
            wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        my = jax.lax.axis_index("model")
        logits = xl.astype(jnp.float32) @ router            # (tl, E)
        scores = (jax.nn.sigmoid(logits) if cfg.attn_type == "mla"
                  else jax.nn.softmax(logits, axis=-1))
        gate, eidx = jax.lax.top_k(scores, k)               # (tl, k)
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

        flat_e = eidx.reshape(-1)
        local_e = flat_e - my * e_loc
        mine = (local_e >= 0) & (local_e < e_loc)
        safe_e = jnp.clip(local_e, 0, e_loc - 1)
        onehot = jax.nn.one_hot(safe_e, e_loc, dtype=jnp.int32) * \
            mine[:, None].astype(jnp.int32)
        pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, -1) - 1
        keep = mine & (pos >= 0) & (pos < capl)
        tok = jnp.repeat(jnp.arange(tl), k)

        buf = jnp.zeros((e_loc, capl, d), xl.dtype)
        buf = buf.at[safe_e, jnp.clip(pos, 0, capl - 1)].add(
            jnp.where(keep[:, None], xl[tok], 0))

        hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wi)
        out_buf = jnp.einsum("ecf,efd->ecd", hidden, wo)     # (e_loc,C,d)

        gathered = out_buf[safe_e, jnp.clip(pos, 0, capl - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0)
        weighted = gathered * gate.reshape(-1)[:, None].astype(xl.dtype)
        out_l = jnp.zeros((tl, d), xl.dtype).at[tok].add(weighted)
        out_l = jax.lax.psum(out_l, "model")                 # the ONLY comm

        # switch aux loss (per shard; psum-averaged outside)
        probs = jax.nn.softmax(logits, axis=-1)
        f = jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32), 0)
        aux = e * jnp.sum(f * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(aux, batch_ax)
        return out_l, aux

    from repro.compat import shard_map

    fs = "data" if fsdp else None
    mapped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(batch_ax, None), P(fs, None),
                  P("model", fs, None), P("model", fs, None),
                  P("model", None, fs)),
        out_specs=(P(batch_ax, None), P()),
        check_vma=False,
    )
    out, aux = mapped(xf, p["router"], p["wi"], p["wg"], p["wo"])
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)
    return out, aux


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(t, d)

    if cfg.moe_dp > 1 and t % cfg.moe_dp == 0:
        ctx = _ctx()
        if (ctx is not None and e % dict(zip(
                ctx["mesh"].axis_names, ctx["mesh"].devices.shape)).get(
                    "model", 1) == 0):
            out, aux = _moe_shard_map(p, xf, cfg)
            return out.reshape(b, s, d), aux
        out, aux = _moe_two_stage(p, xf, cfg)
        return out.reshape(b, s, d), aux

    logits = xf.astype(jnp.float32) @ p["router"]          # (T, E)
    if cfg.attn_type == "mla":  # deepseek-style sigmoid scoring
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(scores, k)        # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # capacity per expert; capacity_factor <= 0 = dropless (cap = t*k, for
    # tests/small models); otherwise floor at min(t, 8) so decode batches
    # (t = B tokens) never round to a 1-token capacity.
    if cfg.capacity_factor <= 0:
        cap = t * k
    else:
        cap = int(max((t * k * cfg.capacity_factor) // e, min(t, 8)))

    # position of each (token, slot) inside its expert's buffer
    flat_expert = expert_idx.reshape(-1)                    # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot          # running count
    position = jnp.sum(pos_in_e, axis=-1) - 1               # (T*k,)
    keep = position < cap

    # scatter tokens into (E, C, d) buffers; the buffer shards E over
    # 'model' and capacity over 'batch' so the dispatch spike stays
    # O(E/tp * C/dp * d) per chip (the all-to-all happens here).
    tok_of_slot = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_expert, jnp.clip(position, 0, cap - 1)].add(
        jnp.where(keep[:, None], xf[tok_of_slot], 0))
    buf = annotate(buf, ("model", "batch", None))

    # expert FFN (einsum over stacked weights; E shards over `model`)
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    hidden = annotate(hidden, ("model", "batch", None))
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, p["wo"])   # (E, C, d)
    out_buf = annotate(out_buf, ("model", "batch", None))

    # gather back + combine with gate weights
    gathered = out_buf[flat_expert, jnp.clip(position, 0, cap - 1)]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_of_slot].add(weighted)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)

    # Switch load-balance loss: E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    f = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * pbar)
    return out.reshape(b, s, d), aux
