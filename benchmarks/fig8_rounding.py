"""Fig. 8/9 (App. G): rounding schemes — update cost vs reactivity.

Thin wrapper over the config-driven experiment harness: the whole
protocol (traces, policy sweeps, shared oracle, summary lines) lives in
the named grid `benchmarks.experiments.GRIDS["fig8"]`.
"""

from __future__ import annotations

from benchmarks import common, experiments


def main(full: bool = False, kind: str = "amazon") -> list:
    return experiments.run_named("fig8", full=full, trace=kind)


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
