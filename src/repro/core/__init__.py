"""AÇAI core — the paper's contribution as composable JAX modules.

  costs       — dissimilarity/fetching cost model, augmented catalog (Sec. II/IV-A)
  gain        — service cost Eq. (5), caching gain Eq. (7), subgradient Eq. (55)
  mirror      — mirror maps (negative entropy / Euclidean)
  projection  — Bregman projections onto the capped simplex (Sec. IV-F)
  oma         — Online Mirror Ascent, Algorithm 1
  rounding    — DepRound + CoupledRounding (App. F)
  policy      — AcaiCache: serving (Eq. 2) + state updates, trace replay
  baselines   — LRU, SIM-LRU, CLS-LRU, RND-LRU, QCACHE (Sec. II/V)
  trace       — SIFT-like / Amazon-like synthetic traces (Sec. V-A)
  ref         — pure-numpy oracles for every equation (test-only)
"""

from repro.core.costs import CostModel, calibrate_fetch_cost, pairwise_dissimilarity
from repro.core.gain import gain_and_subgradient, gain_value, serve
from repro.core.oma import OMAConfig, oma_update, theoretical_eta, uniform_state
from repro.core.policy import (AcaiCache, AcaiConfig, init_state, make_replay,
                               make_replay_batched, make_step,
                               make_step_batched)
from repro.core.rounding import coupled_rounding, depround, independent_rounding

__all__ = [
    "AcaiCache",
    "AcaiConfig",
    "CostModel",
    "OMAConfig",
    "calibrate_fetch_cost",
    "coupled_rounding",
    "depround",
    "gain_and_subgradient",
    "gain_value",
    "independent_rounding",
    "init_state",
    "make_replay",
    "make_replay_batched",
    "make_step",
    "make_step_batched",
    "oma_update",
    "pairwise_dissimilarity",
    "serve",
    "theoretical_eta",
    "uniform_state",
]
