"""Property-based (hypothesis) tests on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
import hypothesis.extra.numpy as hnp  # noqa: E402

from repro.core import gain as G
from repro.core import projection as P


def _dists(n):
    return hnp.arrays(
        np.float32, (n,),
        elements=st.floats(0.0, 10.0, width=32, allow_nan=False),
    )


def _fracs(n):
    return hnp.arrays(
        np.float32, (n,),
        elements=st.floats(0.0, 1.0, width=32, allow_nan=False),
    )


@settings(max_examples=60, deadline=None)
@given(d=_dists(20), y=_fracs(20), z=_fracs(20),
       lam=st.floats(0.0, 1.0, width=32),
       k=st.integers(1, 5), c_f=st.floats(0.05, 3.0))
def test_gain_concave_along_segments(d, y, z, lam, k, c_f):
    """G(lam y + (1-lam) z) >= lam G(y) + (1-lam) G(z)  (Sec. IV-D)."""
    dj = jnp.array(d)
    gy = float(G.gain_value(dj, jnp.array(y), k, c_f))
    gz = float(G.gain_value(dj, jnp.array(z), k, c_f))
    mid = lam * y + (1 - lam) * z
    gm = float(G.gain_value(dj, jnp.array(mid), k, c_f))
    assert gm >= lam * gy + (1 - lam) * gz - 1e-3 * (1 + abs(gm))


@settings(max_examples=60, deadline=None)
@given(d=_dists(20), y=_fracs(20), k=st.integers(1, 5), c_f=st.floats(0.05, 3.0))
def test_lemma1_sandwich(d, y, k, c_f):
    dj, yj = jnp.array(d), jnp.array(y)
    g = float(G.gain_value(dj, yj, k, c_f))
    low = float(G.lower_bound_l(dj, yj, k, c_f))
    assert low <= g + 1e-3 * (1 + abs(g))
    assert g <= low / (1 - 1 / np.e) + 1e-3 * (1 + abs(g))


@settings(max_examples=60, deadline=None)
@given(d=_dists(20), y=_fracs(20), k=st.integers(1, 5), c_f=st.floats(0.05, 3.0),
       i=st.integers(0, 19), delta=st.floats(0.0, 1.0, width=32))
def test_gain_monotone_in_cache_state(d, y, k, c_f, i, delta):
    """Storing more of any object never decreases the gain."""
    y2 = y.copy()
    y2[i] = min(1.0, y2[i] + delta)
    g1 = float(G.gain_value(jnp.array(d), jnp.array(y), k, c_f))
    g2 = float(G.gain_value(jnp.array(d), jnp.array(y2), k, c_f))
    assert g2 >= g1 - 1e-3 * (1 + abs(g1))


@settings(max_examples=60, deadline=None)
@given(d=_dists(24), k=st.integers(1, 5), c_f=st.floats(0.05, 3.0),
       bits=st.integers(0, 2 ** 24 - 1))
def test_serve_cost_never_exceeds_empty_cost(d, k, c_f, bits):
    x = np.array([(bits >> i) & 1 for i in range(24)], np.float32)
    res = G.serve(jnp.array(d), jnp.array(x), k, c_f)
    empty = float(G.empty_cache_cost(jnp.array(d), k, c_f))
    assert float(res.cost) <= empty + 1e-4
    assert float(res.gain) >= -1e-4


@settings(max_examples=40, deadline=None)
@given(z=hnp.arrays(np.float32, (60,),
                    elements=st.floats(0.0, 100.0, width=32, allow_nan=False)),
       h=st.integers(1, 59))
def test_projection_feasibility(z, h):
    z = z + np.float32(1e-6)  # keep strictly inside the entropy domain
    y = np.array(P.capped_simplex_negentropy(jnp.array(z), h))
    assert (y >= -1e-6).all() and (y <= 1 + 1e-5).all()
    assert abs(y.sum() - h) < 2e-3 * h + 1e-3


@settings(max_examples=40, deadline=None)
@given(z=hnp.arrays(np.float32, (60,),
                    elements=st.floats(-10.0, 10.0, width=32)),
       h=st.integers(1, 59))
def test_euclidean_projection_feasibility(z, h):
    y = np.array(P.capped_simplex_euclidean(jnp.array(z), h))
    assert (y >= -1e-6).all() and (y <= 1 + 1e-5).all()
    assert abs(y.sum() - h) < 1e-2
