"""Unified policy API: protocol, spec, and config-driven policy registry.

The paper's claims (Sec. V, Figs. 1-8) are *comparative*: AÇAI against the
similarity-caching baselines (SIM-LRU / CLS-LRU / RND-LRU / QCACHE / LRU)
across traces with and without statistical regularity.  This module makes
that comparison surface first-class, mirroring the index layer's design
(repro.index.base, DESIGN.md §8) one layer up:

* `CachePolicy` — the batched step protocol every policy implements:
  `serve_update_batch(rs (B, d), ts) -> StepMetrics` serves a request
  mini-batch against the current cache state and applies the policy's
  update.  `ts` are the requests' trace positions into the shared
  `ServerOracle` table (baselines read their precomputed exact kNN
  answers there); AÇAI ignores them.  `replay(reqs, ts)` drives a whole
  trace through the same contract.
* `PolicySpec` — a serializable (policy name + kwargs) description, the
  one config knob selecting a policy end-to-end: the experiment harness
  grids, `SemanticCachedLM(policy_spec=...)`, `launch/serve.py --policy/
  --policy-opt`, and dry-run provenance records.  Every registered policy
  accepts the paper's `augmented` serving-rule flag (AÇAI's per-object
  local/remote composition grafted onto the baseline's update logic —
  Fig. 7's dissection; a no-op for AÇAI itself, whose serving rule *is*
  the augmented one).
* `build_policy(spec, catalog, cost_model, oracle=None, index_spec=None,
  mesh=None, seed=0)` — the registry constructor.  AÇAI builds through
  the batched JAX pipeline (`repro.core.policy`), optionally over an
  approximate index (`index_spec`) or a device mesh; baselines build over
  the shared `ServerOracle` (one per trace, reused across every policy of
  a grid) with their hit tests and serving costs vectorized per
  mini-batch (repro.core.baselines.KeyValueCache.step_batch).

Policies register via `register_policy`, so adding one is a single
registration — no cross-cutting edits in benchmarks/serve/launch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import (Any, Callable, Dict, Mapping, Optional, Protocol, Tuple,
                    runtime_checkable)

import numpy as np

from repro.core import baselines as B
from repro.core import oma as oma_lib
from repro.core import policy as acai
from repro.core.costs import CostModel
from repro.core.policy import StepMetrics


@runtime_checkable
class CachePolicy(Protocol):
    """Batched cache policy over a catalog of object embeddings.

    This is the one step contract every registered policy — AÇAI and the
    five LRU-family baselines — speaks, so harnesses (`replay_trace`, the
    experiment grids, the churn driver, the serving tier) never branch on
    the policy name.

    Required surface (the conformance contract pinned by
    tests/test_policy_api.py):

    * `serve_update_batch(rs (B, d), ts (B,) | None) -> StepMetrics` —
      serve a request mini-batch from the current cache state and apply
      the policy's update; every StepMetrics field comes back with a (B,)
      leading axis.  `ts` are trace positions into the policy's
      `ServerOracle` (baselines need them to read precomputed server
      answers; None means "online" — the oracle computes answers on
      demand, which is also the only valid mode under catalog churn).
      AÇAI ignores `ts`.
    * `spec: PolicySpec` — the spec the policy was built from.
    * `k: int`, `c_f: float`, `h: int` — the cost-model/capacity knobs
      every metric is normalised by.
    * `normalized_gain(total_gain, t) -> float` — NAG, Eq. (11):
      total gain / (k · c_f · t), the paper's headline metric.

    Mutable-catalog surface (DESIGN.md §10; every registered policy
    implements it, pinned by tests/test_mutable_index.py):

    * `add_objects(vectors (B, d)) -> (B,) int32 ids` — admit new catalog
      objects online (monotonic row ids, never recycled).
    * `remove_objects(ids)` — expire objects online: they are dropped from
      the cache state immediately and can never be served again.
    * `refresh()` — rebuild approximate structures over the live rows
      (AÇAI over an ANN index; a no-op for exact candidates and for the
      oracle-exact baselines).

    Optional: `replay(reqs (T, d), ts) -> dict` — whole-trace replay
    (AÇAI runs a jitted lax.scan; the default helper `replay_trace` loops
    `serve_update_batch`).

    Example::

        pol = build_policy(PolicySpec("acai", {"h": 200}), catalog,
                           CostModel(c_f=1.0))
        m = pol.serve_update_batch(reqs[:8])          # one mini-batch step
        new_ids = pol.add_objects(fresh_embeddings)   # online insertion
        pol.remove_objects(new_ids[:2])               # online expiry
    """

    spec: "PolicySpec"
    k: int
    c_f: float
    h: int

    def serve_update_batch(self, rs, ts=None) -> StepMetrics:
        ...

    def normalized_gain(self, total_gain: float, t: int) -> float:
        ...


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Serializable policy selection: policy name + build kwargs.

    The policy twin of `repro.index.base.IndexSpec` (DESIGN.md §9): one
    value that names a policy and everything needed to rebuild it, usable
    anywhere a config travels — experiment-grid rows, benchmark JSON,
    `launch/serve.py --policy/--policy-opt`, dry-run provenance records,
    `SemanticCachedLM(policy_spec=...)`.

    `name` must be a registered policy (`registered_policies()`; today
    ``acai | lru | sim_lru | cls_lru | rnd_lru | qcache``).  `params` are
    passed verbatim to the registered builder, so valid keys are exactly
    the builder's keyword arguments — e.g.
    ``PolicySpec("sim_lru", {"h": 200, "k_prime": 20, "c_theta": 1.5,
    "augmented": True})`` or ``PolicySpec("acai", {"h": 200, "eta":
    0.05, "batch": 8})``.  Common params: ``h`` (required — cache
    capacity in objects), ``k`` (answers per request), ``c_f`` (fetch
    cost, overrides the build-time CostModel so a serialized spec is
    self-contained), ``augmented`` (every baseline: AÇAI's serving rule
    over the baseline's updates, Fig. 7), ``seed``.

    Round-trips through a flat dict (`to_dict` / `from_dict`) with the
    name under the ``"policy"`` key: ``{"policy": "sim_lru", "k_prime":
    20, ...}``; `with_params` derives sweep variants; `label` renders a
    stable human-readable row name for benchmark tables.
    """

    name: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))
        if "policy" in self.params:
            raise ValueError("'policy' is the spec field, not a param")

    def __hash__(self):
        return hash((self.name, tuple(sorted(self.params.items()))))

    def to_dict(self) -> Dict[str, Any]:
        """Flat dict form: {'policy': name, **params}."""
        return {"policy": self.name, **self.params}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PolicySpec":
        d = dict(d)
        try:
            name = d.pop("policy")
        except KeyError:
            raise ValueError(f"policy spec dict needs a 'policy' key: {d}")
        if name not in _REGISTRY:
            raise ValueError(_unknown_policy_msg(name))
        return cls(name, d)

    def with_params(self, **updates) -> "PolicySpec":
        return PolicySpec(self.name, {**self.params, **updates})

    @property
    def label(self) -> str:
        """Short human label for benchmark rows: name + the params that
        distinguish it from the defaults.  Floats are formatted to 4
        significant digits so calibrated values (C_theta = 1.5 c_f, eta =
        0.05 / c_f) keep row names stable across backends/BLAS — tracked
        BENCH rows must not rename on a last-ulp calibration shift."""
        if not self.params:
            return self.name
        parts = [f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                 for k, v in sorted(self.params.items())]
        return f"{self.name}({','.join(parts)})"


def resolve_policy_spec(value) -> "PolicySpec | None":
    """Normalize any user-facing spec form to PolicySpec-or-None: None, a
    PolicySpec, a policy-name string, or the flat dict form."""
    if value is None or isinstance(value, PolicySpec):
        if isinstance(value, PolicySpec) and value.name not in _REGISTRY:
            raise ValueError(_unknown_policy_msg(value.name))
        return value
    if isinstance(value, str):
        if value not in _REGISTRY:
            raise ValueError(_unknown_policy_msg(value))
        return PolicySpec(value)
    if isinstance(value, Mapping):
        return PolicySpec.from_dict(value)
    raise TypeError(f"cannot resolve a policy spec from {value!r}")


def parse_policy_opts(opts) -> Dict[str, Any]:
    """Parse CLI `--policy-opt key=value` pairs into builder kwargs.

    One parser with the index layer (repro.index.base.parse_index_opts:
    int -> float -> str coercion) plus a bool layer on top, so
    `augmented=true k_prime=20 eta=0.5 mirror=negentropy` all land with
    their natural types.
    """
    from repro.index.base import parse_index_opts

    try:
        out = parse_index_opts(opts)
    except ValueError as e:
        raise ValueError(str(e).replace("--index-opt", "--policy-opt"))
    return {k: v.lower() == "true"
            if isinstance(v, str) and v.lower() in ("true", "false") else v
            for k, v in out.items()}


_REGISTRY: Dict[str, Callable] = {}


def register_policy(name: str):
    """Decorator registering `fn(spec, catalog, cost_model, *, oracle,
    index_spec, mesh, seed, answer_cache) -> CachePolicy` under `name`."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def registered_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _unknown_policy_msg(name: str) -> str:
    return (f"unknown policy {name!r}; registered: "
            f"{', '.join(registered_policies())}")


def build_policy(spec, catalog, cost_model: CostModel, *, oracle=None,
                 index_spec=None, mesh=None, seed: int = 0,
                 answer_cache=None) -> CachePolicy:
    """Construct the policy a spec describes over `catalog`.

    Args:
      spec: a `PolicySpec`, a registered policy name, or the flat-dict
        form (``{"policy": "acai", "h": 200, ...}``) — all normalised
        through `resolve_policy_spec`.
      catalog: (N, d) object embeddings (numpy or jax; AÇAI moves them to
        device, the baselines keep a float32 host copy).
      cost_model: supplies (c_f, metric); spec params ``c_f`` / ``metric``
        override it so serialized specs are self-contained.
      oracle: the trace's shared `ServerOracle` — baselines require one
        (built on demand in *online* mode when omitted, answers computed
        per mini-batch through the fused chunked scan); AÇAI ignores it.
      index_spec: route AÇAI's remote-catalog candidate generation through
        the unified index registry (DESIGN.md §8); baselines reject it —
        their serving is oracle-exact by construction.
      mesh: serve AÇAI through the sharded multi-device step
        (DESIGN.md §7); baselines reject it.
      answer_cache: front AÇAI's index with the exact answer-memo tier
        (DESIGN.md §13) — an `AnswerCacheSpec`, its dict/int/bool forms,
        or None; requires `index_spec`; baselines reject it (their
        serving is oracle-exact/memoized by construction).
      seed: rounding / randomized-policy seed (spec param ``seed`` wins).

    Returns:
      A `CachePolicy` — step it with `serve_update_batch`, replay a trace
      with `replay_trace(pol, reqs, ts)`, mutate the catalog online with
      `add_objects` / `remove_objects` / `refresh`.

    Raises:
      ValueError/TypeError at build time (before any jit tracing) for
      unknown policies and bad params.
    """
    if isinstance(spec, (str, Mapping)):
        spec = resolve_policy_spec(spec)
    try:
        builder = _REGISTRY[spec.name]
    except KeyError:
        raise ValueError(_unknown_policy_msg(spec.name))
    return builder(spec, catalog, cost_model, oracle=oracle,
                   index_spec=index_spec, mesh=mesh, seed=seed,
                   answer_cache=answer_cache)


# ---------------------------------------------------------------------------
# AÇAI through the batched JAX pipeline
# ---------------------------------------------------------------------------

def acai_config_from_spec(spec: PolicySpec,
                          cost_model: Optional[CostModel] = None,
                          index_spec=None) -> acai.AcaiConfig:
    """Translate PolicySpec("acai", {...}) + a cost model into AcaiConfig.

    Spec params: h (required), k, c_remote, c_local, eta (default
    0.05 / c_f), mirror, rounding, round_every, debug — the same knobs
    the fig harnesses sweep — plus c_f itself, so a serialized spec can
    be self-contained (a `c_f` param overrides `cost_model`).
    `augmented` is accepted and ignored (AÇAI's serving rule is the
    augmented composition by definition)."""
    p = dict(spec.params)
    p.pop("augmented", None)   # AÇAI is the augmented serving rule
    p.pop("batch", None)       # replay-level knob, not a config field
    p.pop("seed", None)
    c_f = p.pop("c_f", None)
    if c_f is None:
        if cost_model is None:
            raise ValueError(
                "acai policy spec needs a cost model: pass cost_model= or "
                "put c_f in the spec params")
        c_f = cost_model.c_f
    try:
        h = p.pop("h")
    except KeyError:
        raise ValueError("acai policy spec needs 'h' (cache capacity)")
    k = p.pop("k", 10)
    eta = p.pop("eta", None)
    oma_kw = {kk: p.pop(kk) for kk in ("mirror", "rounding", "round_every")
              if kk in p}
    oma_kw["eta"] = float(eta) if eta is not None else 0.05 / float(c_f)
    cfg = acai.AcaiConfig(
        h=int(h), k=int(k), c_f=float(c_f),
        c_remote=int(p.pop("c_remote", 64)),
        c_local=int(p.pop("c_local", 16)),
        oma=oma_lib.OMAConfig(**oma_kw),
        index=index_spec, debug=bool(p.pop("debug", False)))
    if p:
        raise ValueError(f"unknown acai policy params: {sorted(p)}")
    return cfg


class AcaiPolicy:
    """CachePolicy adapter over `repro.core.policy.AcaiCache`.

    `serve_update_batch` is the cache's batched step (one OMA + rounding
    update per mini-batch, DESIGN.md §6); `replay` runs the whole trace
    through `make_replay_batched` — a single jitted lax.scan, bit-exact
    with the pre-harness `make_replay` pipeline at batch = 1 — with p50
    step latency measured on the jitted mini-batch step (the step is
    pure, so timing it does not advance the replay state)."""

    def __init__(self, spec: PolicySpec, catalog, cost_model: CostModel, *,
                 oracle=None, index_spec=None, mesh=None, seed: int = 0,
                 answer_cache=None):
        import jax.numpy as jnp

        del oracle  # AÇAI never consults the server oracle
        self.spec = spec
        self.batch = int(spec.params.get("batch", 1))
        cfg = acai_config_from_spec(spec, cost_model, index_spec=index_spec)
        self.cache = acai.AcaiCache(jnp.asarray(catalog), cfg, seed=seed,
                                    mesh=mesh, answer_cache=answer_cache)
        self.cfg = self.cache.cfg

    @property
    def answer_cache(self):
        """The `CachedIndex` wrapper when the answer tier is on (None
        otherwise) — the serving engine's fast path and the launch
        surface read hit stats through this."""
        return self.cache.answer_cache

    k = property(lambda self: self.cfg.k)
    c_f = property(lambda self: self.cfg.c_f)
    h = property(lambda self: self.cfg.h)

    def serve_update_batch(self, rs, ts=None) -> StepMetrics:
        import jax.numpy as jnp

        return self.cache.serve_update_batch(jnp.asarray(rs))

    def serve_update(self, r, t=None) -> StepMetrics:
        import jax.numpy as jnp

        return self.cache.serve_update(jnp.asarray(r))

    # -- online catalog mutation (DESIGN.md §10) --------------------------

    def add_objects(self, vectors):
        """Admit new catalog objects online (delegates to AcaiCache)."""
        return self.cache.add_objects(vectors)

    def remove_objects(self, ids) -> None:
        """Expire catalog objects online (tombstone + state invalidation)."""
        self.cache.remove_objects(ids)

    def refresh(self) -> None:
        """Rebuild the remote index's structures over the live rows."""
        self.cache.refresh()

    def refresh_start(self) -> None:
        """Start a double-buffered refresh (shadow rebuild; stale serves)."""
        self.cache.refresh_start()

    def refresh_swap(self) -> None:
        """Install the pending refresh shadow (the only serving stall)."""
        self.cache.refresh_swap()

    def compact(self) -> np.ndarray:
        """Epoch compaction: drop tombstoned slab rows and renumber the
        survivors; returns the old-capacity -> new-id remap (-1 = dead)."""
        return self.cache.compact()

    def normalized_gain(self, total_gain: float, t: int) -> float:
        return self.cache.normalized_gain(total_gain, t)

    def replay(self, reqs, ts=None, time_reps: int = 5) -> dict:
        import jax
        import jax.numpy as jnp

        if self.cache._mutated:
            # the scanned replay would close over pre-mutation structures;
            # mutated caches replay through the generic mini-batch loop
            return replay_trace_steps(self, reqs, ts, batch=self.batch)
        reqs = jnp.asarray(reqs)
        t, b = reqs.shape[0], self.batch
        tt = (t // b) * b
        if self.cache.mesh is not None:
            step = jax.jit(self.cache._sharded_step(b))
        else:
            step = jax.jit(acai.make_step_batched(self.cfg,
                                                  self.cache._fn_batched, b))
        state0 = self.cache.state  # replay from the cache's current state
        _, m = step(state0, reqs[:b])            # compile + warmup
        m.gain_int.block_until_ready()
        times = []
        for _ in range(time_reps):  # the step is pure: state0 untouched
            t0 = time.time()
            _, m = step(state0, reqs[:b])
            m.gain_int.block_until_ready()
            times.append(time.time() - t0)
        replay = acai.make_replay_from_step(step, b)
        state, m = replay(state0, reqs[:tt])
        self.cache.state = state
        return {
            "gain": np.asarray(m.gain_int, np.float64),
            "cost": np.asarray(m.cost, np.float64),
            "served_local": np.asarray(m.served_local),
            "hit": np.asarray(m.served_local) > 0,
            "fetched": np.asarray(m.fetched),
            "occupancy": np.asarray(m.occupancy, np.float64),
            "p50_step_s": float(np.percentile(times, 50)),
            "requests": int(tt),
        }


# ---------------------------------------------------------------------------
# Baselines through the batched mini-batch step
# ---------------------------------------------------------------------------

class BaselinePolicy:
    """CachePolicy adapter over the sequential LRU-family baselines.

    The update logic stays the exact sequential data-structure policy
    (repro.core.baselines); serving costs and hit tests are vectorized
    per mini-batch via `KeyValueCache.step_batch` (two float32 GEMMs per
    batch instead of per-step python distance loops), and the server
    answers come from the shared per-trace `ServerOracle` — built here in
    online mode (answers computed on demand through the fused chunked
    scan) when the caller does not pass one."""

    def __init__(self, spec: PolicySpec, catalog, cost_model: CostModel, *,
                 oracle=None, index_spec=None, mesh=None, seed: int = 0,
                 answer_cache=None):
        if index_spec is not None:
            raise ValueError(
                f"policy {spec.name!r} serves from the exact server oracle; "
                f"index_spec only applies to 'acai'")
        if mesh is not None:
            raise ValueError(
                f"policy {spec.name!r} is a sequential baseline; mesh= only "
                f"applies to 'acai'")
        if answer_cache is not None:
            raise ValueError(
                f"policy {spec.name!r} serves oracle-exact (memoized) "
                f"answers by construction; answer_cache only applies to "
                f"'acai'")
        self.spec = spec
        p = dict(spec.params)
        p.pop("batch", None)
        cls = B.POLICIES[_BASELINE_CLASS[spec.name]]
        try:
            h = p.pop("h")
        except KeyError:
            raise ValueError(f"{spec.name} policy spec needs 'h'")
        k = int(p.pop("k", 10))
        # self-contained serialized specs (same contract as the acai twin):
        # c_f / metric / seed params override the cost model / seed args
        c_f = float(p.pop("c_f", cost_model.c_f))
        metric = p.pop("metric", cost_model.metric)
        seed = int(p.pop("seed", seed))
        kmax = max(k, int(p.get("k_prime") or k), 1)
        catalog = np.asarray(catalog, np.float32)
        if oracle is None:
            # online mode: answers computed per mini-batch; don't retain
            # the whole answer history (the serving tier runs unbounded)
            oracle = B.ServerOracle(catalog, kmax=max(kmax, 16),
                                    retain_all=False)
        if oracle.kmax < kmax:
            raise ValueError(
                f"shared oracle holds kmax={oracle.kmax} answers but "
                f"{spec.name} needs {kmax} (k/k_prime)")
        self.oracle = oracle
        self.policy = cls(catalog, oracle, h=int(h), k=k, c_f=c_f,
                          metric=metric, seed=seed, **p)
        self._total_gain = 0.0
        self._t = 0

    k = property(lambda self: self.policy.k)
    c_f = property(lambda self: self.policy.c_f)
    h = property(lambda self: self.policy.h)

    def serve_update_batch(self, rs, ts=None) -> StepMetrics:
        from repro.index.base import check_finite_queries

        rs = np.atleast_2d(np.asarray(rs, np.float32))
        check_finite_queries(rs, f"{self.spec.name}.serve_update_batch")
        if ts is None:  # online mode: answer the new requests on demand
            ts = self.oracle.extend(rs)
        results = self.policy.step_batch(np.asarray(ts), rs)
        self._total_gain += float(sum(r.gain for r in results))
        self._t += len(results)
        occ = float(len(self.policy.cached_object_ids()))
        zeros = np.zeros(len(results), np.int32)  # resilience counters:
        # arrays (not the int defaults) so tree_map over metrics is safe
        return StepMetrics(
            gain_int=np.array([r.gain for r in results]),
            gain_frac=np.array([r.gain for r in results]),
            cost=np.array([r.cost for r in results]),
            served_local=np.array([r.served_local for r in results],
                                  np.int32),
            fetched=np.array([r.fetched for r in results], np.int32),
            occupancy=np.full(len(results), occ),
            local_overflow=np.zeros(len(results), np.int32),
            degraded=zeros, shed=zeros, remote_failures=zeros,
            retries=zeros, deadline_misses=zeros,
            answer_hits=zeros, answer_misses=zeros,
            answer_invalidations=zeros,
        )

    def serve_update(self, r, t=None) -> StepMetrics:
        import jax.tree_util as jtu

        ts = None if t is None else np.asarray([t])
        m = self.serve_update_batch(np.atleast_2d(np.asarray(r)), ts)
        return jtu.tree_map(lambda a: a[0], m)

    # -- online catalog mutation (DESIGN.md §10) --------------------------

    def add_objects(self, vectors) -> np.ndarray:
        """Admit new objects: the server oracle learns the rows (stale
        precomputed answers are invalidated — serve with ts=None after a
        mutation) and the policy's catalog reference follows."""
        ids = self.oracle.add_objects(np.asarray(vectors, np.float32))
        self.policy.catalog = self.oracle.catalog
        return ids

    def remove_objects(self, ids) -> None:
        """Expire objects: tombstoned in the oracle (they vanish from all
        future kNN answers) and cached entries referencing them are
        evicted, so a removed object is never served again."""
        self.oracle.remove_objects(ids)
        self.policy.catalog = self.oracle.catalog
        self.policy.drop_objects(ids)

    def refresh(self) -> None:
        """No-op: baseline serving is oracle-exact (nothing drifts)."""

    def refresh_start(self) -> None:
        """No-op: nothing to shadow-rebuild (see refresh)."""

    def refresh_swap(self) -> None:
        """No-op: nothing to swap (see refresh)."""

    def compact(self) -> np.ndarray:
        """Epoch compaction: the oracle drops tombstoned rows and
        renumbers; cached entries' value ids are rewritten through the
        remap (entries never hold dead objects — remove_objects evicts).
        Returns the old-capacity -> new-id remap (-1 = dead)."""
        remap = self.oracle.compact()
        self.policy.catalog = self.oracle.catalog
        self.policy.remap_objects(remap)
        return remap

    def normalized_gain(self, total_gain: float, t: int) -> float:
        return float(total_gain) / (self.k * self.c_f * max(t, 1))


# `spec name -> baselines.POLICIES key` (the paper's display names)
_BASELINE_CLASS = {
    "lru": "LRU",
    "sim_lru": "SIM-LRU",
    "cls_lru": "CLS-LRU",
    "rnd_lru": "RND-LRU",
    "qcache": "QCACHE",
}

register_policy("acai")(AcaiPolicy)
for _name in _BASELINE_CLASS:
    register_policy(_name)(BaselinePolicy)


def replay_trace(pol: CachePolicy, reqs, ts=None, *, batch: int = 8) -> dict:
    """Drive a whole trace through `serve_update_batch`, timing each step.

    The generic CachePolicy replay: loops mini-batches of `batch`
    requests (the trace tail that does not fill a batch is dropped, same
    convention as make_replay_batched) and assembles per-request metric
    arrays + the p50 step latency.  Policies with a native `replay`
    (AÇAI's jitted scan) are dispatched to it instead."""
    if hasattr(pol, "replay"):
        return pol.replay(reqs, ts)
    return replay_trace_steps(pol, reqs, ts, batch=batch)


def replay_trace_steps(pol: CachePolicy, reqs, ts=None, *,
                       batch: int = 8) -> dict:
    """The mini-batch stepping loop behind `replay_trace` (no native-replay
    dispatch) — also the path a mutated AÇAI cache replays through, since
    its scanned replay would close over pre-mutation structures."""
    reqs = np.asarray(reqs)
    t = reqs.shape[0]
    tt = (t // batch) * batch
    if tt == 0:
        raise ValueError(
            f"trace of {t} requests is shorter than one mini-batch "
            f"(batch={batch}); shrink batch or extend the trace")
    out = {k: [] for k in ("gain", "cost", "served_local", "fetched",
                           "occupancy", "answer_hits",
                           "answer_invalidations")}
    times = []
    for s in range(0, tt, batch):
        t0 = time.time()
        # ts=None stays None per batch: the online-oracle path must fire
        # (fabricating positions would index an empty answer table)
        m = pol.serve_update_batch(reqs[s:s + batch],
                                   None if ts is None else ts[s:s + batch])
        times.append(time.time() - t0)
        out["gain"].append(np.asarray(m.gain_int, np.float64))
        out["cost"].append(np.asarray(m.cost, np.float64))
        out["served_local"].append(np.asarray(m.served_local))
        out["fetched"].append(np.asarray(m.fetched))
        out["occupancy"].append(np.asarray(m.occupancy, np.float64))
        # answer-tier counters (DESIGN.md §13): 0 everywhere when off
        # (the int-default leaves broadcast to per-request zeros)
        out["answer_hits"].append(
            np.broadcast_to(np.asarray(m.answer_hits), (batch,)))
        out["answer_invalidations"].append(
            np.broadcast_to(np.asarray(m.answer_invalidations), (batch,)))
    res = {k: np.concatenate(v) for k, v in out.items()}
    res["hit"] = res["served_local"] > 0
    res["p50_step_s"] = float(np.percentile(times, 50)) if times else 0.0
    res["requests"] = int(tt)
    return res


def replay_trace_online(pol: CachePolicy, reqs, arrivals, *,
                        former=None, admission=None, service=None,
                        catalog=None, events=(), slo_ms=None) -> dict:
    """Drive a trace through the *online* serving engine (DESIGN.md §12).

    The arrival-aware counterpart of `replay_trace`: instead of feeding
    fixed mini-batches, requests arrive on the virtual clock per
    `arrivals` (an `ArrivalSpec`, a raw times array, or a ready source),
    queue, get coalesced by the dynamic batch former, and may be shed by
    admission control — so the result adds queueing/latency/shed fields
    (`latency_ms`, `p50_ms`/`p99_ms`/`p999_ms`, `shed`, `goodput_slo`,
    `batch_hist`, ...) to the usual per-request gain/cost arrays.  Works
    for every registered policy: the engine only calls
    `serve_update_batch(rs, None)` (and the §10 mutation surface when
    `events` are given).  Defaults reproduce `fixed_window_engine`
    semantics via BatchFormerConfig/AdmissionConfig/ServiceModel
    defaults.  Lazy import keeps core free of a serve dependency."""
    from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                                   ServiceModel, serve_trace_online)

    return serve_trace_online(
        pol, reqs, arrivals,
        former=BatchFormerConfig() if former is None else former,
        admission=AdmissionConfig() if admission is None else admission,
        service=ServiceModel() if service is None else service,
        catalog=catalog, events=events, slo_ms=slo_ms)


# Smallest sensible spec params per registered policy (fractions of a
# second on a tiny trace).  The single source of truth for the
# conformance test (tests/test_policy_api.py) and the scripts/smoke.sh
# sweep — a new policy registers here once and both pick it up.  AÇAI
# pins depround rounding so the occupancy-≤-h invariant is exact.
TINY_POLICY_KWARGS = {
    "acai": {"h": 16, "k": 4, "c_remote": 12, "c_local": 8, "eta": 0.05,
             "rounding": "depround", "batch": 8},
    "lru": {"h": 16, "k": 4},
    "sim_lru": {"h": 16, "k": 4, "k_prime": 8, "c_theta": 1.5},
    "cls_lru": {"h": 16, "k": 4, "k_prime": 8, "c_theta": 1.5},
    "rnd_lru": {"h": 16, "k": 4, "k_prime": 8, "c_theta": 1.5},
    "qcache": {"h": 16, "k": 4},
}
