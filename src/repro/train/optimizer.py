"""Optimizers, built from scratch (no optax): AdamW and Adafactor.

AdamW keeps fp32 master weights + two fp32 moments (12 bytes/param) — used
for the small/medium archs.  Adafactor keeps a factored second moment
(row + col vectors for >=2-D params) and no momentum — O(sum of dims)
state, mandatory for the 100B+ archs where fp32 Adam state exceeds the
aggregate HBM of the assigned meshes (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay: float = 0.8
    clip_threshold: float = 1.0


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw_init(params):
    f32 = lambda p: jnp.zeros_like(p, jnp.float32)
    return {
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: OptConfig):
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step = step + cfg.weight_decay * master
        new_master = master - cfg.lr * step
        return m, v, new_master

    out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    return new_params, {"master": master, "m": m, "v": v, "count": count}


# --------------------------------------------------------------------------
# Adafactor (factored second moments, momentum-free)
# --------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),   # row
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, jnp.float32)}

    return {"v": jax.tree.map(init, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, cfg: OptConfig):
    count = state["count"] + 1
    beta = 1.0 - count.astype(jnp.float32) ** (-cfg.decay)

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(g.shape):
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            new_v = {"vr": vr, "vc": vc}
        else:
            vhat = beta * v["v"] + (1 - beta) * g2
            new_v = {"v": vhat}
        update = g / jnp.sqrt(vhat + 1e-30)
        # update clipping (Shazeer & Stern)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms / cfg.clip_threshold)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * update).astype(p.dtype)
        return new_p, new_v

    out = jax.tree.map(upd, grads, state["v"], params,
                       is_leaf=lambda x: isinstance(x, jax.Array))
    is_pair = lambda x: isinstance(x, tuple)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    new_v = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return new_params, {"v": new_v, "count": count}


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def init_opt(name: str, params):
    return adamw_init(params) if name == "adamw" else adafactor_init(params)


def apply_opt(name: str, grads, state, params, cfg: OptConfig):
    if name == "adamw":
        return adamw_update(grads, state, params, cfg)
    return adafactor_update(grads, state, params, cfg)
