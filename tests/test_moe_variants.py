"""MoE dispatch variants: two-stage local dispatch == reference scatter."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models.moe import init_moe, moe_ffn


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "mixtral-8x22b",
                                  "jamba-1.5-large-398b"])
@pytest.mark.parametrize("dp", [2, 4])
def test_two_stage_equals_single_stage_dropless(arch, dp):
    cfg = SMOKE_ARCHS[arch]  # smoke configs are dropless (cf = 0)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (4, 16, cfg.d_model)).astype(jnp.bfloat16)
    out1, aux1 = moe_ffn(p, x, cfg)
    out2, aux2 = moe_ffn(p, x, dataclasses.replace(cfg, moe_dp=dp))
    rel = float(jnp.abs(out1.astype(jnp.float32) - out2.astype(jnp.float32)
                        ).max() / jnp.abs(out1.astype(jnp.float32)).max())
    assert rel < 2e-2, rel
    assert abs(float(aux1 - aux2)) < 1e-4


def test_two_stage_capacity_local():
    """With per-shard capacity, drops are decided within each shard —
    outputs stay finite and gate-weighted."""
    cfg = dataclasses.replace(SMOKE_ARCHS["mixtral-8x22b"],
                              capacity_factor=1.0, moe_dp=4)
    key = jax.random.PRNGKey(1)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (4, 32, cfg.d_model)).astype(jnp.bfloat16)
    out, aux = moe_ffn(p, x, cfg)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux) > 0


def test_flash_train_forward_matches_dense():
    from repro.models import forward, init_params
    cfg = SMOKE_ARCHS["yi-6b"]
    cfg_flash = dataclasses.replace(cfg, flash_threshold=32, flash_chunk=16)
    params = init_params(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 64), 0, cfg.vocab)
    dense = forward(params, cfg, tokens=toks).logits
    flash = forward(params, cfg_flash, tokens=toks).logits
    rel = float(jnp.abs(dense - flash).max() / jnp.abs(dense).max())
    assert rel < 2e-2, rel
