"""Unified index API (DESIGN.md §8): protocol conformance across every
registered backend, IndexSpec round-tripping, registry completeness, CLI
option parsing, and the debug overflow counter."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.index as index_pkg
from repro.core import oma, policy, trace
from repro.index import (Index, IndexSpec, build_index, parse_index_opts,
                         registered_backends)
from repro.index.candidates import index_candidate_fn_batched

# tiny-catalog build kwargs: every single-device backend, seconds to
# build — the canonical table lives in base.py (shared with smoke.sh)
from repro.index.base import TINY_BUILD_KWARGS as TINY  # noqa: E402


@pytest.fixture(scope="module")
def setup():
    catalog, reqs, _ = trace.sift_like(n=400, d=16, t=64, seed=0)
    cat, rq = jnp.array(catalog), jnp.array(reqs)
    cfg = policy.AcaiConfig(h=24, k=4, c_f=1.0, c_remote=16, c_local=8,
                            oma=oma.OMAConfig(eta=0.05))
    return cat, rq, cfg


@pytest.fixture(scope="module")
def built(setup):
    cat, _, _ = setup
    return {b: build_index(IndexSpec(b, kw), cat) for b, kw in TINY.items()}


# ---------------------------------------------------------------------------
# batched query-contract conformance (all backends, one shared test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", sorted(TINY))
def test_query_contract(setup, built, backend):
    cat, rq, _ = setup
    idx = built[backend]
    assert isinstance(idx, Index)
    assert idx.exact_distances in (True, False)
    assert idx.n == cat.shape[0]
    assert isinstance(idx.memory_bytes(), int) and idx.memory_bytes() > 0

    d, ids = idx.query(rq[:8], 5)
    assert d.shape == (8, 5) and ids.shape == (8, 5)
    assert ids.dtype == jnp.int32
    dd, ii = np.asarray(d), np.asarray(ids)
    valid = ii >= 0
    assert (ii[valid] < idx.n).all()
    assert (dd[valid] >= -1e-5).all()
    # ascending distances; underflow slots carry +inf and sort to the tail
    assert (np.diff(np.where(valid, dd, np.inf), axis=1) >= -1e-5).all()
    assert np.isinf(dd[~valid]).all()

    # a (d,) request vector is promoted to B = 1
    d1, i1 = idx.query(rq[0], 5)
    assert d1.shape == (1, 5) and i1.shape == (1, 5)


def test_underflow_marks_minus_one(setup):
    """Fewer than k reachable candidates -> id = -1, dist = +inf."""
    cat, rq, _ = setup
    # 2 tables x 4-slot buckets can reach at most 8 distinct candidates;
    # after cross-table dedup most queries see fewer than 8
    idx = build_index(
        IndexSpec("lsh", {"tables": 2, "bits": 6, "cap": 4}), cat)
    d, ids = idx.query(rq[:16], 8)
    ids, d = np.asarray(ids), np.asarray(d)
    assert (ids == -1).any(), "expected at least one underflowing slot"
    assert np.isinf(d[ids == -1]).all()
    # k beyond the structure's reachable width (NSW beam, LSH/IVF slab) is
    # structural underflow, not a crash — this is what AcaiConfig defaults
    # hit (c_remote=64) on small structures
    nsw = build_index(IndexSpec("nsw", TINY["nsw"]), cat)  # beam=16
    d, ids = nsw.query(rq[:4], 20)
    assert d.shape == (4, 20)
    assert (np.asarray(ids)[:, 16:] == -1).all()
    assert np.isinf(np.asarray(d)[:, 16:]).all()
    d, ids = idx.query(rq[:4], 16)            # LSH slab is 2*4 = 8 wide
    assert d.shape == (4, 16)
    assert (np.asarray(ids)[:, 8:] == -1).all()
    assert np.isinf(np.asarray(d)[:, 8:]).all()


# ---------------------------------------------------------------------------
# policy-stack conformance: every backend through make_replay_batched,
# and the sharded twin through the same registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", sorted(TINY))
def test_replay_batched_conformance(setup, built, backend):
    cat, rq, cfg = setup
    fnb = index_candidate_fn_batched(built[backend], cat, cfg.c_remote,
                                     cfg.c_local, h=cfg.h)
    state, m = policy.make_replay_batched(cfg, fnb, 8)(
        policy.init_state(cat.shape[0], cfg), rq)
    assert m.gain_int.shape == (64,)
    assert float(jnp.sum(m.gain_int)) >= 0
    assert int(state.t) == 64
    assert abs(float(jnp.sum(state.y)) - cfg.h) < 1e-2


def test_sharded_twin_through_registry(setup):
    """ivf_sharded builds through build_index(mesh=...) and drives the
    sharded replay twin (1-device mesh on CPU; multi-device covered by
    tests/test_distributed_acai.py and scripts/smoke.sh)."""
    from repro.core.distributed import ShardedIVF, make_replay_sharded

    cat, rq, cfg = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ivf = build_index(IndexSpec("ivf_sharded", {"nlist": 8, "nprobe": 4}),
                      cat, mesh=mesh)
    assert isinstance(ivf, ShardedIVF)
    state, m = make_replay_sharded(cfg, mesh, cat, 8, ivf=ivf)(
        policy.init_state(cat.shape[0], cfg), rq)
    assert m.gain_int.shape == (64,)
    assert float(jnp.sum(m.gain_int)) >= 0
    assert abs(float(jnp.sum(state.y)) - cfg.h) < 1e-2

    with pytest.raises(ValueError, match="sharded"):
        build_index(IndexSpec("ivf_sharded"), cat)  # mesh is required


def test_acai_cache_builds_from_spec(setup):
    """AcaiConfig.index is the one knob: AcaiCache builds its candidate
    generator from the spec; explicit candidate_fn alongside it warns."""
    import dataclasses

    from repro.index import IVFFlatIndex

    cat, rq, cfg = setup
    cfg_ivf = dataclasses.replace(
        cfg, index=IndexSpec("ivf", {"nlist": 8, "nprobe": 4}))
    cache = policy.AcaiCache(cat, cfg_ivf, seed=0)
    assert isinstance(cache.index, IVFFlatIndex)
    m1 = cache.serve_update(rq[0])
    assert m1.gain_int.shape == ()
    mb = cache.serve_update_batch(rq[1:9])
    assert mb.gain_int.shape == (8,)
    assert int(cache.state.t) == 9

    with pytest.warns(DeprecationWarning):
        policy.AcaiCache(
            cat, cfg_ivf,
            candidate_fn_batched=policy.exact_candidate_fn_batched(
                cat, cfg.c_remote, cfg.c_local))

    # the reserved "exact" spec (as written by dryrun provenance records)
    # normalizes to the spec-less exact generator instead of crashing
    cfg_exact = dataclasses.replace(cfg, index=IndexSpec("exact"))
    cache = policy.AcaiCache(cat, cfg_exact, seed=0)
    assert cache.index is None and cache.cfg.index is None
    assert cache.serve_update_batch(rq[:4]).gain_int.shape == (4,)


# ---------------------------------------------------------------------------
# IndexSpec serialization + registry + CLI parsing
# ---------------------------------------------------------------------------

def test_spec_roundtrip():
    spec = IndexSpec("ivf", {"nlist": 256, "nprobe": 16})
    d = spec.to_dict()
    assert d == {"backend": "ivf", "nlist": 256, "nprobe": 16}
    assert IndexSpec.from_dict(d) == spec
    assert IndexSpec.from_dict(spec.to_dict()).to_dict() == d
    assert spec.with_params(nprobe=32).params["nprobe"] == 32
    assert hash(spec) == hash(IndexSpec("ivf", {"nprobe": 16, "nlist": 256}))


def test_spec_errors(setup):
    cat, _, _ = setup
    with pytest.raises(ValueError, match="unknown index backend"):
        IndexSpec.from_dict({"backend": "annoy"})
    with pytest.raises(ValueError, match="unknown index backend"):
        build_index(IndexSpec("annoy"), cat)
    with pytest.raises(ValueError, match="backend"):
        IndexSpec.from_dict({"nlist": 4})
    with pytest.raises(ValueError, match="backend"):
        IndexSpec("ivf", {"backend": "ivf"})


def test_resolve_spec():
    """'exact' is the reserved spec-less name: every serialized form of it
    (the dryrun provenance record, the CLI default) resolves to None."""
    from repro.index.base import resolve_spec

    assert resolve_spec(None) is None
    assert resolve_spec("exact") is None
    assert resolve_spec({"backend": "exact"}) is None
    assert resolve_spec(IndexSpec("exact")) is None
    spec = IndexSpec("ivf", {"nlist": 8})
    assert resolve_spec(spec) is spec
    assert resolve_spec("nsw") == IndexSpec("nsw")
    assert resolve_spec({"backend": "ivf", "nlist": 8}) == spec
    with pytest.raises(ValueError, match="unknown index backend"):
        resolve_spec("annoy")
    with pytest.raises(ValueError, match="unknown index backend"):
        resolve_spec(IndexSpec("annoy"))
    with pytest.raises(ValueError, match="no params"):
        resolve_spec({"backend": "exact", "nlist": 8})
    with pytest.raises(TypeError):
        resolve_spec(42)


def test_parse_index_opts():
    assert parse_index_opts(
        ["nlist=256", "refine=0", "eta=0.5", "kernel=xla"]
    ) == {"nlist": 256, "refine": 0, "eta": 0.5, "kernel": "xla"}
    assert parse_index_opts([]) == {}
    assert parse_index_opts(None) == {}
    with pytest.raises(ValueError, match="key=value"):
        parse_index_opts(["nlist"])


def test_registry_complete(setup, built):
    """Every index class exported from repro.index is constructible through
    a registered backend, and the registry names are stable."""
    assert set(registered_backends()) == {
        "flat", "ivf", "ivfpq", "lsh", "nsw", "ivf_sharded"}
    assert registered_backends(sharded=True) == ("ivf_sharded",)
    # the shared tiny-kwargs table (this file + smoke.sh) covers every
    # single-device backend
    assert set(TINY) == set(registered_backends(sharded=False))
    exported = {name for name in index_pkg.__all__
                if name.endswith("Index") and name != "Index"}
    constructed = {type(idx).__name__ for idx in built.values()}
    assert exported <= constructed, exported - constructed


# ---------------------------------------------------------------------------
# debug-mode local_cap overflow counter
# ---------------------------------------------------------------------------

def test_local_overflow_counter(setup):
    """Occupancy above the candidate generator's static local_cap is a
    silent quality loss; with cfg.debug the step books it per request."""
    import dataclasses

    cat, rq, cfg = setup
    n = cat.shape[0]
    idx = build_index(IndexSpec("flat"), cat)
    fnb = index_candidate_fn_batched(idx, cat, cfg.c_remote, cfg.c_local,
                                     local_cap=8)
    assert fnb.local_cap == 8
    x = jnp.zeros((n,)).at[jnp.arange(20)].set(1.0)  # occupancy 20 > cap 8
    state = policy.CacheState(
        y=jnp.full((n,), cfg.h / n), x=x,
        t=jnp.zeros((), jnp.int32), key=jax.random.PRNGKey(0))

    cfg_dbg = dataclasses.replace(cfg, debug=True)
    _, m = policy.make_step_batched(cfg_dbg, fnb, 4)(state, rq[:4])
    np.testing.assert_array_equal(np.asarray(m.local_overflow),
                                  np.full((4,), 12, np.int32))
    # per-request step sees the same counter
    _, m1 = policy.make_step(cfg_dbg, policy.per_request_view(fnb))(
        state, rq[0])
    assert int(m1.local_overflow) == 12
    # debug off (the default): counter stays zero
    _, m0 = policy.make_step_batched(cfg, fnb, 4)(state, rq[:4])
    assert int(jnp.sum(m0.local_overflow)) == 0


def test_local_overflow_counter_sharded(setup):
    """The sharded step's capped cached-row gather (scan_chunk/ivf paths,
    static 2h + 64 bound per shard) books the same debug counter."""
    import dataclasses

    from repro.core.distributed import make_step_sharded

    cat, rq, cfg = setup
    n = cat.shape[0]
    cfg_dbg = dataclasses.replace(cfg, debug=True)
    cap = 2 * cfg.h + 64                      # 112 on the 1-shard mesh
    occ = cap + 30
    x = jnp.zeros((n,)).at[jnp.arange(occ)].set(1.0)
    state = policy.CacheState(
        y=jnp.full((n,), cfg.h / n), x=x,
        t=jnp.zeros((), jnp.int32), key=jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _, m = make_step_sharded(cfg_dbg, mesh, cat, 4, scan_chunk=64)(
        state, rq[:4])
    np.testing.assert_array_equal(np.asarray(m.local_overflow),
                                  np.full((4,), 30, np.int32))
    # the uncapped exact sharded path cannot truncate: counter stays zero
    _, m0 = make_step_sharded(cfg_dbg, mesh, cat, 4)(state, rq[:4])
    assert int(jnp.sum(m0.local_overflow)) == 0
