"""Shared benchmark machinery.

Every figure benchmark emits CSV rows  `name,us_per_call,derived`  where
`derived` carries the figure's metric (NAG etc.) and us_per_call the mean
wall time per request for the policy.  Sizes are reduced by default so the
whole suite runs on CPU in minutes; pass --full for paper-scale runs.

The policy-comparison protocol (tuned baselines, augmented twins, shared
per-trace oracle) lives in `benchmarks.experiments`; this module keeps
the trace/oracle setup cache used by the kernel-, serve- and
regret-level suites plus the AÇAI sequential-replay helper.
"""

from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass
from functools import lru_cache

import numpy as np
import jax.numpy as jnp

from repro.core import baselines as B
from repro.core import oma, policy, trace
from repro.core.costs import calibrate_fetch_cost


@dataclass
class BenchSetup:
    name: str
    catalog: np.ndarray
    requests: np.ndarray
    ids: np.ndarray
    cat_j: jnp.ndarray
    oracle: B.ServerOracle
    cf_table: dict  # i-th neighbour -> avg distance


def _cf_table(cat_j, kths=(2, 10, 50, 100, 500, 1000)):
    out = {}
    for i in kths:
        if i < cat_j.shape[0]:
            out[i] = float(calibrate_fetch_cost(cat_j, kth=i, sample=256))
    return out


@lru_cache(maxsize=4)
def get_setup(kind: str, n: int, t: int, d: int = 32, kmax: int = 128) -> BenchSetup:
    name = {"sift": "sift_like", "amazon": "amazon_like"}.get(kind, kind)
    catalog, reqs, ids = trace.build_trace(name, n=n, d=d, t=t)
    cat_j = jnp.array(catalog)
    oracle = B.ServerOracle(catalog, reqs, kmax=kmax)
    return BenchSetup(kind, catalog, reqs, ids, cat_j, oracle, _cf_table(cat_j))


def run_acai(setup: BenchSetup, *, h, k, c_f, eta=None, mirror="negentropy",
             rounding="coupled", round_every=1, c_remote=64, c_local=16,
             candidate_fn=None, requests=None):
    """Returns (metrics dict, seconds_per_request)."""
    reqs = setup.requests if requests is None else requests
    eta = eta if eta is not None else 0.05 / c_f
    cfg = policy.AcaiConfig(
        h=h, k=k, c_f=c_f, c_remote=c_remote, c_local=c_local,
        oma=oma.OMAConfig(eta=eta, mirror=mirror, rounding=rounding,
                          round_every=round_every),
    )
    fn = candidate_fn or policy.exact_candidate_fn(setup.cat_j, c_remote, c_local)
    replay = policy.make_replay(cfg, fn)
    state = policy.init_state(setup.cat_j.shape[0], cfg)
    t0 = time.time()
    state, m = replay(state, jnp.array(reqs))
    m.gain_int.block_until_ready()
    dt = (time.time() - t0) / reqs.shape[0]
    return {
        "gain": np.array(m.gain_int), "gain_frac": np.array(m.gain_frac),
        "fetched": np.array(m.fetched), "occupancy": np.array(m.occupancy),
        "served_local": np.array(m.served_local), "state": state,
    }, dt


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def std_args(desc: str):
    p = argparse.ArgumentParser(description=desc)
    p.add_argument("--full", action="store_true",
                   help="paper-scale sizes (slow on CPU)")
    p.add_argument("--trace", default="sift",
                   help="sift|amazon aliases or any registered scenario")
    return p


def sizes(full: bool):
    return dict(n=20000, t=30000) if full else dict(n=4000, t=4000)
