"""Architecture registry: the 10 assigned architectures, selectable via
``--arch <id>`` in every launcher, plus reduced SMOKE variants for CPU
tests and the assigned shape sets."""

from repro.configs import (deepseek_v3, hubert_xlarge, jamba_1_5_large,
                           mamba2_130m, minitron_8b, mixtral_8x22b,
                           qwen1_5_0_5b, qwen2_72b, qwen2_vl_7b, yi_6b)
from repro.configs.shapes import SHAPES, SMOKE_SHAPES, ShapeSpec, runnable

_MODULES = {
    "mamba2-130m": mamba2_130m,
    "minitron-8b": minitron_8b,
    "yi-6b": yi_6b,
    "qwen2-72b": qwen2_72b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "hubert-xlarge": hubert_xlarge,
    "jamba-1.5-large-398b": jamba_1_5_large,
    "qwen2-vl-7b": qwen2_vl_7b,
    "mixtral-8x22b": mixtral_8x22b,
    "deepseek-v3-671b": deepseek_v3,
}

ARCHS = {name: mod.CONFIG for name, mod in _MODULES.items()}
SMOKE_ARCHS = {name: mod.SMOKE for name, mod in _MODULES.items()}


def get_config(arch: str, smoke: bool = False):
    table = SMOKE_ARCHS if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(table)}")
    return table[arch]


__all__ = ["ARCHS", "SMOKE_ARCHS", "SHAPES", "SMOKE_SHAPES", "ShapeSpec",
           "get_config", "runnable"]
