"""Kernel microbenchmarks: µs/call for the distance/ADC paths.

On CPU the Pallas kernels run in interpret mode (a correctness harness, not
a perf path), so the XLA-fused implementations are the CPU-meaningful
numbers; the Pallas timings are emitted for completeness and marked as
interpreted.  On TPU the same call sites dispatch to the compiled kernels.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops


def _time(fn, *args, reps=5):
    out = fn(*args)  # single warmup/compile call
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def main(full: bool = False, kind: str = "sift") -> None:
    rng = np.random.default_rng(0)
    q_n, db_n, d = (256, 100_000, 128) if full else (64, 20_000, 64)
    q = jnp.array(rng.normal(size=(q_n, d)).astype(np.float32))
    x = jnp.array(rng.normal(size=(db_n, d)).astype(np.float32))

    us = _time(ops.pairwise_l2_xla, q, x)
    flops = 2 * q_n * db_n * d
    common.emit("kernel/pairwise_l2/xla", us, f"GFLOPs={flops / us / 1e3:.1f}")

    us = _time(lambda a, b: ops.topk_l2(a, b, 16, interpret=True)[0], q[:8], x[:2048])
    common.emit("kernel/l2_topk/pallas-interpret(8x2048)", us, "correctness-path")

    us = _time(lambda a, b: ops.topk_l2_xla(a, b, 16)[0], q, x)
    common.emit("kernel/l2_topk/xla", us, f"GFLOPs={flops / us / 1e3:.1f}")

    cand = jnp.array(rng.integers(0, 2048, (8, 256)).astype(np.int32))
    us = _time(lambda a, b, c: ops.ivf_scan_topk(a, b, c, 16, interpret=True)[0],
               q[:8], x[:2048], cand)
    common.emit("kernel/ivf_scan/pallas-interpret(8x256)", us, "correctness-path")
    us = _time(lambda a, b, c: ops.ivf_scan_xla(a, b, c, 16)[0],
               q[:8], x[:2048], cand)
    common.emit("kernel/ivf_scan/xla(8x256)", us, "gather+l2+topk")

    m, c = 16, 256
    lut = jnp.array(rng.random((q_n, m, c)).astype(np.float32))
    codes = jnp.array(rng.integers(0, c, (db_n, m)).astype(np.int32))
    us = _time(ops.pq_adc_xla, lut, codes)
    common.emit("kernel/pq_adc/xla", us,
                f"bytes={db_n * m}→GBps={db_n * m / us / 1e3:.2f}")
    us = _time(lambda a, b: ops.pq_adc(a, b, interpret=True), lut[:2], codes[:2048])
    common.emit("kernel/pq_adc/pallas-interpret(2x2048)", us, "correctness-path")


if __name__ == "__main__":
    # kernel sizes are synthetic: --trace picks a dataset, not a kernel shape,
    # so it must not be forwarded into the `kind` parameter
    args = common.std_args(__doc__).parse_args()
    main(args.full)
