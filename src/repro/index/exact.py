"""Exact (flat) kNN index — the recall=1 reference and the local-catalog
workhorse (h <= a few thousand objects: a flat MXU scan beats any structure).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.index.base import arrays_bytes
from repro.kernels import ops


class FlatIndex:
    """Brute-force index.  kernel='xla' uses the fused-XLA distance path,
    'pallas' the Pallas kernel (interpret-mode on CPU), 'auto' dispatches
    by backend (pallas on TPU) via ops.topk_l2_auto."""

    exact_distances = True  # query() distances need no re-rank

    def __init__(self, embeddings: jax.Array, kernel: str = "auto"):
        self.embeddings = jnp.asarray(embeddings, jnp.float32)
        self.kernel = kernel

    @property
    def n(self) -> int:
        return self.embeddings.shape[0]

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings)

    @partial(jax.jit, static_argnames=("self", "k"))
    def query(self, q: jax.Array, k: int):
        q = jnp.atleast_2d(q)
        if self.kernel == "auto":
            return ops.topk_l2_auto(q, self.embeddings, k)
        if self.kernel == "pallas":
            return ops.topk_l2(q, self.embeddings, k)
        d = ops.pairwise_l2_xla(q, self.embeddings)
        neg, ids = jax.lax.top_k(-d, k)
        return -neg, ids

    def __hash__(self):  # allow use as a static jit argument
        return id(self)

    def __eq__(self, other):
        return self is other
