"""Fig. 8/9 (App. G): rounding schemes — update cost vs reactivity, and
cache-occupancy concentration under the relaxed capacity constraint."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "amazon") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    h, k = (1000, 10) if full else (200, 10)
    c_f = s.cf_table[50]
    out = {}
    schemes = [("coupled", 1), ("independent", 1),
               ("depround", 1), ("depround", 20), ("depround", 100)]
    for rounding, m_every in schemes:
        m, dt = common.run_acai(s, h=h, k=k, c_f=c_f, rounding=rounding,
                                round_every=m_every)
        label = rounding if rounding != "depround" else f"depround-M{m_every}"
        nag = B.nag(m["gain"], k, c_f)[-1]
        fetches = m["fetched"].mean()
        occ = m["occupancy"]
        out[label] = (nag, fetches)
        common.emit(f"fig8/{kind}/{label}/NAG", dt * 1e6, f"{nag:.4f}")
        common.emit(f"fig8/{kind}/{label}/fetches_per_req", 0.0, f"{fetches:.3f}")
        common.emit(
            f"fig8/{kind}/{label}/occupancy", 0.0,
            f"mean={occ.mean():.1f};p99dev={np.percentile(np.abs(occ - h), 99) / h:.3f}",
        )
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
