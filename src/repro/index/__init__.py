"""Approximate kNN index substrate (paper Sec. III).

AÇAI keeps two indexes at the edge server:
  * local catalog  (dynamic, h objects)  — graph index (NSW, HNSW-style)
    or flat scan: h is small enough that both are sub-millisecond.
  * remote catalog (static, N objects)   — IVF-Flat / IVF-PQ (FAISS-style)
    with compact codes, or LSH.

All indexes are JAX-native with static shapes (dense padded bucket tables,
fixed-width beams) so queries jit and shard; the TPU adaptations are
documented in DESIGN.md §3.  Builds run once in numpy/JAX at setup time.

Backend choice is one config knob (DESIGN.md §8): every class implements
the batched `Index` protocol and is registered in `repro.index.base`, so
`build_index(IndexSpec("ivf", {"nlist": 256}), catalog)` constructs any of
them uniformly — including the sharded `ivf_sharded` layout for the
multi-device serving path.
"""

from repro.index.base import (Index, IndexSpec, build_index,
                              parse_index_opts, register_backend,
                              registered_backends)
from repro.index.exact import FlatIndex
from repro.index.ivf import IVFFlatIndex
from repro.index.kmeans import kmeans
from repro.index.lsh import LSHIndex
from repro.index.nsw import NSWIndex
from repro.index.pq import IVFPQIndex, PQCodec
from repro.kernels import ops as _ops

_ops.register_tracked_jits()  # fold kernel scans into the compile tracker
del _ops

__all__ = [
    "FlatIndex",
    "IVFFlatIndex",
    "IVFPQIndex",
    "Index",
    "IndexSpec",
    "LSHIndex",
    "NSWIndex",
    "PQCodec",
    "build_index",
    "kmeans",
    "parse_index_opts",
    "register_backend",
    "registered_backends",
]
