"""PQ asymmetric-distance (ADC) scan — Pallas TPU kernel.

FAISS's IVF-PQ scan on GPU gathers 8-bit codes against per-query lookup
tables held in shared memory (one gather per (point, subspace)).  TPUs have
no efficient per-lane gather, so we ADAPT rather than port (DESIGN.md §3):
inside the kernel each subspace's codes are expanded to a one-hot matrix on
the fly and contracted against the LUT slice on the MXU:

    dist[q, n] = sum_m LUT[q, m, codes[n, m]]
               = sum_m ( LUT[:, m, :] @ onehot(codes[:, m])^T )[q, n]

This turns a memory-bound gather into C=256-wide matmuls — on TPU the MXU
is idle during a scan anyway, so the extra FLOPs are free and the kernel
stays HBM-bandwidth-bound on the (N, M) uint8 code stream, which is the
same bottleneck (and byte count) as the GPU original.

Blocks: (BQ=128 queries) x (BN=128 points); the full (BQ, M, 256) LUT tile
lives in VMEM (M=16, fp32 -> 2 MiB).  The M-loop is a fori_loop inside the
kernel so codes are touched once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BN = 128


def _adc_kernel(lut_ref, codes_ref, o_ref):
    lut = lut_ref[...]          # (BQ, M, C) float32
    codes = codes_ref[...]      # (BN, M) int32
    m = lut.shape[1]
    c = lut.shape[2]
    cols = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], c), 1)

    def body(mi, acc):
        onehot = (cols == codes[:, mi][:, None]).astype(jnp.float32)  # (BN, C)
        # (BQ, C) @ (C, BN) on the MXU
        return acc + jax.lax.dot_general(
            lut[:, mi, :], onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    acc = jnp.zeros((lut.shape[0], codes.shape[0]), jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, m, body, acc)


def pq_adc_pallas(
    lut: jax.Array, codes: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """lut (Q, M, C) float32, codes (N, M) int32 -> (Q, N) float32."""
    q, m, c = lut.shape
    n, m2 = codes.shape
    assert m == m2, (m, m2)
    assert q % BQ == 0 and n % BN == 0, (q, n)
    grid = (q // BQ, n // BN)
    return pl.pallas_call(
        _adc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BQ, m, c), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((BN, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BQ, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.float32),
        interpret=interpret,
    )(lut, codes.astype(jnp.int32))
