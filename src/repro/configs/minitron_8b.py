"""minitron-8b [dense] — pruned nemotron (arXiv:2407.14679).

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Squared-ReLU 2-matrix FFN (nemotron family) — with it the analytic count
lands on 8.2B, matching the advertised size.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    rope_theta=1e4,
    ffn_act="relu2",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    ffn_act="relu2",
)
