"""Synthetic datasets + request traces matching the paper's Sec. V-A setup.

SIFT1M and the Amazon crawl are not available offline; we generate
statistically matched stand-ins:

* `sift_like`   — N points ~ U[0,1]^d (SIFT descriptors are dense,
  roughly isotropic after whitening).  Requests follow the Independent
  Reference Model with lambda_i ∝ d_i^{-beta}, d_i = distance of object i
  from the catalog barycenter — the paper's exact construction — with beta
  calibrated so the ranked-popularity tail matches Zipf(0.9).
* `amazon_like` — Gaussian-mixture clustered embeddings (product
  categories) + a non-stationary request process: a slow random walk over
  cluster preferences (temporal drift of review traffic).

Both return (catalog (N,d), request embeddings (T,d), request ids (T,)).
Requests are *for catalog points* (the k=1 exact target exists), matching
the benchmark datasets where queries are held-out points of the same
distribution — we optionally jitter the request embedding.
"""

from __future__ import annotations

import numpy as np


def _zipf_calibrate_beta(dist_rank: np.ndarray, zipf_a: float = 0.9) -> float:
    """Pick beta so lambda ∝ d^{-beta} has a Zipf(a)-like ranked tail.

    Matching the log-log slope of the ranked popularity curve: if ranked
    distances grow ~ rank^gamma then lambda_(rank) ~ rank^(-beta*gamma); we
    want beta*gamma = a."""
    n = dist_rank.shape[0]
    ranks = np.arange(1, n + 1)
    sel = slice(n // 100 + 1, n // 2)  # fit the body, ignore head/tail noise
    gamma = np.polyfit(np.log(ranks[sel]), np.log(dist_rank[sel] + 1e-12), 1)[0]
    return float(zipf_a / max(gamma, 1e-3))


def sift_like(
    n: int = 20000,
    d: int = 32,
    t: int = 30000,
    zipf_a: float = 0.9,
    jitter: float = 0.0,
    seed: int = 0,
):
    rng = np.random.default_rng(seed)
    catalog = rng.random((n, d), dtype=np.float32)
    bary = catalog.mean(axis=0, keepdims=True)
    dist = np.linalg.norm(catalog - bary, axis=1)
    beta = _zipf_calibrate_beta(np.sort(dist))
    lam = (dist + 1e-9) ** (-beta)
    lam /= lam.sum()
    ids = rng.choice(n, size=t, p=lam)
    reqs = catalog[ids]
    if jitter > 0:
        reqs = reqs + rng.normal(0, jitter, reqs.shape).astype(np.float32)
    return catalog, reqs.astype(np.float32), ids


def amazon_like(
    n: int = 20000,
    d: int = 32,
    t: int = 30000,
    clusters: int = 50,
    drift: float = 0.05,
    seed: int = 1,
):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.0, (clusters, d)).astype(np.float32)
    assign = rng.integers(0, clusters, n)
    catalog = centers[assign] + rng.normal(0, 0.25, (n, d)).astype(np.float32)

    # non-stationary cluster preference random walk
    logits = rng.normal(0, 1.0, clusters)
    ids = np.empty(t, dtype=np.int64)
    members = [np.nonzero(assign == c)[0] for c in range(clusters)]
    members = [m if len(m) else np.array([0]) for m in members]
    # per-cluster popularity (Zipf within cluster)
    for step in range(t):
        logits += rng.normal(0, drift, clusters)
        p = np.exp(logits - logits.max())
        p /= p.sum()
        c = rng.choice(clusters, p=p)
        m = members[c]
        w = (np.arange(len(m)) + 1.0) ** -0.9
        ids[step] = m[rng.choice(len(m), p=w / w.sum())]
    return catalog, catalog[ids].copy(), ids


def ranked_popularity(ids: np.ndarray, n: int) -> np.ndarray:
    counts = np.bincount(ids, minlength=n).astype(np.float64)
    return np.sort(counts)[::-1]
