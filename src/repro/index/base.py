"""Unified index API: protocol, spec, and config-driven backend registry.

AÇAI's central design choice (paper Sec. III) is that the *same* policy
machinery works over any approximate index for the catalog — the index
only shapes the candidate-set quality/cost trade-off.  This module makes
that pluggability first-class:

* `Index` — the batched query protocol every backend implements.  A whole
  request mini-batch goes down in one call, so the policy's batched
  pipeline (DESIGN.md §6) never loops over requests.
* `IndexSpec` — a serializable (backend name + kwargs) description of an
  index, the one config knob that selects a backend end-to-end: in
  `AcaiConfig.index`, `SemanticCachedLM(index_spec=...)`,
  `launch/serve.py --remote-index/--index-opt`, the `backends` benchmark
  suite, and dry-run provenance records.
* `build_index(spec, catalog, mesh=None)` — the registry constructor.
  Single-device backends (`flat | ivf | ivfpq | lsh | nsw`) build from
  the catalog alone; sharded backends (`ivf_sharded`) additionally take
  the device mesh and return the structure the sharded step consumes
  (`repro.core.distributed.ShardedIVF`).

Backends register themselves via `register_backend`, so adding a new one
is a single registration — no cross-cutting edits in core/serve/launch.

Mutable catalogs (DESIGN.md §10): every registered backend additionally
implements the batched mutation contract —

* `add(vectors (B, d)) -> (B,) int32 row ids` — online insertion.  New
  rows are *appended* at the slab high-water mark (flat append, IVF/LSH
  list append with capacity doubling, IVF-PQ encode-on-insert, NSW
  incremental linking); row ids are assigned monotonically and **never
  recycled**, so stale references (policy state, payload tables, cached
  entries) can never alias a new object.
* `remove(ids)` — online expiry via tombstones: the rows flip in the
  (capacity,) `valid` mask, auxiliary structures are untouched, and every
  query path masks tombstoned rows so they can never surface (the fused
  scans fold them into their -1 invalid-slot convention).
* `refresh()` — periodic rebuild: re-derive the auxiliary structures
  (quantizers, inverted lists, buckets, graphs, entry points) from the
  live rows only, restoring fresh-build recall after heavy churn.  Row
  ids are stable across refresh (the slab is not compacted) — only the
  structures are.

Query paths take the mutable arrays (slab, mask, tables) as *runtime*
jit arguments, so add/remove/refresh at fixed capacity never retrace;
recompilation happens only when the capacity-doubling growth changes
array shapes — O(log growth) times over an index's lifetime.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Mapping, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class Index(Protocol):
    """Batched ANN index over a fixed catalog of `n` embeddings.

    Required surface (the conformance contract pinned by
    tests/test_index_api.py):

    * `query(rs (B, d), k) -> (dists (B, k), ids (B, k))` — per-query k
      best candidates, ascending float32 squared distances, int32 catalog
      row ids; **-1 marks underflow** (fewer than k real candidates, dist
      = +inf on those slots).  Must accept a whole request mini-batch —
      a (d,) vector is promoted to B = 1.
    * `exact_distances: bool` — True when returned distances are exact on
      the shared catalog embeddings, letting
      `repro.index.candidates.index_candidate_fn_batched` skip its exact
      re-rank.
    * `n: int` — catalog size (number of indexed objects).
    * `memory_bytes() -> int` — resident bytes of the index structures
      (embedding slab + tables/codes), the cost side of the paper's
      quality/cost trade-off.

    Mutation surface (implemented by every registered single-device
    backend; see the module docstring and tests/test_mutable_index.py):

    * `add(vectors (B, d)) -> (B,) int32 row ids` — append new objects.
    * `remove(ids)` — tombstone rows (mask-only; ids never recycled).
    * `refresh()` — rebuild auxiliary structures over the live rows.
    * `valid: (capacity,) bool` — the tombstone mask; `capacity: int` and
      `n_slots: int` (high-water mark) describe the slab; `n` counts
      *live* rows only.

    Optional: `shard(mesh)` — return a mesh-sharded equivalent consumed by
    `repro.core.distributed.make_step_sharded` (today only the IVF family
    implements the sharded layout, via the `ivf_sharded` backend).
    """

    exact_distances: bool

    def query(self, rs: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
        ...

    @property
    def n(self) -> int:
        ...

    def memory_bytes(self) -> int:
        ...


def arrays_bytes(*arrays) -> int:
    """Sum of .nbytes over the given arrays (None entries skipped)."""
    return int(sum(a.size * a.dtype.itemsize for a in arrays if a is not None))


def check_finite_queries(rs, where: str) -> None:
    """Query-path input hygiene: reject NaN/Inf query vectors with a clear
    error instead of letting them corrupt top-k and OMA state (a NaN query
    makes every distance NaN, which silently breaks the top-k masking and
    then poisons the subgradient forever).

    Host-side check only: under `jax.jit` tracing (the candidate
    generators call `Index.query` inside traced functions) the values are
    abstract, so the check is skipped — eager entry points
    (`AcaiCache.serve_update*`, `BaselinePolicy.serve_update*`, direct
    backend queries) are where poisoned inputs actually enter."""
    if isinstance(rs, jax.core.Tracer):
        return
    a = np.asarray(rs)
    if np.issubdtype(a.dtype, np.floating) and not np.isfinite(a).all():
        rows = (np.nonzero(~np.isfinite(a.reshape(a.shape[0], -1)).all(
            axis=1))[0].tolist() if a.ndim > 1 else [0])
        raise ValueError(
            f"{where}: query vector(s) contain NaN/Inf (rows {rows}) — "
            f"refusing to serve; sanitize the embedding upstream (a NaN "
            f"query would corrupt top-k and OMA state)")


# ---------------------------------------------------------------------------
# Mutable-catalog slab machinery (DESIGN.md §10, device path §14)
# ---------------------------------------------------------------------------

# Tracked-jit registry (the no-retrace guard, DESIGN.md §14): every jitted
# entry point on a mutation or mutable-query path registers here, and
# `tracked_compiles()` sums their compile-cache sizes.  A churn run at
# fixed capacity must not grow the sum after warmup — the guard test
# (tests/test_mutable_index.py) pins it so the device-resident path can
# never silently regress into per-event retracing.
_TRACKED_JITS: Dict[str, Any] = {}


def track_jit(name: str, fn=None):
    """Register a jitted callable under `name` for the no-retrace guard.
    Usable directly (`track_jit("q", jitted)`) or as a decorator factory
    (`@track_jit("q")` above the `@jax.jit`-decorated def)."""
    if fn is None:
        def deco(f):
            _TRACKED_JITS[name] = f
            return f
        return deco
    _TRACKED_JITS[name] = fn
    return fn


def tracked_compiles() -> int:
    """Total compiled-trace count across every tracked jit entry point."""
    return int(sum(f._cache_size() for f in _TRACKED_JITS.values()))


# Device-dispatch wall clock of the mutation fast path, accumulated by
# `run_device` (blocks until ready, so the number is honest on async
# backends).  `benchmarks/churn_bench.py` books it as `mutation_device_ms`
# next to the host-side bookkeeping remainder.
_device_mutation_s = 0.0


def device_mutation_seconds() -> float:
    """Cumulative wall seconds spent in mutation device dispatches."""
    return _device_mutation_s


def run_device(fn, *args):
    """Dispatch a (jitted) mutation update and account its device wall."""
    global _device_mutation_s
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    _device_mutation_s += time.perf_counter() - t0
    return out


# Mutation batches are padded to power-of-two widths (min MIN_WRITE) before
# they reach the donated device updates, so the jit cache holds O(log
# max-batch) entries per slab capacity instead of one per distinct event
# size — the difference between zero retraces under churn and one per event.
MIN_WRITE = 32


def bucket_width(b: int) -> int:
    """Smallest power-of-two >= max(b, MIN_WRITE)."""
    return max(MIN_WRITE, 1 << max(int(b) - 1, 0).bit_length())


def grow_capacity(n_slots: int, needed: int, cap: int) -> int:
    """Capacity-doubling growth schedule: the smallest power-of-two-style
    doubling of `cap` that fits `n_slots + needed` rows.  Doubling keeps
    reallocation (and the shape-driven jit retrace that comes with it)
    amortised O(log growth) over an index's lifetime."""
    new_cap = max(cap, 1)
    while n_slots + needed > new_cap:
        new_cap *= 2
    return new_cap


# -- donated device-slab primitives -----------------------------------------
#
# All three take the mutable buffer as a DONATED argument: XLA reuses its
# storage for the output, so a fixed-capacity update moves zero slab bytes
# and allocates nothing.  Donation invalidates the caller's old reference —
# every holder (MutableRows, AcaiCache.catalog/.valid) re-points to the
# returned array immediately, and nothing may read the donated buffer in
# between.  Writes use padded widths with out-of-range indices / clamped
# row tails landing in unused slab territory (callers guarantee
# capacity >= n_slots + W before dispatch; `lax.dynamic_update_slice`
# clamps, so an unguarded call would silently corrupt the tail rows).

@track_jit("slab_write")
@partial(jax.jit, donate_argnums=(0, 1))
def _slab_write(emb, valid, rows, start, count):
    """Write `rows` (W, d) at slab rows [start, start+W); rows past
    `count` land beyond the high-water mark (unused, overwritten by the
    next append) and their validity bits keep their old value."""
    emb = jax.lax.dynamic_update_slice(emb, rows, (start, 0))
    w = rows.shape[0]
    upd = jnp.arange(w, dtype=jnp.int32) < count
    old = jax.lax.dynamic_slice(valid, (start,), (w,))
    valid = jax.lax.dynamic_update_slice(valid, old | upd, (start,))
    return emb, valid


@track_jit("rows_write")
@partial(jax.jit, donate_argnums=(0,))
def _rows_write(buf, rows, start):
    """Contiguous row write into any (cap, ...) auxiliary slab (PQ codes,
    NSW out-edge rows): rows beyond the real batch land in unused rows."""
    return jax.lax.dynamic_update_slice(
        buf, rows, (start,) + (0,) * (buf.ndim - 1))


@track_jit("flat_set")
@partial(jax.jit, donate_argnums=(0,))
def _flat_set(buf, idx, vals):
    """Scattered element writes into any device table via flat indices;
    pad slots use idx >= buf.size and are dropped (mode='drop')."""
    shape = buf.shape
    return buf.reshape(-1).at[idx].set(vals, mode="drop").reshape(shape)


@track_jit("mask_clear")
@partial(jax.jit, donate_argnums=(0,))
def _mask_clear(valid, ids):
    """Tombstone scatter: pad slots use ids >= capacity (dropped)."""
    return valid.at[ids].set(False, mode="drop")


@track_jit("mask_gather")
@jax.jit
def _mask_gather(valid, ids):
    """Padded aliveness gather (validation reads W bools, not the mask)."""
    return valid[jnp.clip(ids, 0, valid.shape[0] - 1)]


def pad_ids(ids: np.ndarray, fill: int) -> jax.Array:
    """Pad an id batch to its power-of-two bucket width with `fill`
    (callers pass an out-of-range index so drop-mode scatters skip it)."""
    ids = np.asarray(ids, np.int32)
    w = bucket_width(len(ids))
    out = np.full((w,), fill, np.int32)
    out[:len(ids)] = ids
    return jnp.asarray(out)


def pad_rows(rows: np.ndarray, dtype=np.float32) -> jax.Array:
    """Pad a (B, ...) row batch to its bucket width with zero rows."""
    rows = np.atleast_2d(np.asarray(rows, dtype))
    w = bucket_width(rows.shape[0])
    if w == rows.shape[0]:
        return jnp.asarray(rows)
    out = np.zeros((w,) + rows.shape[1:], dtype)
    out[:rows.shape[0]] = rows
    return jnp.asarray(out)


def slab_append(emb: jax.Array, valid: jax.Array, n_slots: int,
                vectors) -> Tuple[jax.Array, jax.Array, np.ndarray]:
    """Append rows to a capacity slab, growing by doubling when full.

    The write is the donated device fast path (DESIGN.md §14): the batch
    is padded to its power-of-two bucket width and written with one
    `dynamic_update_slice` into the donated slab, so churn at fixed
    capacity triggers zero retraces and moves zero slab bytes.  The
    passed-in `emb`/`valid` buffers are DONATED whenever no growth
    reallocation happens first — callers must treat them as consumed and
    use only the returned arrays.

    Args:
      emb: (cap, d) float32 embedding slab (rows >= n_slots are unused).
      valid: (cap,) bool liveness mask (False on unused + tombstoned rows).
      n_slots: current high-water mark (rows ever assigned).
      vectors: (B, d) new embeddings.

    Returns:
      (emb', valid', ids): the (possibly grown) slab and mask with the new
      rows written and marked live, plus their assigned row ids
      (np.int32 (B,), = arange(n_slots, n_slots + B)).  Ids are never
      recycled — tombstoned slots stay dead until compaction.
    """
    vec_np = np.atleast_2d(np.asarray(vectors, np.float32))
    b = vec_np.shape[0]
    w = bucket_width(b)
    cap = emb.shape[0]
    if n_slots + w > cap:  # headroom for the padded write (the clamp guard)
        new_cap = grow_capacity(n_slots, w, cap)
        emb = jnp.pad(emb, ((0, new_cap - cap), (0, 0)))
        valid = jnp.pad(valid, (0, new_cap - cap), constant_values=False)
    emb, valid = run_device(_slab_write, emb, valid, pad_rows(vec_np),
                            np.int32(n_slots), np.int32(b))
    ids = np.arange(n_slots, n_slots + b, dtype=np.int32)
    return emb, valid, ids


class MutableRows:
    """Capacity-slab + tombstone bookkeeping shared by every backend.

    Owns `embeddings` (capacity, d), `valid` (capacity,) bool, the
    high-water mark `n_slots` and the live count `n` — the state side of
    the mutation contract.  Backends call `_append_rows` from `add` (slab
    growth + id assignment) and `_tombstone_rows` from `remove`, and add
    their structure-specific bookkeeping on top.

    Device-resident mutation (DESIGN.md §14): both primitives route
    through the donated slab writes above — at fixed capacity a mutation
    is one padded device dispatch with zero retraces and zero slab-sized
    transfers.  Subclasses with auxiliary structures additionally get the
    two-phase refresh (`refresh_start` computes a shadow while the stale
    structures keep serving; `refresh_swap` installs it — the only
    serving-visible stall) and `compact()` (epoch compaction: rebuild the
    slab over the live rows only and return the old→new id remap).
    """

    embeddings: jax.Array
    valid: jax.Array

    def _init_rows(self, embeddings) -> None:
        self.embeddings = jnp.atleast_2d(
            jnp.asarray(embeddings, jnp.float32))
        self._n_slots = int(self.embeddings.shape[0])
        self._live = self._n_slots
        self.valid = jnp.ones((self._n_slots,), bool)
        self._shadow = None  # pending two-phase-refresh structures

    @property
    def n(self) -> int:
        """Live (indexed, non-tombstoned) objects."""
        return self._live

    @property
    def capacity(self) -> int:
        """Slab rows allocated (= the id-space bound every query result
        respects; grows by doubling)."""
        return int(self.embeddings.shape[0])

    @property
    def n_slots(self) -> int:
        """High-water mark: rows ever assigned (live + tombstoned)."""
        return self._n_slots

    def live_rows(self) -> np.ndarray:
        """Row ids of the live objects, ascending (refresh rebuilds walk
        this order so a refreshed structure matches a fresh build on the
        live rows modulo the id remap)."""
        return np.nonzero(np.asarray(self.valid))[0]

    def _append_rows(self, vectors) -> np.ndarray:
        self.embeddings, self.valid, ids = slab_append(
            self.embeddings, self.valid, self._n_slots, vectors)
        self._n_slots += len(ids)
        self._live += len(ids)
        # a pending shadow predates these rows: installing it would make
        # them unfindable — discard (the churn driver's boundary ordering
        # guarantees no mutation between start and swap, so this only
        # fires on out-of-order direct API use)
        self._shadow = None
        return ids

    def _tombstone_rows(self, ids) -> np.ndarray:
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if len(ids) == 0:
            return ids
        if ids.min() < 0 or ids.max() >= self._n_slots:
            raise ValueError(
                f"remove: ids must be assigned rows in [0, {self._n_slots});"
                f" got range [{ids.min()}, {ids.max()}]")
        if len(np.unique(ids)) != len(ids):
            raise ValueError("remove: duplicate ids in one batch")
        padded = pad_ids(ids, self.capacity)  # pad slots dropped by OOB
        alive = np.asarray(run_device(_mask_gather, self.valid,
                                      padded))[:len(ids)]
        if not alive.all():
            raise ValueError(
                f"remove: rows {ids[~alive].tolist()} are already dead "
                f"(tombstoned or never assigned)")
        self.valid = run_device(_mask_clear, self.valid, padded)
        self._live -= len(ids)
        self._shadow = None  # see _append_rows
        return ids

    def add(self, vectors) -> np.ndarray:
        """Default `add`: slab append only (structure-free backends)."""
        return self._append_rows(vectors)

    def remove(self, ids) -> None:
        """Tombstone `ids` (mask-only: every query path filters through
        `valid`, so the rows can never surface again)."""
        self._tombstone_rows(ids)

    # -- two-phase refresh (DESIGN.md §14) ----------------------------------

    def _compute_structures(self):
        """Backend hook: derive fresh auxiliary structures from the live
        rows WITHOUT touching serving state (returns an opaque bundle for
        `_install_structures`; None = structure-free backend)."""
        return None

    def _install_structures(self, structures) -> None:
        """Backend hook: atomically install a `_compute_structures`
        bundle (attribute assignments only — this is the whole stall)."""

    def _build_structures(self) -> None:
        """Blocking rebuild = compute + install (constructor/compact)."""
        s = self._compute_structures()
        if s is not None:
            self._install_structures(s)

    def refresh_start(self) -> None:
        """Phase 1 of the double-buffered refresh: rebuild the auxiliary
        structures into a shadow while the stale ones keep serving —
        queries between start and swap are bitwise the stale index."""
        self._shadow = self._compute_structures()

    def refresh_swap(self) -> None:
        """Phase 2: install the shadow (a handful of attribute swaps —
        the only serving-visible stall).  No-op without a pending shadow
        (it is discarded by any interleaved mutation)."""
        s, self._shadow = self._shadow, None
        if s is not None:
            self._install_structures(s)

    @property
    def refresh_pending(self) -> bool:
        """True between `refresh_start()` and the installing swap."""
        return self._shadow is not None

    def refresh(self) -> None:
        """Blocking refresh: both phases back to back (structure-free
        backends rebuild nothing and this stays a no-op)."""
        self.refresh_start()
        self.refresh_swap()

    # -- epoch compaction (DESIGN.md §14) -----------------------------------

    @property
    def answer_stable_compact(self) -> bool:
        """True when `compact()` changes nothing but row numbering, so an
        answer cache may remap its stored ids instead of flushing.  Only
        structure-free backends qualify: any backend that overrides
        `_compute_structures` re-derives its auxiliaries over the live
        set at compaction (IVF re-trains k-means, LSH re-draws bucket
        membership under truncation caps), which can change answers
        beyond the id remap — the same answer-changing rebuild that
        forces a flush on refresh."""
        return (type(self)._compute_structures
                is MutableRows._compute_structures)

    def compact(self) -> np.ndarray:
        """Epoch compaction: rebuild the slab over the live rows only, in
        ascending slab order, and rebuild the auxiliary structures on the
        compacted ids.  Returns the explicit old→new id remap
        ((old_capacity,) int32, -1 on dead/unused rows) that every id
        holder (OMA state, payload tables, answer caches, oracles) must
        apply.  The remap is order-preserving, so backends whose answers
        depend only on per-row distances and index-order tie-breaks
        answer identically modulo the remap."""
        live = self.live_rows()
        n_live = int(live.size)
        remap = np.full(self.capacity, -1, np.int32)
        remap[live] = np.arange(n_live, dtype=np.int32)
        # smallest doubling capacity with one write-bucket of headroom, so
        # the next append does not immediately regrow
        cap = grow_capacity(0, n_live + MIN_WRITE, 1)
        emb_live = self.embeddings[jnp.asarray(live)]
        self.embeddings = jnp.pad(emb_live, ((0, cap - n_live), (0, 0)))
        self.valid = jnp.pad(jnp.ones((n_live,), bool), (0, cap - n_live))
        self._n_slots = n_live
        self._live = n_live
        self._shadow = None
        self._build_structures()
        return remap


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Serializable index selection: backend name + build kwargs.

    `params` are passed verbatim to the registered builder (after the
    catalog / mesh), so valid keys are exactly the builder's keyword
    arguments — e.g. ``IndexSpec("ivf", {"nlist": 256, "nprobe": 16})``.

    Round-trips through a flat dict (`to_dict` / `from_dict`) so a spec
    can live in CLI flags, benchmark rows and dry-run records:
    ``{"backend": "ivf", "nlist": 256, "nprobe": 16}``.
    """

    backend: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))
        if "backend" in self.params:
            raise ValueError("'backend' is the spec field, not a param")

    def __hash__(self):  # params is a dict; hash the canonical item tuple
        return hash((self.backend, tuple(sorted(self.params.items()))))

    def to_dict(self) -> Dict[str, Any]:
        """Flat dict form: {'backend': name, **params}."""
        return {"backend": self.backend, **self.params}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "IndexSpec":
        d = dict(d)
        try:
            backend = d.pop("backend")
        except KeyError:
            raise ValueError(f"index spec dict needs a 'backend' key: {d}")
        spec = cls(backend, d)
        if backend not in _REGISTRY:
            raise ValueError(_unknown_backend_msg(backend))
        return spec

    def with_params(self, **updates) -> "IndexSpec":
        return IndexSpec(self.backend, {**self.params, **updates})


@dataclasses.dataclass(frozen=True)
class _Backend:
    build: Callable
    sharded: bool  # builder signature is (catalog, mesh, **params)


_REGISTRY: Dict[str, _Backend] = {}


def register_backend(name: str, *, sharded: bool = False):
    """Decorator registering `fn(catalog, **params)` (or
    `fn(catalog, mesh, **params)` when sharded=True) under `name`."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"index backend {name!r} already registered")
        _REGISTRY[name] = _Backend(fn, sharded)
        return fn

    return deco


def registered_backends(*, sharded: bool | None = None) -> Tuple[str, ...]:
    """Sorted backend names; filter by shardedness when given."""
    return tuple(sorted(
        name for name, b in _REGISTRY.items()
        if sharded is None or b.sharded == sharded
    ))


def _unknown_backend_msg(name: str) -> str:
    return (f"unknown index backend {name!r}; registered: "
            f"{', '.join(registered_backends())}")


def build_index(spec: IndexSpec, catalog: jax.Array, mesh=None):
    """Construct the index a spec describes over `catalog`.

    Single-device backends ignore `mesh`; sharded backends require it
    (their layout depends on the mesh's `model` axis size).  Unknown
    backends and bad params raise ValueError/TypeError at build time —
    before any jit tracing.
    """
    if isinstance(spec, Mapping):  # accept the flat-dict form directly
        spec = IndexSpec.from_dict(spec)
    try:
        backend = _REGISTRY[spec.backend]
    except KeyError:
        raise ValueError(_unknown_backend_msg(spec.backend))
    if backend.sharded:
        if mesh is None:
            raise ValueError(
                f"index backend {spec.backend!r} is sharded: build_index "
                f"needs the device mesh (mesh=...)")
        return backend.build(catalog, mesh, **spec.params)
    return backend.build(catalog, **spec.params)


# Reserved spec-less backend name: "exact" means *no* index — the policy's
# perfect-recall exact candidate generator (one GEMM feeding both slabs).
# It is deliberately not in the registry (there is nothing to build);
# surfaces that accept serialized specs resolve it through `resolve_spec`.
EXACT = "exact"


def resolve_spec(value) -> "IndexSpec | None":
    """Normalize any user-facing spec form to IndexSpec-or-None.

    Accepts None, an IndexSpec, a backend-name string, or the flat dict
    form — with the reserved name "exact" (however spelled) mapping to
    None, so provenance records like ``{"backend": "exact"}`` round-trip
    through every surface (SemanticCachedLM, CLI, dryrun records).
    """
    if isinstance(value, IndexSpec):
        if value.backend != EXACT:
            if value.backend not in _REGISTRY:
                raise ValueError(_unknown_backend_msg(value.backend))
            return value
        if value.params:
            raise ValueError(
                f"'exact' takes no params (it is the spec-less exact "
                f"candidate generator): {value.params}")
        return None
    if value is None:
        return None
    if isinstance(value, str):
        if value == EXACT:
            return None
        if value not in _REGISTRY:
            raise ValueError(_unknown_backend_msg(value))
        return IndexSpec(value)
    if isinstance(value, Mapping):
        if value.get("backend") == EXACT:
            if len(value) > 1:
                raise ValueError(
                    f"'exact' takes no params (it is the spec-less exact "
                    f"candidate generator): {dict(value)}")
            return None
        return IndexSpec.from_dict(value)
    raise TypeError(f"cannot resolve an index spec from {value!r}")


def parse_index_opts(opts) -> Dict[str, Any]:
    """Parse CLI `--index-opt key=value` pairs into builder kwargs.

    Values are coerced int -> float -> str in that order, so
    `nlist=256 refine=0 kernel=xla` all land with their natural types.
    """
    out: Dict[str, Any] = {}
    for opt in opts or ():
        key, sep, val = opt.partition("=")
        if not sep or not key:
            raise ValueError(
                f"--index-opt expects key=value, got {opt!r}")
        for cast in (int, float):
            try:
                out[key] = cast(val)
                break
            except ValueError:
                continue
        else:
            out[key] = val
    return out


# ---------------------------------------------------------------------------
# Backend registrations.  Builders are thin closures over the class
# constructors so the registry maps spec params 1:1 onto constructor
# kwargs; `ivf_sharded` imports lazily (repro.core.distributed imports the
# policy layer, which imports this module).
# ---------------------------------------------------------------------------

@register_backend("flat")
def _build_flat(catalog, **kw):
    from repro.index.exact import FlatIndex

    return FlatIndex(catalog, **kw)


@register_backend("ivf")
def _build_ivf(catalog, **kw):
    from repro.index.ivf import IVFFlatIndex

    return IVFFlatIndex(catalog, **kw)


@register_backend("ivfpq")
def _build_ivfpq(catalog, **kw):
    from repro.index.pq import IVFPQIndex

    return IVFPQIndex(catalog, **kw)


@register_backend("lsh")
def _build_lsh(catalog, **kw):
    from repro.index.lsh import LSHIndex

    return LSHIndex(catalog, **kw)


@register_backend("nsw")
def _build_nsw(catalog, **kw):
    from repro.index.nsw import NSWIndex

    return NSWIndex(catalog, **kw)


@register_backend("ivf_sharded", sharded=True)
def _build_ivf_sharded(catalog, mesh, *, model_axis: str = "model", **kw):
    """Per-shard IVF for the sharded serving path: one coarse quantizer +
    inverted-list table per catalog shard on the mesh's `model` axis.
    Returns `repro.core.distributed.ShardedIVF` (consumed via
    `make_step_sharded(ivf=...)` / `AcaiCache(mesh=..., cfg.index=...)`)."""
    from repro.core.distributed import build_sharded_ivf

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if model_axis not in sizes:
        raise ValueError(
            f"ivf_sharded shards over mesh axis {model_axis!r}, but the "
            f"mesh has axes {tuple(sizes)} — pass model_axis=<axis name> "
            f"in the spec params or rename the mesh axis")
    return build_sharded_ivf(catalog, sizes[model_axis], **kw)


# Smallest sensible build kwargs per single-device backend (seconds to
# build on a few-hundred-row catalog).  The single source of truth for
# the conformance test (tests/test_index_api.py) and the scripts/smoke.sh
# sweep — a new backend registers here once and both pick it up.
TINY_BUILD_KWARGS = {
    "flat": {},
    "ivf": {"nlist": 8, "nprobe": 4},
    "ivfpq": {"nlist": 8, "nprobe": 4, "m": 4, "refine": 4},
    "lsh": {"tables": 4, "bits": 5},
    "nsw": {"degree": 8, "beam": 16, "steps": 8},
}
