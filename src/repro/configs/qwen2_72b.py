"""qwen2-72b [dense] — GQA with QKV bias (arXiv:2407.10671).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
Adafactor: fp32-Adam state for 72B exceeds the per-chip HBM budget
(DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    fsdp=True,
    optimizer="adafactor",
)

SMOKE = ModelConfig(
    name="qwen2-72b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    qkv_bias=True, optimizer="adafactor",
)
