"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: the
production mesh is built from 512 host-platform placeholder devices, every
cell's step function is pjit-lowered with full shardings, compiled, and the
compiled artifact is mined for the roofline terms (FLOPs, bytes, per-class
collective bytes, per-device memory).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
Cells already present in --out are skipped (resumable).
"""

# The VERY FIRST lines — before ANY other import (jax locks the device
# count on first init).  Do NOT set this globally: tests/benches must see
# one device.
import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402

import jax        # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, SHAPES, SMOKE_ARCHS, SMOKE_SHAPES, runnable  # noqa: E402
from repro.launch.mesh import (batch_axes, make_host_mesh,  # noqa: E402
                               make_production_mesh, mesh_shape_dict)
from repro.models import init_cache, init_params  # noqa: E402
from repro.serve.engine import make_decode_step, make_prefill  # noqa: E402
from repro.sharding import specs as S  # noqa: E402
from repro.sharding.ctx import mesh_context  # noqa: E402
from repro.train import OptConfig, make_train_step  # noqa: E402
from repro.train.batching import input_specs  # noqa: E402
from repro.train.optimizer import init_opt  # noqa: E402

def sharded_bytes(shape_tree, pspec_tree, mesh_shape: dict) -> int:
    """Per-device bytes of a sharded pytree (analytic)."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(shape_tree),
                          jax.tree.leaves(pspec_tree,
                                          is_leaf=lambda x: isinstance(
                                              x, jax.sharding.PartitionSpec))):
        shards = 1
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                shards *= mesh_shape.get(a, 1)
        total += leaf.size * leaf.dtype.itemsize // max(shards, 1)
    return total


def _accum_for(cfg, shape) -> int:
    if shape.kind != "train":
        return 1
    if shape.global_batch >= 64:
        return 8
    return 1


def build_lowering(cfg, shape, mesh, multi_pod: bool):
    mesh_shape = mesh_shape_dict(mesh)
    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    pspecs = S.param_pspecs(cfg, params_shape, mesh_shape)
    param_sh = S.as_shardings(mesh, pspecs)
    bspecs = input_specs(cfg, shape)
    bp = S.batch_pspecs(cfg, bspecs, multi_pod, mesh_shape)
    batch_sh = S.as_shardings(mesh, bp)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    info = {"params_bytes_per_device": sharded_bytes(params_shape, pspecs,
                                                     mesh_shape)}

    with mesh_context(mesh, batch_axes(multi_pod)):
        if shape.kind == "train":
            opt_shape = jax.eval_shape(partial(init_opt, cfg.optimizer),
                                       params_shape)
            ospecs = S.opt_pspecs(cfg.optimizer, params_shape, pspecs, cfg,
                                  mesh_shape)
            opt_sh = S.as_shardings(mesh, ospecs)
            info["opt_bytes_per_device"] = sharded_bytes(opt_shape, ospecs,
                                                         mesh_shape)
            accum = _accum_for(cfg, shape)
            step = make_train_step(cfg, OptConfig(name=cfg.optimizer), accum,
                                   grad_shardings=param_sh)
            jitted = jax.jit(step,
                             in_shardings=(param_sh, opt_sh, batch_sh, repl),
                             out_shardings=(param_sh, opt_sh, None))
            lowered = jitted.lower(params_shape, opt_shape, bspecs, 0)
            info["accum"] = accum
        elif shape.kind == "prefill":
            cache_shape = jax.eval_shape(
                partial(init_cache, cfg, shape.global_batch, shape.seq_len))
            cspecs = S.cache_pspecs(cfg, cache_shape, mesh_shape, multi_pod)
            cache_sh = S.as_shardings(mesh, cspecs)
            info["cache_bytes_per_device"] = sharded_bytes(
                cache_shape, cspecs, mesh_shape)
            fn = make_prefill(cfg, shape.seq_len)
            jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh, cache_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params_shape, bspecs, cache_shape)
        else:  # decode
            cache_shape = jax.eval_shape(
                partial(init_cache, cfg, shape.global_batch, shape.seq_len))
            cspecs = S.cache_pspecs(cfg, cache_shape, mesh_shape, multi_pod)
            cache_sh = S.as_shardings(mesh, cspecs)
            info["cache_bytes_per_device"] = sharded_bytes(
                cache_shape, cspecs, mesh_shape)
            fn = make_decode_step(cfg)
            args = [params_shape, cache_shape, bspecs["tokens"],
                    jax.ShapeDtypeStruct((), np.int32)]
            in_sh = [param_sh, cache_sh, batch_sh["tokens"], repl]
            kwargs = {}
            if "positions3" in bspecs:
                kwargs["positions3"] = bspecs["positions3"]
                fn2 = lambda p, c, t, l, positions3: fn(  # noqa: E731
                    p, c, t, l, positions3=positions3)
                jitted = jax.jit(
                    fn2, in_shardings=tuple(in_sh) + (batch_sh["positions3"],),
                    out_shardings=(None, None, cache_sh))
                lowered = jitted.lower(*args, bspecs["positions3"])
            else:
                jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                                 out_shardings=(None, None, cache_sh))
                lowered = jitted.lower(*args)
    return lowered, info


def analyse(lowered, compiled, info: dict, n_devices: int) -> dict:
    out = dict(info)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        out["cost_analysis"] = {k: float(v) for k, v in cost.items()
                                if np.isscalar(v)}
    except Exception as e:  # noqa: BLE001
        out["cost_analysis_error"] = str(e)
    try:
        mem = compiled.memory_analysis()
        if mem is not None:
            out["memory_analysis"] = {
                a: float(getattr(mem, a))
                for a in dir(mem)
                if not a.startswith("_")
                and np.isscalar(getattr(mem, a, None))
            }
    except Exception as e:  # noqa: BLE001
        out["memory_analysis_error"] = str(e)
    try:
        text = compiled.as_text()
        from repro.launch import hlo_analysis
        s = hlo_analysis.summarize(text)
        out["hlo"] = {
            "flops_per_device": s.flops,
            "hbm_bytes_per_device": s.bytes,
            "collective_bytes_per_shard": s.coll_bytes,
            "collective_counts": s.coll_counts,
            "loops": s.loops,
        }
        out["collective_bytes_per_shard_total"] = float(
            sum(s.coll_bytes.values()))
        out["hlo_lines"] = text.count("\n")
    except Exception as e:  # noqa: BLE001
        out["collectives_error"] = str(e)
    out["n_devices"] = n_devices
    return out


def apply_variant(cfg, variant: str, multi_pod: bool):
    """'opt' = the confirmed §Perf beyond-baseline configuration:
      * shard_map expert-weights-stationary MoE (moe_dp) — kills the
        partitioner's replicate-and-all-reduce of the expert buffers,
      * MLA absorbed decode — latent-space attention for deepseek decode.
    Flash-threshold lowering and the chunked retrieval scan were measured
    and REFUTED at the XLA level (EXPERIMENTS.md §Perf iterations B.1/C.1)
    — the real memory wins need the Pallas kernels
    (cfg.use_pallas_attention / FlatIndex(kernel='pallas') on TPU), which
    CPU dry-runs cannot compile; they are accounted analytically."""
    if variant != "opt":
        return cfg
    import dataclasses
    dp = 32 if multi_pod else 16
    return dataclasses.replace(cfg,
                               moe_dp=dp if cfg.n_experts else 0,
                               mla_absorbed_decode=cfg.attn_type == "mla",
                               replicate_misaligned_heads=True)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             smoke: bool = False, variant: str = "baseline") -> dict:
    cfgs = SMOKE_ARCHS if smoke else ARCHS
    shapes = SMOKE_SHAPES if smoke else SHAPES
    cfg, shape = cfgs[arch], shapes[shape_name]
    cfg = apply_variant(cfg, variant, mesh_kind == "multi")
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "kind": shape.kind, "seq_len": shape.seq_len,
              "global_batch": shape.global_batch,
              "variant": variant,
              "params_total": cfg.param_count(),
              "params_active": cfg.active_param_count()}
    ok, reason = runnable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        return record
    if smoke:
        mesh = make_host_mesh()
        multi_pod = False
    else:
        multi_pod = mesh_kind == "multi"
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        lowered, info = build_lowering(cfg, shape, mesh, multi_pod)
        record["lower_seconds"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_seconds"] = time.time() - t1
        record.update(analyse(lowered, compiled, info, mesh.devices.size))
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_seconds"] = time.time() - t0
    return record


def acai_cell_meta(mesh_kind: str, *, n_catalog: int, d: int, batch: int,
                  k: int, h: int, eta: float, variant: str) -> dict:
    """Static provenance fields of the AÇAI retrieval cell, kept in a
    helper so tests can pin the record schema without compiling on a
    512-device mesh (tests/test_policy_api.py)."""
    from repro.compat import SHARD_MAP_IMPL
    from repro.core.policy_api import PolicySpec

    return {"arch": "acai-retrieval", "shape": f"retrieval_b{batch}",
            "mesh": mesh_kind, "kind": "serve", "variant": variant,
            "seq_len": n_catalog, "global_batch": batch,
            "params_total": n_catalog * d, "params_active": n_catalog * d,
            # which shard_map the compat shim resolved (provenance: the
            # cell lowers on both the jax.shard_map and the experimental
            # API — see repro/compat.py)
            "shard_map_impl": SHARD_MAP_IMPL,
            # index selection provenance (DESIGN.md §8): the cell lowers
            # the exact per-shard scan — 'exact' is the spec-less
            # perfect-recall configuration, same convention as
            # launch/serve.py --remote-index exact.  An approximate cell
            # would carry e.g. IndexSpec("ivf_sharded", ...).to_dict().
            "index_spec": {"backend": "exact"},
            # policy selection provenance (DESIGN.md §9), the twin knob:
            # the cell lowers AÇAI's OMA retrieval step with these
            # hyper-parameters — same serialized form as
            # launch/serve.py --policy / --policy-opt.  c_f is included
            # so the record is self-contained (round-trips into
            # AcaiCache(catalog, PolicySpec.from_dict(...))).
            "policy_spec": PolicySpec(
                "acai", {"h": h, "k": k, "eta": eta, "c_f": 1.0,
                         "batch": batch}).to_dict()}


def run_acai_cell(mesh_kind: str, *, n_catalog: int = 2 ** 27, d: int = 128,
                  batch: int = 4096, c: int = 64, k: int = 10,
                  h: int = 2 ** 20, variant: str = "baseline") -> dict:
    """The paper-representative cell: one distributed AÇAI retrieval +
    OMA-update step over a 134M-object catalog sharded on the mesh."""
    from repro.core.distributed import make_retrieval_step

    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    msd = mesh_shape_dict(mesh)
    n_model = msd["model"]
    n_shard = n_catalog // n_model
    eta = 1e-2
    record = acai_cell_meta(mesh_kind, n_catalog=n_catalog, d=d, batch=batch,
                            k=k, h=h, eta=eta, variant=variant)
    t0 = time.time()
    try:
        # NOTE: the chunked-scan variant was measured and refuted (§Perf
        # C.1); the retrieval memory win is the Pallas l2_topk kernel on
        # TPU, so the XLA-level lowering is identical for both variants.
        step = make_retrieval_step(
            mesh, n_shard=n_shard, d=d, c=c, k=k, c_f=1.0, h=h, eta=eta,
            top_a=4096, batch_axes=batch_axes(multi_pod), scan_chunk=0)
        from jax.sharding import NamedSharding, PartitionSpec as P
        cat_sh = NamedSharding(mesh, P("model", None))
        y_sh = NamedSharding(mesh, P("model"))
        req_sh = NamedSharding(mesh, P(batch_axes(multi_pod), None))
        jitted = jax.jit(step, in_shardings=(cat_sh, y_sh, req_sh))
        lowered = jitted.lower(
            jax.ShapeDtypeStruct((n_catalog, d), np.float32),
            jax.ShapeDtypeStruct((n_catalog,), np.float32),
            jax.ShapeDtypeStruct((batch, d), np.float32))
        record["lower_seconds"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_seconds"] = time.time() - t1
        info = {"params_bytes_per_device": n_catalog * d * 4 // n_model}
        record.update(analyse(lowered, compiled, info, mesh.devices.size))
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_seconds"] = time.time() - t0
    return record


def cell_path(out_dir, arch, shape, mesh_kind):
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt"])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.arch == "acai-retrieval" or args.all:
        for mesh_kind in meshes:
            path = cell_path(args.out, "acai-retrieval", "retrieval_b4096",
                             mesh_kind)
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"[cached] acai-retrieval {mesh_kind}")
                        continue
            rec = run_acai_cell(mesh_kind, variant=args.variant)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[{rec['status']:7s}] acai-retrieval {mesh_kind} "
                  f"({rec.get('total_seconds', 0):.0f}s) "
                  f"{rec.get('error', '')}", flush=True)
        if args.arch == "acai-retrieval":
            return

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = cell_path(args.out, arch, shape, mesh_kind)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} {shape} {mesh_kind}: "
                              f"{prev['status']}")
                        continue
                rec = run_cell(arch, shape, mesh_kind, args.out,
                               smoke=args.smoke, variant=args.variant)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                msg = rec.get("reason") or rec.get("error", "")
                print(f"[{rec['status']:7s}] {arch} {shape} {mesh_kind} "
                      f"({rec.get('total_seconds', 0):.0f}s) {msg}",
                      flush=True)


if __name__ == "__main__":
    main()
