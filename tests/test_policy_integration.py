"""End-to-end behaviour: AÇAI replay, baselines comparison, regret decay."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import baselines as B
from repro.core import oma, policy, trace
from repro.core.costs import calibrate_fetch_cost
from repro.index.candidates import index_candidate_fn
from repro.index import IVFFlatIndex


@pytest.fixture(scope="module")
def setup():
    catalog, reqs, ids = trace.sift_like(n=1500, d=16, t=2500, seed=0)
    cat = jnp.array(catalog)
    c_f = float(calibrate_fetch_cost(cat, kth=50, sample=256))
    return catalog, reqs, cat, c_f


def _run_acai(cat, reqs, c_f, h=80, k=10, **oma_kw):
    cfg = policy.AcaiConfig(
        h=h, k=k, c_f=c_f, c_remote=64, c_local=16,
        oma=oma.OMAConfig(eta=oma_kw.pop("eta", 0.05 / c_f), **oma_kw),
    )
    fn = policy.exact_candidate_fn(cat, cfg.c_remote, cfg.c_local)
    replay = policy.make_replay(cfg, fn)
    state, m = replay(policy.init_state(cat.shape[0], cfg), jnp.array(reqs))
    return np.array(m.gain_int), state, m, cfg


def test_acai_beats_all_baselines(setup):
    catalog, reqs, cat, c_f = setup
    h, k = 80, 10
    gains, _, m, cfg = _run_acai(cat, reqs, c_f, h=h, k=k)
    nag_acai = B.nag(gains, k, c_f)[-1]

    oracle = B.ServerOracle(catalog, reqs, kmax=64)
    for name, cls in B.POLICIES.items():
        kwargs = dict(h=h, k=k, c_f=c_f)
        if name in ("SIM-LRU", "CLS-LRU", "RND-LRU"):
            kwargs.update(k_prime=2 * k, c_theta=1.5 * c_f)
        mtr = B.run_policy(cls(catalog, oracle, **kwargs), reqs)
        nag_p = B.nag(mtr["gain"], k, c_f)[-1]
        assert nag_acai > nag_p, (name, nag_acai, nag_p)


def test_gain_curve_stabilises(setup):
    catalog, reqs, cat, c_f = setup
    gains, _, m, cfg = _run_acai(cat, reqs, c_f)
    nag = B.nag(gains, 10, c_f)
    # paper Fig. 1: almost stationary after a few thousand requests,
    # and far above the cold start
    assert nag[-1] > nag[100]
    assert abs(nag[-1] - nag[-500]) < 0.05


def test_served_answers_increasingly_local(setup):
    catalog, reqs, cat, c_f = setup
    _, _, m, cfg = _run_acai(cat, reqs, c_f)
    served = np.array(m.served_local)
    assert served[-500:].mean() > served[:200].mean()
    assert served[-500:].mean() > 5  # most of k=10 served locally at steady state


def test_depround_occupancy_exact(setup):
    catalog, reqs, cat, c_f = setup
    _, _, m, cfg = _run_acai(cat, reqs[:400], c_f, rounding="depround",
                             round_every=10)
    occ = np.array(m.occupancy)
    np.testing.assert_array_equal(occ, 80)


def test_coupled_occupancy_concentrates(setup):
    catalog, reqs, cat, c_f = setup
    _, _, m, cfg = _run_acai(cat, reqs, c_f, rounding="coupled")
    occ = np.array(m.occupancy)
    assert abs(occ.mean() - 80) < 8
    assert (np.abs(occ - 80) < 24).mean() > 0.99  # within ~5%·sqrt relax


def test_negentropy_at_least_euclidean(setup):
    """Paper Fig. 6: negative entropy >= Euclidean map (each at its best
    learning rate — the paper tunes eta per map over a grid)."""
    catalog, reqs, cat, c_f = setup
    best_ne = max(
        B.nag(_run_acai(cat, reqs, c_f, mirror="negentropy", eta=e)[0], 10, c_f)[-1]
        for e in (0.02 / c_f, 0.1 / c_f, 0.5 / c_f)
    )
    best_eu = max(
        B.nag(_run_acai(cat, reqs, c_f, mirror="euclidean", eta=e)[0], 10, c_f)[-1]
        for e in (0.1 / (c_f * 80), 0.5 / (c_f * 80), 2.0 / (c_f * 80))
    )
    assert best_ne >= best_eu - 0.01


def test_index_candidate_fn_close_to_exact(setup):
    catalog, reqs, cat, c_f = setup
    cfg = policy.AcaiConfig(h=80, k=10, c_f=c_f, c_remote=64, c_local=16,
                            oma=oma.OMAConfig(eta=0.05 / c_f))
    index = IVFFlatIndex(cat, nlist=48, nprobe=10)
    fn_approx = index_candidate_fn(index, cat, cfg.c_remote, cfg.c_local,
                                   h=cfg.h)
    replay = policy.make_replay(cfg, fn_approx)
    state, m = replay(policy.init_state(cat.shape[0], cfg), jnp.array(reqs[:1200]))
    g_approx = B.nag(np.array(m.gain_int), 10, c_f)[-1]
    g_exact, _, _, _ = _run_acai(cat, reqs[:1200], c_f)
    assert g_approx > 0.8 * B.nag(g_exact, 10, c_f)[-1]


def test_time_average_regret_decays(setup):
    """Theorem IV.1: time-averaged regret ~ O(1/sqrt(T)) against the best
    static allocation in hindsight (approximated greedily)."""
    catalog, reqs, cat, c_f = setup
    k, h = 10, 80
    gains, _, m, cfg = _run_acai(cat, reqs, c_f, h=h, k=k)
    # best static-in-hindsight approx: cache the h most popular objects
    # (popularity = exact nearest catalog object of each request)
    d = np.linalg.norm(catalog[None, :, :] - reqs[:, None, :], axis=-1).argmin(1)
    top = np.bincount(d, minlength=catalog.shape[0]).argsort()[::-1][:h]
    x_static = np.zeros(catalog.shape[0], np.float32)
    x_static[top] = 1.0

    from repro.core import gain as G
    # evaluate static gain over a subsample
    sub = slice(0, 2500, 5)
    static_gain = []
    for r in reqs[sub]:
        dfull = jnp.sum((cat - jnp.array(r)[None, :]) ** 2, -1)
        static_gain.append(float(G.gain_value(dfull, jnp.array(x_static), k, c_f)))
    static_avg = np.mean(static_gain)
    avg_first = gains[:500].mean()
    avg_last = gains[-500:].mean()
    # regret per step shrinks: late average gain approaches the static optimum
    assert (static_avg - avg_last) < (static_avg - avg_first) + 1e-6
    assert avg_last > 0.85 * (1 - 1 / np.e) * static_avg
