"""Mutable catalog (DESIGN.md §10): add/remove/refresh conformance across
every registered backend, the removed-object-is-never-served invariant,
refresh-matches-fresh-build recall, the AcaiCache/ServerOracle invalidation
hooks, and the churn_rate = 0 static-replay consistency pin."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import baselines as B
from repro.core import churn, oma, policy, trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel
from repro.index import Index, IndexSpec, build_index
from repro.index.base import TINY_BUILD_KWARGS as TINY
from repro.index.base import grow_capacity, slab_append


@pytest.fixture(scope="module")
def setup():
    catalog, reqs, _ = trace.sift_like(n=300, d=16, t=48, seed=0)
    rng = np.random.default_rng(7)
    newv = (rng.random((60, 16)) * 0.9 + 0.05).astype(np.float32)
    return jnp.array(catalog), jnp.array(reqs), jnp.asarray(newv)


# ---------------------------------------------------------------------------
# all-backends mutation conformance (the sweep mirroring test_index_api)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", sorted(TINY))
def test_add_remove_refresh_conformance(setup, backend):
    cat, rq, newv = setup
    idx = build_index(IndexSpec(backend, TINY[backend]), cat)
    assert isinstance(idx, Index)
    assert (idx.n, idx.capacity, idx.n_slots) == (300, 300, 300)

    # --- add: appended ids are monotonic, never recycled ------------------
    ids = idx.add(newv)
    assert (ids == np.arange(300, 360)).all()
    assert idx.n == 360 and idx.n_slots == 360 and idx.capacity >= 360
    # an inserted vector is findable by querying it exactly (recall on the
    # insert itself — every backend links/bins new rows at add time)
    d, got = idx.query(newv[:16], 5)
    assert d.shape == (16, 5) and got.dtype == jnp.int32
    found = [int(ids[j]) in set(np.asarray(got)[j]) for j in range(16)]
    assert sum(found) >= 14, f"{backend}: inserted rows not retrievable"

    # --- remove: a tombstoned object can NEVER surface again --------------
    d1, top = idx.query(rq[:16], 5)
    doomed = np.unique(np.asarray(top)[:, 0])
    idx.remove(doomed)
    assert idx.n == 360 - len(doomed)
    d2, after = idx.query(rq[:16], 8)
    assert not set(doomed.tolist()) & set(np.asarray(after).ravel().tolist())
    # contract still holds post-mutation: ascending dists, -1 underflow
    dd, ii = np.asarray(d2), np.asarray(after)
    valid = ii >= 0
    assert (np.diff(np.where(valid, dd, np.inf), axis=1) >= -1e-5).all()
    assert np.isinf(dd[~valid]).all()

    # --- refresh: ids stable, removed rows still dead ----------------------
    idx.refresh()
    assert idx.n == 360 - len(doomed)
    d3, again = idx.query(rq[:16], 8)
    assert not set(doomed.tolist()) & set(np.asarray(again).ravel().tolist())

    # --- double-remove / bad ids are loud errors ---------------------------
    with pytest.raises(ValueError, match="already dead"):
        idx.remove(doomed[:1])
    with pytest.raises(ValueError):
        idx.remove(np.asarray([idx.n_slots + 5]))


@pytest.mark.parametrize("backend", sorted(TINY))
def test_refresh_matches_fresh_build(setup, backend):
    """Recall after refresh matches a fresh build on the live rows: the
    rebuild runs over the live rows in slab order with a pure id remap, so
    the two indexes answer identically (the acceptance pin)."""
    cat, rq, newv = setup
    idx = build_index(IndexSpec(backend, TINY[backend]), cat)
    idx.add(newv)
    rng = np.random.default_rng(11)
    idx.remove(rng.choice(360, size=80, replace=False))
    idx.refresh()

    live = idx.live_rows()
    fresh = build_index(IndexSpec(backend, TINY[backend]),
                        idx.embeddings[jnp.asarray(live)])
    d_ref, i_ref = idx.query(rq[:16], 5)
    d_new, i_new = fresh.query(rq[:16], 5)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_new),
                               atol=1e-4)
    # fresh ids are local row offsets into the live set: remap and compare
    i_new = np.asarray(i_new)
    remapped = np.where(i_new >= 0, live[np.clip(i_new, 0, None)], -1)
    np.testing.assert_array_equal(np.asarray(i_ref), remapped)


def test_slab_growth_and_id_monotonicity(setup):
    cat, _, newv = setup
    assert grow_capacity(300, 1, 300) == 600
    assert grow_capacity(300, 1000, 300) == 2400
    emb, valid, n_slots = cat, jnp.ones((300,), bool), 300
    all_ids = []
    for s in range(0, 60, 20):
        emb, valid, ids = slab_append(emb, valid, n_slots, newv[s:s + 20])
        n_slots += len(ids)
        all_ids.append(ids)
    assert (np.concatenate(all_ids) == np.arange(300, 360)).all()
    assert emb.shape[0] == 600 and not bool(valid[360:].any())
    np.testing.assert_allclose(np.asarray(emb[300:360]), np.asarray(newv),
                               atol=0)


# ---------------------------------------------------------------------------
# AcaiCache invalidation hooks (the OMA state drops removed objects)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cache_cfg():
    return policy.AcaiConfig(h=24, k=4, c_f=1.0, c_remote=16, c_local=8,
                             oma=oma.OMAConfig(eta=0.05,
                                               rounding="depround"))


@pytest.mark.parametrize("index", [None, IndexSpec("ivf", {"nlist": 8,
                                                           "nprobe": 4})])
def test_acai_cache_invalidation_invariant(setup, cache_cfg, index):
    cat, rq, newv = setup
    cfg = dataclasses.replace(cache_cfg, index=index)
    cache = policy.AcaiCache(cat, cfg, seed=0)
    for s in range(0, 16, 8):
        cache.serve_update_batch(rq[s:s + 8])

    ids = cache.add_objects(newv[:30])
    assert (ids == np.arange(300, 330)).all()
    assert cache.state.y.shape[0] == cache.catalog.shape[0] >= 330
    # new rows admitted at the uniform prior (they can be learned at once)
    assert float(cache.state.y[301]) > 0

    doomed = np.asarray(cache.cached_ids)[:6]
    cache.remove_objects(doomed)
    jd = jnp.asarray(doomed)
    assert float(jnp.abs(cache.state.y[jd]).sum()) == 0.0
    assert float(jnp.abs(cache.state.x[jd]).sum()) == 0.0
    for s in range(16, 48, 8):
        m = cache.serve_update_batch(rq[s:s + 8])
        # the invariant persists through every OMA + rounding update
        assert float(jnp.abs(cache.state.y[jd]).sum()) == 0.0
        assert float(jnp.abs(cache.state.x[jd]).sum()) == 0.0
    assert float(m.occupancy[0]) <= cfg.h + 1e-6
    assert cache.live_count == 330 - 6
    # B = 1 serving keeps working in mutable mode
    m1 = cache.serve_update(rq[0])
    assert m1.gain_int.shape == ()


def test_mutable_path_matches_static_when_all_alive(setup, cache_cfg):
    """Forcing the mutable serving mode without any actual mutation must
    reproduce the static path (same candidate math with the catalog as a
    runtime argument instead of a traced constant; float tolerance — the
    constant/parameter boundary reassociates the distance GEMM)."""
    cat, rq, _ = setup
    a = policy.AcaiCache(cat, cache_cfg, seed=0)
    b = policy.AcaiCache(cat, cache_cfg, seed=0)
    b._enter_mutable()
    for s in range(0, 48, 8):
        ma = a.serve_update_batch(rq[s:s + 8])
        mb = b.serve_update_batch(rq[s:s + 8])
        np.testing.assert_allclose(np.asarray(ma.gain_int),
                                   np.asarray(mb.gain_int),
                                   rtol=0, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(ma.served_local),
                                      np.asarray(mb.served_local))
    np.testing.assert_allclose(np.asarray(a.state.y), np.asarray(b.state.y),
                               rtol=0, atol=1e-5)


def test_acai_cache_mutation_guards(setup, cache_cfg):
    cat, rq, newv = setup
    fn = policy.exact_candidate_fn_batched(cat, 16, 8)
    cache = policy.AcaiCache(cat, cache_cfg, candidate_fn_batched=fn, seed=0)
    with pytest.raises(ValueError, match="explicit candidate_fn"):
        cache.add_objects(newv[:2])
    # exact sharded mutation is supported (tests/test_sharded_churn.py);
    # only configurations the exact masked scan cannot honor still reject:
    # approximate sharded structures (ivf / scan_chunk) have no mutable
    # sharded serving path
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sharded = policy.AcaiCache(cat, cache_cfg, mesh=mesh, seed=0,
                               sharded_kwargs={"scan_chunk": 64})
    with pytest.raises(NotImplementedError, match="sharded"):
        sharded.remove_objects([0])
    assert not sharded._mutated  # rejected mutation leaves the static path

    # a rejected mutation leaves the cache on the static path with its
    # live-count intact (exact path validates duplicates/range/aliveness
    # exactly like the index path does)
    clean = policy.AcaiCache(cat, cache_cfg, seed=0)
    with pytest.raises(ValueError, match="duplicate"):
        clean.remove_objects([5, 5])
    with pytest.raises(ValueError):
        clean.remove_objects([cat.shape[0] + 7])
    assert not clean._mutated and clean.live_count == cat.shape[0]
    clean.remove_objects([5])
    with pytest.raises(ValueError, match="already dead"):
        clean.remove_objects([5])
    assert clean.live_count == cat.shape[0] - 1


def test_churn_replay_drains_tail_events(cache_cfg):
    """Events landing past the last full mini-batch still apply, so the
    catalog ends in the schedule's final state."""
    params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"])
    catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
    events = trace.rolling_catalog_events(**params)
    n0 = churn.warm_size(params["n"], params["warm"])
    cache = policy.AcaiCache(jnp.asarray(catalog[:n0]), cache_cfg, seed=0)
    # drop the trace to 60 requests: batch=16 -> tt=48, several events
    # land in [48, 64) and must be drained after the last batch
    res = churn.replay_with_churn(cache, catalog, reqs[:60], events,
                                  batch=16)
    assert res["requests"] == 48
    assert res["events_applied"] == len(events)
    assert cache.live_count == n0


# ---------------------------------------------------------------------------
# churn_rate = 0: the static-catalog consistency pin (acceptance criterion)
# ---------------------------------------------------------------------------

def test_churn_zero_is_bit_consistent_with_static_replay(cache_cfg):
    """A rolling_catalog replay with churn_rate = 0 produces bitwise the
    same metrics and state as make_replay_batched on the warm window —
    the mutable-catalog plumbing costs nothing when nothing mutates."""
    params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"],
                  churn_rate=0.0)
    catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
    events = trace.rolling_catalog_events(**params)
    assert events == []
    n0 = churn.warm_size(params["n"], params["warm"])

    cache = policy.AcaiCache(jnp.asarray(catalog[:n0]), cache_cfg, seed=0)
    res = churn.replay_with_churn(cache, catalog, reqs, events, batch=8)
    assert res["events_applied"] == 0

    replay = policy.make_replay_batched(
        cache_cfg, policy.exact_candidate_fn_batched(
            jnp.asarray(catalog[:n0]), cache_cfg.c_remote,
            cache_cfg.c_local), 8)
    st, m = replay(policy.init_state(n0, cache_cfg, seed=0),
                   jnp.asarray(reqs))
    np.testing.assert_array_equal(res["gain"], np.asarray(m.gain_int))
    np.testing.assert_array_equal(res["served_local"],
                                  np.asarray(m.served_local))
    np.testing.assert_array_equal(res["occupancy"], np.asarray(m.occupancy))
    np.testing.assert_array_equal(np.asarray(cache.state.y), np.asarray(st.y))
    np.testing.assert_array_equal(np.asarray(cache.state.x), np.asarray(st.x))


def test_churn_replay_applies_schedule(cache_cfg):
    """Under churn the driver applies every event, row ids stay aligned
    with the trace schedule, and expired objects drop out of the state."""
    params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"])
    catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
    events = trace.rolling_catalog_events(**params)
    assert len(events) > 0
    n0 = churn.warm_size(params["n"], params["warm"])
    cache = policy.AcaiCache(jnp.asarray(catalog[:n0]), cache_cfg, seed=0)
    res = churn.replay_with_churn(cache, catalog, reqs, events, batch=8,
                                  refresh_every=32)
    assert res["events_applied"] == len(events)
    assert res["gain"].shape == (64,)
    removed = np.concatenate([ev[2] for ev in events])
    assert float(jnp.abs(cache.state.y[jnp.asarray(removed)]).sum()) == 0.0
    assert cache.live_count == n0  # rolling window: one expiry per insert


def test_churn_replay_baseline_policy():
    """The same driver runs the oracle-backed baselines (online mode)."""
    params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"])
    catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
    events = trace.rolling_catalog_events(**params)
    n0 = churn.warm_size(params["n"], params["warm"])
    pol = PA.build_policy(
        PA.PolicySpec("sim_lru", dict(PA.TINY_POLICY_KWARGS["sim_lru"])),
        catalog[:n0], CostModel(c_f=1.0), seed=0)
    res = churn.replay_with_churn(pol, catalog, reqs, events, batch=8)
    assert res["events_applied"] == len(events)
    removed = set(np.concatenate([ev[2] for ev in events]).tolist())
    assert not removed & set(pol.policy.cached_object_ids().tolist())


# ---------------------------------------------------------------------------
# double-buffered refresh (DESIGN.md §14): stale serves bitwise until the
# swap; after the swap the index answers like a blocking refresh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", sorted(TINY))
def test_two_phase_refresh_stale_then_fresh(setup, backend):
    cat, rq, newv = setup
    idx = build_index(IndexSpec(backend, TINY[backend]), cat)
    ref = build_index(IndexSpec(backend, TINY[backend]), cat)
    rng = np.random.default_rng(5)
    doomed = rng.choice(300, size=40, replace=False)
    for i in (idx, ref):
        i.add(newv)
        i.remove(doomed)

    assert not idx.refresh_pending
    idx.refresh_start()
    # between start and swap the *stale* structures serve, bitwise equal
    # to a twin that never started a refresh
    d_s, i_s = idx.query(rq[:16], 5)
    d_r, i_r = ref.query(rq[:16], 5)
    np.testing.assert_array_equal(np.asarray(i_s), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(d_s), np.asarray(d_r))

    idx.refresh_swap()
    assert not idx.refresh_pending
    ref.refresh()  # blocking = start + swap back to back
    d_a, i_a = idx.query(rq[:16], 5)
    d_b, i_b = ref.query(rq[:16], 5)
    np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))
    np.testing.assert_array_equal(np.asarray(d_a), np.asarray(d_b))


def test_shadow_discarded_on_interleaved_mutation(setup):
    """A mutation between start and swap invalidates the shadow (it was
    computed over rows that no longer reflect the slab); the swap then
    installs nothing and the stale structures keep serving."""
    cat, rq, newv = setup
    spec = IndexSpec("ivf", TINY["ivf"])
    idx = build_index(spec, cat)
    twin = build_index(spec, cat)
    idx.refresh_start()
    assert idx.refresh_pending
    for i in (idx, twin):
        i.add(newv[:8])
    assert not idx.refresh_pending
    idx.refresh_swap()  # no-op: the shadow was discarded
    d_a, i_a = idx.query(rq[:8], 5)
    d_b, i_b = twin.query(rq[:8], 5)
    np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))
    np.testing.assert_array_equal(np.asarray(d_a), np.asarray(d_b))


# ---------------------------------------------------------------------------
# epoch compaction (DESIGN.md §14)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", sorted(TINY))
def test_compact_matches_fresh_build(setup, backend):
    """After compaction the index answers like a fresh build over the
    live rows: the remap is order-preserving, so even index-order
    tie-breaks survive the renumbering."""
    cat, rq, newv = setup
    idx = build_index(IndexSpec(backend, TINY[backend]), cat)
    idx.add(newv)
    rng = np.random.default_rng(13)
    idx.remove(rng.choice(360, size=100, replace=False))

    live = idx.live_rows()
    emb_live = idx.embeddings[jnp.asarray(live)]
    old_cap = idx.capacity
    remap = np.asarray(idx.compact())
    assert remap.shape == (old_cap,)
    # order-preserving: live rows land densely at [0, n_live) in order
    np.testing.assert_array_equal(remap[live], np.arange(len(live)))
    dead = np.setdiff1d(np.arange(old_cap), live)
    assert (remap[dead] == -1).all()
    assert idx.n == idx.n_slots == len(live)

    fresh = build_index(IndexSpec(backend, TINY[backend]), emb_live)
    d_a, i_a = idx.query(rq[:16], 5)
    d_b, i_b = fresh.query(rq[:16], 5)
    np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))
    np.testing.assert_allclose(np.asarray(d_a), np.asarray(d_b), atol=1e-4)

    # the id space restarts dense: post-compaction adds continue from
    # n_live, and the new rows are immediately retrievable
    ids = idx.add(newv[:4])
    np.testing.assert_array_equal(ids, np.arange(len(live), len(live) + 4))
    _, got = idx.query(newv[:4], 3)
    hits = [int(ids[j]) in set(np.asarray(got)[j]) for j in range(4)]
    assert sum(hits) >= 3, f"{backend}: rows added after compact not found"


def test_churn_compaction_parity():
    """Compaction is behavior-neutral for exact candidates: the driver's
    id translation routes the schedule through the remap, and the replay
    matches the compaction-free run on gain/served/occupancy bitwise —
    only the slab capacity (and wall time) changes.  Re-rounding is
    frozen past the initial draw (`round_every` > trace length):
    randomized rounding consumes one uniform per slab *position*, so no
    renumbering can keep its stream aligned — the deterministic pipeline
    (candidates, distances, OMA y, gains given x) is the compaction
    contract, and that is what this pins bitwise."""
    cfg = policy.AcaiConfig(
        h=24, k=4, c_f=1.0, c_remote=16, c_local=8,
        oma=oma.OMAConfig(eta=0.05, rounding="depround",
                          round_every=1_000_000))
    params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"])
    catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
    events = trace.rolling_catalog_events(**params)
    assert len(events) > 0
    n0 = churn.warm_size(params["n"], params["warm"])
    runs = {}
    for every in (0, 24):
        cache = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0)
        runs[every] = (churn.replay_with_churn(
            cache, catalog, reqs, events, batch=8, compact_every=every),
            cache)
    (r0, c0), (r1, c1) = runs[0], runs[24]
    assert r0["compactions"] == 0 and r1["compactions"] >= 1
    np.testing.assert_array_equal(r0["gain"], r1["gain"])
    np.testing.assert_array_equal(r0["served_local"], r1["served_local"])
    np.testing.assert_array_equal(r0["occupancy"], r1["occupancy"])
    # compaction reclaimed the tombstoned rows: the compacted slab is no
    # bigger, and the live mass is identical
    assert c1.catalog.shape[0] <= c0.catalog.shape[0]
    assert c1.live_count == c0.live_count
    np.testing.assert_array_equal(
        np.sort(np.asarray(c1.state.y)[np.asarray(c1.state.y) != 0]),
        np.sort(np.asarray(c0.state.y)[np.asarray(c0.state.y) != 0]))


# ---------------------------------------------------------------------------
# no-retrace guard: churn at warmed shapes compiles nothing new
# ---------------------------------------------------------------------------

def test_no_retrace_after_warmup(cache_cfg):
    """The donated-update mutation path keys its jits on (capacity,
    pow2-bucketed batch width): replaying the same churn schedule a
    second time — fresh policy, same shapes — must add zero compiled
    traces across every tracked entry point (DESIGN.md §14)."""
    from repro.index.base import tracked_compiles

    params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"])
    catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
    events = trace.rolling_catalog_events(**params)
    assert len(events) > 0
    n0 = churn.warm_size(params["n"], params["warm"])
    for attempt in ("warmup", "pinned"):
        cache = policy.AcaiCache(jnp.asarray(catalog[:n0]), cache_cfg,
                                 seed=0)
        before = tracked_compiles()
        churn.replay_with_churn(cache, catalog, reqs, events, batch=8,
                                refresh_every=32)
        grew = tracked_compiles() - before
        if attempt == "pinned":
            assert grew == 0, (
                f"churn retraced {grew} tracked jits after warmup")


# ---------------------------------------------------------------------------
# ServerOracle mutation semantics
# ---------------------------------------------------------------------------

def test_server_oracle_mutation(setup):
    cat, rq, newv = setup
    catalog = np.asarray(cat)
    o = B.ServerOracle(catalog, kmax=16, retain_all=False)
    ts = o.extend(np.asarray(rq[:8]))
    ids = o.add_objects(np.asarray(newv[:20]))
    assert (ids == np.arange(300, 320)).all()
    with pytest.raises(KeyError):          # stale precomputed answers
        o.knn(int(ts[0]), 4)
    ts2 = o.extend(np.asarray(rq[:8]))
    top = o.knn(int(ts2[0]), 1)[0]
    o.remove_objects(top)
    ts3 = o.extend(np.asarray(rq[:8]))
    block = o.knn_block(ts3, 16)
    assert int(top[0]) not in set(block.ravel().tolist())
    with pytest.raises(ValueError, match="already dead"):
        o.remove_objects(top)
    # an added-then-queried object is reachable
    tq = o.extend(np.asarray(newv[:1]))
    assert int(o.knn(int(tq[0]), 1)[0][0]) == 300
