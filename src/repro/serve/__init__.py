"""Serving substrate: prefill/decode engine with KV/SSM caches, continuous
batching, the AÇAI semantic cache tier, the resilient remote tier
(fault-injected backend + retry/hedge/deadline/degrade, DESIGN.md §11),
and the online serving engine (arrival processes + request queue +
dynamic batch former + admission control on the virtual clock,
DESIGN.md §12)."""

from repro.serve.arrivals import (ARRIVAL_KINDS, ArrivalSpec,
                                  ClosedLoopSource, OpenLoopSource,
                                  arrival_times, make_source)
from repro.serve.engine import ServeEngine, generate, make_decode_step, make_prefill
from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                               OnlineServingEngine, RequestRecord,
                               ServiceModel, fixed_window_engine,
                               serve_trace_online)
from repro.serve.remote import (FaultSpec, FaultyRemote, OracleRemote,
                                RemoteBackend, parse_outage_windows,
                                payload_ok)
from repro.serve.resilience import (CircuitBreaker, RemoteSession,
                                    ResilienceConfig, ResilientPolicy,
                                    RetryConfig, replay_resilient,
                                    simulate_request)
from repro.serve.semantic_cache import SemanticCachedLM, embed_prompt

__all__ = ["ARRIVAL_KINDS", "AdmissionConfig", "ArrivalSpec",
           "BatchFormerConfig", "CircuitBreaker", "ClosedLoopSource",
           "FaultSpec", "FaultyRemote", "OnlineServingEngine",
           "OpenLoopSource", "OracleRemote", "RemoteBackend",
           "RemoteSession", "RequestRecord", "ResilienceConfig",
           "ResilientPolicy", "RetryConfig", "SemanticCachedLM",
           "ServeEngine", "ServiceModel", "arrival_times", "embed_prompt",
           "fixed_window_engine", "generate", "make_decode_step",
           "make_prefill", "make_source", "parse_outage_windows",
           "payload_ok", "replay_resilient", "serve_trace_online",
           "simulate_request"]
