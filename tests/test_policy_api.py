"""Unified policy API (DESIGN.md §9): CachePolicy conformance across every
registered policy, PolicySpec round-tripping, batched-baseline parity with
the sequential path, the augmented serving-rule invariant, harness
bit-consistency at B = 1, and dry-run provenance."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import baselines as B
from repro.core import oma, policy, trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel, calibrate_fetch_cost
from repro.core.policy_api import (PolicySpec, build_policy,
                                   parse_policy_opts, registered_policies)

# tiny-trace / tiny-spec tables: the canonical kwargs live next to the
# registries (shared with scripts/smoke.sh)
from repro.core.policy_api import TINY_POLICY_KWARGS as TINY  # noqa: E402
from repro.core.trace import TINY_TRACE_KWARGS  # noqa: E402


@pytest.fixture(scope="module")
def setup():
    catalog, reqs, _ = trace.sift_like(n=400, d=16, t=96, seed=0)
    oracle = B.ServerOracle(catalog, reqs, kmax=16)
    return catalog, reqs, CostModel(c_f=1.0), oracle


# ---------------------------------------------------------------------------
# batched step-contract conformance (all policies, one shared test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TINY))
def test_step_contract(setup, name):
    catalog, reqs, cm, oracle = setup
    pol = build_policy(PolicySpec(name, TINY[name]), catalog, cm,
                       oracle=oracle, seed=0)
    assert isinstance(pol, PA.CachePolicy)
    assert pol.spec.name == name
    assert pol.k == 4 and pol.c_f == 1.0 and pol.h == 16

    m = pol.serve_update_batch(reqs[:8], np.arange(8))
    for field in policy.StepMetrics._fields:
        assert np.asarray(getattr(m, field)).shape == (8,), (name, field)
    assert np.isfinite(np.asarray(m.gain_int)).all()
    assert (np.asarray(m.cost) >= -1e-5).all()
    assert (np.asarray(m.served_local) <= pol.k).all()
    # B = 1 view
    m1 = pol.serve_update(reqs[8], 8)
    assert np.asarray(m1.gain_int).shape == ()
    nag = pol.normalized_gain(float(np.sum(np.asarray(m.gain_int))), 8)
    assert np.isfinite(nag)


@pytest.mark.parametrize("name", sorted(TINY))
def test_occupancy_invariant(setup, name):
    """Cache occupancy never exceeds h (AÇAI's tiny spec pins depround
    rounding, which keeps sum x = h exactly)."""
    catalog, reqs, cm, oracle = setup
    pol = build_policy(PolicySpec(name, TINY[name]), catalog, cm,
                       oracle=oracle, seed=0)
    for s in range(0, 96, 8):
        m = pol.serve_update_batch(reqs[s:s + 8], np.arange(s, s + 8))
        assert (np.asarray(m.occupancy) <= pol.h + 1e-6).all(), name


@pytest.mark.parametrize("name", sorted(set(TINY) - {"acai"}))
def test_augmented_cost_never_worse(setup, name):
    """The augmented serving rule (AÇAI's per-object composition grafted
    onto the baseline's updates) can only lower the serving cost: the hit
    logic — hence the trajectory — is unchanged, and the augmented answer
    picks the cheapest copy per object from a superset of the plain
    answer's options."""
    catalog, reqs, cm, oracle = setup
    plain = build_policy(PolicySpec(name, TINY[name]), catalog, cm,
                         oracle=oracle, seed=0)
    aug = build_policy(PolicySpec(name, {**TINY[name], "augmented": True}),
                       catalog, cm, oracle=oracle, seed=0)
    ts = np.arange(96)
    m_p = PA.replay_trace(plain, reqs, ts, batch=8)
    m_a = PA.replay_trace(aug, reqs, ts, batch=8)
    assert (m_a["cost"] <= m_p["cost"] + 1e-6).all(), name
    assert m_a["gain"].sum() >= m_p["gain"].sum() - 1e-6


def test_lru_exact_hit_semantics(setup):
    """LRU hits iff the request embedding is byte-identical to a cached
    key; any novel request is a miss."""
    catalog, reqs, cm, oracle = setup
    pol = build_policy(PolicySpec("lru", TINY["lru"]), catalog, cm,
                       oracle=oracle, seed=0)
    m = pol.serve_update(reqs[0], 0)
    assert not bool(np.asarray(m.served_local) > 0)  # cold miss
    m = pol.serve_update(reqs[0], 0)                 # identical request
    assert bool(np.asarray(m.served_local) > 0)
    # a different request is a miss even if geometrically close
    other = np.nextafter(reqs[1], np.inf).astype(np.float32)
    m = pol.serve_update(other, 1)
    assert not bool(np.asarray(m.served_local) > 0)


def test_batched_matches_sequential(setup):
    """step_batch (vectorized hit tests + serving costs) takes the same
    hit decisions as the sequential per-step path."""
    catalog, reqs, cm, oracle = setup
    for name in ("SIM-LRU", "QCACHE", "CLS-LRU"):
        kw = dict(h=24, k=4, c_f=1.0, seed=0)
        if name != "QCACHE":
            kw.update(k_prime=8, c_theta=1.5)
        seq = B.POLICIES[name](catalog, oracle, **kw)
        m_seq = B.run_policy(seq, reqs)
        bat = B.POLICIES[name](catalog, oracle, **kw)
        res = []
        for s in range(0, 96, 16):
            res.extend(bat.step_batch(np.arange(s, s + 16), reqs[s:s + 16]))
        np.testing.assert_array_equal(
            np.array([r.hit for r in res]), m_seq["hit"], err_msg=name)
        np.testing.assert_allclose(
            np.array([r.gain for r in res]), m_seq["gain"], rtol=1e-4,
            atol=1e-4, err_msg=name)


def test_oracle_fused_precompute_and_online(setup):
    """The fused-scan oracle returns exact kNN (vs brute force) and the
    online extend() path matches the precomputed table."""
    catalog, reqs, cm, _ = setup
    oracle = B.ServerOracle(catalog, reqs[:16], kmax=8)
    q = reqs[:4].astype(np.float32)
    d2 = ((q[:, None, :] - catalog[None].astype(np.float32)) ** 2).sum(-1)
    for b in range(4):
        np.testing.assert_allclose(np.sort(d2[b])[:8], oracle.d2[b],
                                   rtol=1e-4, atol=1e-4)
    online = B.ServerOracle(catalog, kmax=8)
    ts = online.extend(reqs[:16])
    assert list(ts) == list(range(16))
    np.testing.assert_allclose(online.d2, oracle.d2, rtol=1e-5, atol=1e-5)
    # memory-bounded chunking: a small budget still scans exactly
    tiny = B.ServerOracle(catalog, reqs[:16], kmax=8, chunk=64)
    np.testing.assert_allclose(tiny.d2, oracle.d2, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# harness bit-consistency: AÇAI rows at B = 1 == the pre-harness pipeline
# ---------------------------------------------------------------------------

def test_acai_replay_b1_bit_consistent(setup):
    catalog, reqs, cm, _ = setup
    cat = jnp.asarray(catalog)
    c_f = float(calibrate_fetch_cost(cat, kth=50, sample=128))
    cfg = policy.AcaiConfig(h=24, k=4, c_f=c_f, c_remote=16, c_local=8,
                            oma=oma.OMAConfig(eta=0.05 / c_f))
    fn = policy.exact_candidate_fn(cat, cfg.c_remote, cfg.c_local)
    _, m = policy.make_replay(cfg, fn)(
        policy.init_state(400, cfg, seed=0), jnp.asarray(reqs))
    spec = PolicySpec("acai", {"h": 24, "k": 4, "c_remote": 16,
                               "c_local": 8, "eta": 0.05 / c_f, "batch": 1})
    pol = build_policy(spec, catalog, CostModel(c_f=c_f), seed=0)
    res = pol.replay(reqs)
    np.testing.assert_array_equal(res["gain"],
                                  np.asarray(m.gain_int, np.float64))
    np.testing.assert_array_equal(res["cost"], np.asarray(m.cost, np.float64))
    np.testing.assert_array_equal(res["served_local"],
                                  np.asarray(m.served_local))


# ---------------------------------------------------------------------------
# PolicySpec serialization + registry + CLI parsing
# ---------------------------------------------------------------------------

def test_spec_roundtrip():
    spec = PolicySpec("sim_lru", {"h": 200, "k_prime": 20, "c_theta": 1.5,
                                  "augmented": True})
    d = spec.to_dict()
    assert d == {"policy": "sim_lru", "h": 200, "k_prime": 20,
                 "c_theta": 1.5, "augmented": True}
    assert PolicySpec.from_dict(d) == spec
    assert spec.with_params(k_prime=40).params["k_prime"] == 40
    assert hash(spec) == hash(PolicySpec("sim_lru", dict(reversed(
        list(spec.params.items())))))
    assert spec.label.startswith("sim_lru(")


def test_spec_errors(setup):
    catalog, _, cm, _ = setup
    with pytest.raises(ValueError, match="unknown policy"):
        PolicySpec.from_dict({"policy": "fifo"})
    with pytest.raises(ValueError, match="unknown policy"):
        build_policy("fifo", catalog, cm)
    with pytest.raises(ValueError, match="policy"):
        PolicySpec.from_dict({"h": 8})
    with pytest.raises(ValueError, match="spec field"):
        PolicySpec("acai", {"policy": "acai"})
    with pytest.raises(ValueError, match="needs 'h'"):
        build_policy(PolicySpec("acai", {"k": 4}), catalog, cm)
    with pytest.raises(ValueError, match="unknown acai policy params"):
        build_policy(PolicySpec("acai", {"h": 8, "nlist": 4}), catalog, cm)
    with pytest.raises(ValueError, match="index_spec"):
        build_policy(PolicySpec("lru", {"h": 8}), catalog, cm,
                     index_spec="flat")
    with pytest.raises(ValueError, match="mesh"):
        build_policy(PolicySpec("qcache", {"h": 8}), catalog, cm,
                     mesh=object())


def test_resolve_policy_spec():
    assert PA.resolve_policy_spec(None) is None
    spec = PolicySpec("acai", {"h": 8})
    assert PA.resolve_policy_spec(spec) is spec
    assert PA.resolve_policy_spec("qcache") == PolicySpec("qcache")
    assert PA.resolve_policy_spec({"policy": "acai", "h": 8}) == spec
    with pytest.raises(ValueError, match="unknown policy"):
        PA.resolve_policy_spec("fifo")
    with pytest.raises(TypeError):
        PA.resolve_policy_spec(42)


def test_parse_policy_opts():
    assert parse_policy_opts(
        ["k_prime=20", "c_theta=1.5", "augmented=true", "mirror=negentropy",
         "round_every=1"]
    ) == {"k_prime": 20, "c_theta": 1.5, "augmented": True,
          "mirror": "negentropy", "round_every": 1}
    assert parse_policy_opts([]) == {}
    assert parse_policy_opts(None) == {}
    with pytest.raises(ValueError, match="key=value"):
        parse_policy_opts(["augmented"])


def test_registry_complete():
    """The six paper policies are registered; the tiny tables cover every
    registered policy and trace scenario."""
    assert set(registered_policies()) == {
        "acai", "lru", "sim_lru", "cls_lru", "rnd_lru", "qcache"}
    assert set(TINY) == set(registered_policies())
    assert set(TINY_TRACE_KWARGS) == set(trace.registered_traces())
    # every baseline spec name maps onto a sequential implementation
    for name, key in PA._BASELINE_CLASS.items():
        assert key in B.POLICIES, name


# ---------------------------------------------------------------------------
# hypothesis: spec round-trip over arbitrary param dicts
# ---------------------------------------------------------------------------

def test_spec_roundtrip_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    keys = st.text("abcdefgh_", min_size=1, max_size=8).filter(
        lambda s: s != "policy")
    vals = st.one_of(st.integers(-1000, 1000),
                     st.floats(-100, 100, allow_nan=False, width=32),
                     st.booleans(), st.text("xyz01", max_size=6))

    @settings(max_examples=60, deadline=None)
    @given(name=st.sampled_from(sorted(registered_policies())),
           params=st.dictionaries(keys, vals, max_size=6))
    def check(name, params):
        spec = PolicySpec(name, params)
        d = spec.to_dict()
        assert PolicySpec.from_dict(d) == spec
        assert PolicySpec.from_dict(dict(d)) == spec
        assert d["policy"] == name
        assert spec.with_params(**params) == spec

    check()


# ---------------------------------------------------------------------------
# end-to-end: the experiment harness + dry-run provenance
# ---------------------------------------------------------------------------

def test_paper_ordering_on_tiny_stationary_trace(setup):
    """The paper's qualitative claim at conformance scale: AÇAI's NAG is
    at least every baseline's on the stationary sift-like trace."""
    catalog, reqs, _, _ = setup
    catalog, reqs, _ = trace.sift_like(n=400, d=16, t=512, seed=0)
    cat = jnp.asarray(catalog)
    c_f = float(calibrate_fetch_cost(cat, kth=50, sample=128))
    cm = CostModel(c_f=c_f)
    oracle = B.ServerOracle(catalog, reqs, kmax=16)
    ts = np.arange(reqs.shape[0])
    nags = {}
    for name, kw in TINY.items():
        kw = {**kw, "h": 40}
        if name == "sim_lru" or name == "cls_lru" or name == "rnd_lru":
            kw["c_theta"] = 1.5 * c_f
        pol = build_policy(PolicySpec(name, kw), catalog, cm, oracle=oracle,
                           seed=0)
        res = PA.replay_trace(pol, reqs, ts, batch=8)
        nags[name] = pol.normalized_gain(res["gain"].sum(), res["requests"])
    for name, v in nags.items():
        assert nags["acai"] >= v - 1e-9, (name, nags)


def test_dryrun_records_policy_spec():
    """launch/dryrun's AÇAI cell records policy_spec next to index_spec
    and shard_map_impl, in the serialized PolicySpec form (pinned without
    compiling on the 512-device mesh)."""
    from repro.launch.dryrun import acai_cell_meta

    meta = acai_cell_meta("single", n_catalog=1024, d=8, batch=16, k=4,
                          h=64, eta=0.01, variant="baseline")
    assert meta["index_spec"] == {"backend": "exact"}
    assert meta["shard_map_impl"]
    spec = PolicySpec.from_dict(meta["policy_spec"])
    assert spec.name == "acai"
    assert spec.params["h"] == 64 and spec.params["batch"] == 16
    # the record is self-contained: it round-trips into an AcaiCache
    catalog, _, _ = trace.sift_like(n=128, d=8, t=8, seed=0)
    cache = policy.AcaiCache(jnp.asarray(catalog), spec)
    assert cache.cfg.h == 64 and cache.cfg.c_f == 1.0
