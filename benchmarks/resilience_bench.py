"""Resilient serving tier sweep (DESIGN.md §11) — BENCH_resilience.json.

Fault scenarios × policies through the retry/hedge/deadline/degrade
ladder (`repro.serve.resilience`), all on the deterministic virtual-time
fault simulator (`repro.serve.remote.FaultyRemote` — no sleeping, bit-
replayable).  Per row: NAG (and its ratio to the same policy's
fault-free row), goodput (fraction of requests answered, healthy or
degraded), degraded/shed shares, virtual latency p50/p99, retry /
deadline-miss / hedge totals, circuit-breaker transitions, and the p50
serving-step wall time.

Two built-in checks:

* the fault-free AÇAI row doubles as the bitwise anchor — at fault-rate
  0 every batch takes the static jitted step, so the resilient replay's
  per-request gains must equal `make_replay_batched`'s exactly (the
  stronger full-state pin lives in tests/test_resilience.py);
* the outage row records the acceptance target honestly — degraded mode
  should retain >= 60% of fault-free NAG while shedding < 5% of
  requests; `outage_target` in the JSON says whether it did.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common
from repro.core import policy, trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel, calibrate_fetch_cost
from repro.serve.remote import FaultSpec, FaultyRemote
from repro.serve.resilience import (BreakerConfig, ResilienceConfig,
                                    ResilientPolicy, replay_resilient)

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_resilience.json"

BATCH = 8


def _policies(c_f: float, h: int, k: int):
    """(label, PolicySpec) cells of the sweep."""
    return (
        ("acai", PA.PolicySpec("acai", {"h": h, "k": k, "batch": BATCH})),
        ("sim_lru", PA.PolicySpec("sim_lru",
                                  {"h": h, "k": k, "k_prime": 2 * k,
                                   "c_theta": 1.5 * c_f})),
        ("qcache", PA.PolicySpec("qcache", {"h": h, "k": k})),
    )


def _scenarios(t: int):
    """(name, FaultSpec, ResilienceConfig) triples.

    `fault_free` is the bitwise anchor; `outage` blacks the remote out
    for the middle 20% of the trace (plus breaker fast-fail ringing
    around it); `slow_spikes` pairs a GC-pause latency train with a
    tight deadline and hedging."""
    base = dict(latency_ms=5.0, seed=3)
    rc = ResilienceConfig(deadline_ms=250.0)
    return (
        ("fault_free", FaultSpec(**base), rc),
        ("flaky", FaultSpec(error_rate=0.15, **base), rc),
        ("corrupt", FaultSpec(corrupt_rate=0.10, **base), rc),
        # NB: the breaker cooldown must not divide spike_every — a
        # 64-request cooldown against a 64-request spike period puts
        # every half-open probe back inside a spike train and the breaker
        # never recloses (goodput -> 0; the resonance is real and worth
        # knowing about, but it is a breaker-tuning bug, not the
        # tail-latency scenario this row studies)
        ("slow_spikes",
         FaultSpec(latency_ms=40.0, latency_sigma=0.3, spike_every=64,
                   spike_width=16, spike_ms=400.0, seed=3),
         ResilienceConfig(deadline_ms=250.0, hedge_ms=80.0,
                          breaker=BreakerConfig(cooldown_requests=48))),
        ("outage",
         FaultSpec(outages=((int(0.4 * t), int(0.6 * t)),), **base), rc),
    )


def _run_cell(label, spec, scenario, fault, rcfg, catalog, reqs, cm,
              seed=0):
    pol = ResilientPolicy(PA.build_policy(spec, catalog, cm, seed=seed),
                          remote=FaultyRemote(fault), resilience=rcfg)
    t0 = time.time()
    res = replay_resilient(pol, reqs, batch=BATCH)
    wall = time.time() - t0
    tt = res["requests"]
    c = res["counters"]
    return {
        "policy": spec.to_dict(), "label": label, "scenario": scenario,
        "fault": fault.to_dict(),
        "deadline_ms": rcfg.deadline_ms, "hedge_ms": rcfg.hedge_ms,
        "nag": round(float(res["gain"].sum()) / (pol.k * pol.c_f * tt), 4),
        "goodput": round(res["goodput"], 4),
        "degraded_share": round(res["degraded_share"], 4),
        "shed_share": round(res["shed_share"], 4),
        "hit_ratio": round(float(res["hit"].mean()), 4),
        "p50_ms": round(res["p50_ms"], 2),
        "p99_ms": round(res["p99_ms"], 2),
        "remote_failures": int(c["remote_failures"]),
        "retries": int(c["retries"]),
        "deadline_misses": int(c["deadline_misses"]),
        "hedges": int(c["hedges"]),
        "fast_fails": int(c["fast_fails"]),
        "slow_fetches": int(c["slow_fetches"]),
        "breaker_transitions": int(res["breaker_transitions"]),
        "p50_step_us": round(res["p50_step_s"] * 1e6, 1),
        "us_per_request": round(wall / tt * 1e6, 2),
        "requests": tt,
    }, res


def main(full: bool = False, kind: str = None) -> None:
    if kind not in (None, "sift"):
        raise ValueError(
            "the resilience suite sweeps fault scenarios on the sift_like "
            "trace (faults are the variable under study); --trace does "
            "not apply here")
    n, t, d = (20000, 8192, 32) if full else (2000, 2048, 16)
    h, k = (400, 10) if full else (64, 8)

    import jax
    import jax.numpy as jnp

    catalog, reqs, _ = trace.sift_like(n=n, d=d, t=t, jitter=0.05, seed=17)
    c_f = float(calibrate_fetch_cost(jnp.asarray(catalog),
                                     kth=min(50, n - 1), sample=256))
    cm = CostModel(c_f=c_f)
    tt = (t // BATCH) * BATCH

    rows = []
    baseline_nag = {}   # label -> fault-free NAG (the ratio denominator)
    for scenario, fault, rcfg in _scenarios(t):
        for label, spec in _policies(c_f, h, k):
            row, res = _run_cell(label, spec, scenario, fault, rcfg,
                                 catalog, reqs, cm)
            if scenario == "fault_free":
                baseline_nag[label] = row["nag"]
                if label == "acai":
                    # bitwise anchor: fault-rate 0 == the static replay
                    pol2 = PA.build_policy(spec, catalog, cm, seed=0)
                    ref = pol2.replay(reqs)
                    assert np.array_equal(res["gain"], ref["gain"]), (
                        "fault-free resilient path diverged from "
                        "make_replay_batched")
                    common.emit("resilience/bitwise-anchor", 0.0,
                                "fault_free acai == static replay")
            row["nag_vs_fault_free"] = (
                round(row["nag"] / baseline_nag[label], 4)
                if baseline_nag.get(label) else None)
            rows.append(row)
            common.emit(
                f"resilience/{scenario}/{label}", row["p50_step_us"],
                f"NAG={row['nag']:.4f};goodput={row['goodput']:.3f};"
                f"degraded={row['degraded_share']:.3f};"
                f"p99={row['p99_ms']:.0f}ms")

    # acceptance target, recorded honestly either way: under the hard
    # outage AÇAI's degraded mode should retain >= 60% of fault-free NAG
    # while shedding < 5% of requests
    acai_out = next(r for r in rows
                    if r["label"] == "acai" and r["scenario"] == "outage")
    target = {
        "nag_ratio": acai_out["nag_vs_fault_free"],
        "shed_share": acai_out["shed_share"],
        "met": bool(acai_out["nag_vs_fault_free"] is not None
                    and acai_out["nag_vs_fault_free"] >= 0.6
                    and acai_out["shed_share"] < 0.05),
    }
    common.emit("resilience/outage-target", 0.0,
                f"nag_ratio={target['nag_ratio']};"
                f"shed={target['shed_share']};met={target['met']}")

    BENCH_JSON.write_text(json.dumps(
        {"full": full, "n": n, "d": d, "t": t, "h": h, "k": k,
         "batch": BATCH, "c_f": round(c_f, 6), "requests": tt,
         "backend": jax.default_backend(), "outage_target": target,
         "rows": rows}, indent=2) + "\n")
    common.emit("resilience/json", 0.0, str(BENCH_JSON.name))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    main(ap.parse_args().full)
