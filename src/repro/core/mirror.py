"""Mirror maps for Online Mirror Ascent (paper Sec. IV-E, Fig. 6).

A mirror map supplies the primal->dual gradient map and its inverse.  For the
negative entropy  Phi(y) = sum y log y  the OMA step
    grad Phi(y) = 1 + log y;   (grad Phi)^{-1}(v) = exp(v - 1)
composes to the multiplicative update  z = y * exp(eta * g)  (the additive
constants cancel, and any global factor is absorbed by the projection scale),
which is what we implement — numerically far safer than exp(log y + ...).

For the squared Euclidean norm, OMA is plain projected gradient ascent.
"""

from __future__ import annotations

import jax.numpy as jnp

NEGENTROPY = "negentropy"
EUCLIDEAN = "euclidean"

# exp-argument clip: keeps the multiplicative update finite for adversarially
# large eta*g without changing the argmax structure of the projection.
_EXP_CLIP = 60.0


def dual_ascent_step(y: jnp.ndarray, g: jnp.ndarray, eta, mirror: str) -> jnp.ndarray:
    """z_{t+1} = (grad Phi)^{-1}(grad Phi(y_t) + eta g_t)   (lines 3-5, Alg. 1)."""
    if mirror == NEGENTROPY:
        return y * jnp.exp(jnp.clip(eta * g, -_EXP_CLIP, _EXP_CLIP))
    if mirror == EUCLIDEAN:
        return y + eta * g
    raise ValueError(f"unknown mirror map {mirror!r}")
