"""mamba2-130m [ssm] — SSD, attention-free (arXiv:2405.21060).

24L d_model=768 (no attention, no FFN: pure mamba2 blocks) vocab=50280,
ssm_state=128.  expand=2 -> d_inner=1536, head_dim=64 -> 24 SSD heads.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    layer_pattern="ssm",
    d_state=128,
    ssm_head_dim=64,
    expand=2,
    d_conv=4,
    pos_emb="none",
    tie_embeddings=True,
    optimizer="adamw",
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0, vocab=256,
    layer_pattern="ssm", d_state=16, ssm_head_dim=16, expand=2, d_conv=4,
    pos_emb="none", tie_embeddings=True, ssd_chunk=16,
)
