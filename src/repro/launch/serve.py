"""Serving driver: continuous-batching decode with the AÇAI semantic cache
in front (the paper's edge-inference deployment).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 40

`--policy` selects the semantic-cache tier's cache policy through the
unified policy registry (DESIGN.md §9) and `--policy-opt key=value`
(repeatable) passes spec params; `--remote-index` selects AÇAI's
remote-catalog index backend through the index registry (DESIGN.md §8)
and `--index-opt key=value` passes builder kwargs, e.g.:

  ... --policy sim_lru --policy-opt k_prime=8 --policy-opt augmented=true
  ... --remote-index nsw --index-opt beam=64 --index-opt steps=24
  ... --remote-index ivf --index-opt nlist=256 --index-opt nprobe=16
  ... --mesh-shards 4 --remote-index ivf_sharded --index-opt nlist=64

`--answer-cache CAP` fronts AÇAI's index with the exact answer-memo tier
(DESIGN.md §13) — repeated queries serve their memoized top-k without
touching the fused scans, bitwise identical to the uncached path — and
`--answer-cache-opt key=value` passes `AnswerCacheSpec` fields:

  ... --remote-index flat --answer-cache 4096
  ... --remote-index ivf --answer-cache 1024 --answer-cache-opt hit_ms=0.1
  ... --remote-index nsw --answer-cache 512 \
      --answer-cache-opt idle_unload_ms=50

`--churn-rate R` exercises the mutable catalog (DESIGN.md §10): the cache
starts on the warm `--churn-warm` fraction of the catalog and R insert+
expire events fire per request (a rolling window — new results admitted
online via SemanticCachedLM.add_documents, the oldest expired via
remove_documents), for any policy and index backend:

  ... --churn-rate 0.2 --remote-index ivf --index-opt nlist=32

`--mesh-shards P` serves the semantic-cache tier through the sharded
multi-device path (catalog + cache state sharded over a (1, P) mesh,
repro.core.distributed) — on hosts without accelerators it forces P
host-platform placeholder devices, so the XLA flag must be set before any
jax import (same discipline as launch/dryrun.py).  Churn composes with
the mesh (DESIGN.md §15): mutation is routed to owner shards and serving
goes through the sharded exact masked scan, so `--churn-rate` on a mesh
only rejects `--remote-index` (sharded index backends don't mutate
online yet):

  ... --mesh-shards 2 --churn-rate 0.2 --catalog 512

The `--remote-fault-*` flags inject a deterministic fault schedule into
the remote tier and route every request through the resilient serving
path (DESIGN.md §11): retries with capped backoff, optional hedging
(`--hedge-ms`), per-request deadlines (`--deadline-ms`), a circuit
breaker, and graceful degradation to the best local candidates when the
remote is down.  Works for any policy:

  ... --remote-fault-rate 0.2 --deadline-ms 250
  ... --remote-fault-outage 10:30 --policy sim_lru
  ... --remote-fault-latency-ms 40 --hedge-ms 80 --retries 3

`--arrival` switches the semantic-cache tier from fixed-batch querying to
the online serving engine (DESIGN.md §12): requests arrive on the virtual
clock per the chosen process, queue, and are coalesced by the dynamic
batch former (`--batch` max size, `--batch-window-ms` max wait); the
load is set as a fraction of the deterministic service model's capacity
(`--offered-load`), admission control sheds on a queue cap
(`--queue-cap`) and on hopeless deadlines (`--shed-deadline-ms`), and
`--slo-ms` reports goodput at that latency SLO:

  ... --arrival poisson --offered-load 0.8 --batch-window-ms 5 --slo-ms 25
  ... --arrival flash_crowd --offered-load 1.2 --queue-cap 64
  ... --arrival closed_loop --slo-ms 25
"""

from __future__ import annotations

import os
import sys

def _sniff_mesh_shards(argv):
    """Pre-argparse peek at --mesh-shards (both `--mesh-shards P` and
    `--mesh-shards=P` forms); malformed values are left for argparse to
    report properly."""
    for i, tok in enumerate(argv):
        val = None
        if tok == "--mesh-shards" and i + 1 < len(argv):
            val = argv[i + 1]
        elif tok.startswith("--mesh-shards="):
            val = tok.split("=", 1)[1]
        if val is not None:
            try:
                return int(val)
            except ValueError:
                return 0
    return 0


if __name__ == "__main__":  # before ANY jax import; no import side effects
    _p = _sniff_mesh_shards(sys.argv)
    if _p > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_p} "
            + os.environ.get("XLA_FLAGS", "")
        )

import argparse  # noqa: E402
import time      # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.core.policy_api import (PolicySpec, parse_policy_opts,
                                   registered_policies)
from repro.index.base import (IndexSpec, parse_index_opts,
                              registered_backends)
from repro.models import init_params
from repro.serve import SemanticCachedLM, ServeEngine, generate
from repro.serve.arrivals import ARRIVAL_KINDS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--catalog", type=int, default=512)
    ap.add_argument("--cache-size", type=int, default=64)
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard the semantic-cache tier over a (1, P) mesh "
                         "(0 = single-device batched pipeline)")
    ap.add_argument("--remote-index", default="exact",
                    choices=("exact",) + registered_backends(),
                    help="remote-catalog index backend for the semantic "
                         "cache ('exact' = perfect-recall candidates)")
    ap.add_argument("--index-opt", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="index builder kwarg (repeatable), e.g. nlist=256")
    ap.add_argument("--answer-cache", type=int, default=None, metavar="CAP",
                    help="answer-cache tier entry budget (DESIGN.md §13): "
                         "memoize exact top-k index answers in front of "
                         "the fused scans (0 = pass-through machinery; "
                         "needs --remote-index, acai only)")
    ap.add_argument("--answer-cache-opt", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="AnswerCacheSpec field (repeatable), e.g. "
                         "hit_ms=0.1 idle_unload_ms=50")
    ap.add_argument("--policy", default="acai",
                    choices=registered_policies(),
                    help="semantic-cache policy (unified policy registry, "
                         "DESIGN.md §9)")
    ap.add_argument("--policy-opt", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="policy spec param (repeatable), e.g. k_prime=8 "
                         "augmented=true")
    ap.add_argument("--churn-rate", type=float, default=0.0,
                    help="catalog churn: insert+expire events per request "
                         "(rolling window over the catalog, DESIGN.md §10; "
                         "0 = frozen catalog)")
    ap.add_argument("--churn-warm", type=float, default=0.5,
                    help="fraction of --catalog live at start under churn "
                         "(the rest inserts over the run)")
    srv = ap.add_argument_group(
        "online serving (DESIGN.md §12; --arrival switches the semantic-"
        "cache tier onto the queued engine with dynamic batch formation)")
    srv.add_argument("--arrival", default="off",
                     choices=("off",) + ARRIVAL_KINDS,
                     help="arrival process driving the request queue "
                          "('off' = fixed-batch querying)")
    srv.add_argument("--offered-load", type=float, default=0.8,
                     help="open-loop arrival rate as a fraction of the "
                          "service model's max-batch capacity (1.0 = "
                          "critically loaded)")
    srv.add_argument("--batch-window-ms", type=float, default=5.0,
                     help="batch former max wait (virtual ms) before a "
                          "partial batch dispatches; 0 = pure size "
                          "trigger (the fixed-window/offline-equivalent "
                          "configuration)")
    srv.add_argument("--slo-ms", type=float, default=None,
                     help="latency SLO (virtual ms): report goodput = "
                          "served fraction meeting it")
    srv.add_argument("--queue-cap", type=int, default=None,
                     help="admission control: shed arrivals beyond this "
                          "queue depth")
    srv.add_argument("--shed-deadline-ms", type=float, default=None,
                     help="admission control: shed queued requests whose "
                          "estimated completion would exceed this budget")
    srv.add_argument("--arrival-seed", type=int, default=0,
                     help="arrival-schedule seed (same seed = same "
                          "schedule, bit for bit)")
    res = ap.add_argument_group(
        "resilient serving (DESIGN.md §11; any flag here switches the "
        "semantic-cache tier onto the resilient remote path)")
    res.add_argument("--remote-fault-rate", type=float, default=0.0,
                     help="per-attempt transient error probability")
    res.add_argument("--remote-fault-corrupt", type=float, default=0.0,
                     help="per-attempt corrupt-payload (NaN) probability")
    res.add_argument("--remote-fault-latency-ms", type=float, default=5.0,
                     help="median remote fetch latency (lognormal)")
    res.add_argument("--remote-fault-outage", action="append", default=[],
                     metavar="START:END",
                     help="hard outage window in request indices "
                          "(repeatable), e.g. 10:30")
    res.add_argument("--remote-fault-seed", type=int, default=0,
                     help="fault-schedule seed (same seed = same faults)")
    res.add_argument("--deadline-ms", type=float, default=None,
                     help="per-request deadline budget (virtual ms); a "
                          "late success counts as a miss")
    res.add_argument("--hedge-ms", type=float, default=None,
                     help="fire a hedged second request this far into a "
                          "slow attempt (tail-latency insurance)")
    res.add_argument("--retries", type=int, default=None,
                     help="extra attempts after the first (default 2)")
    args = ap.parse_args()

    try:
        policy_spec = PolicySpec(args.policy,
                                 parse_policy_opts(args.policy_opt))
    except ValueError as e:
        raise SystemExit(str(e))
    if args.policy != "acai":
        if args.remote_index != "exact":
            raise SystemExit(
                f"--policy {args.policy} serves from the exact server "
                f"oracle; --remote-index only applies to acai")
        if args.mesh_shards > 1:
            raise SystemExit(
                f"--policy {args.policy} is a sequential baseline; "
                f"--mesh-shards only applies to acai")

    index_spec = None
    if args.remote_index != "exact":
        try:
            index_spec = IndexSpec(args.remote_index,
                                   parse_index_opts(args.index_opt))
        except ValueError as e:
            raise SystemExit(str(e))
        sharded = args.remote_index in registered_backends(sharded=True)
        if sharded and args.mesh_shards <= 1:
            raise SystemExit(
                f"--remote-index {args.remote_index} is a sharded backend: "
                f"pass --mesh-shards P (P > 1)")
        if not sharded and args.mesh_shards > 1:
            raise SystemExit(
                f"--remote-index {args.remote_index} is single-device; with "
                f"--mesh-shards use one of "
                f"{('exact',) + registered_backends(sharded=True)}")
    elif args.index_opt:
        raise SystemExit("--index-opt needs --remote-index")

    # answer-cache tier (DESIGN.md §13): memoize exact index answers
    from repro.serve import AnswerCacheSpec, parse_answer_cache_opts

    answer_cache = None
    if args.answer_cache is not None:
        if args.policy != "acai":
            raise SystemExit(
                f"--policy {args.policy} serves oracle-exact (memoized) "
                f"answers by construction; --answer-cache only applies "
                f"to acai")
        if args.mesh_shards > 1:
            raise SystemExit(
                "--answer-cache needs the single-device cache (the "
                "sharded step owns candidate generation)")
        if index_spec is None:
            raise SystemExit(
                "--answer-cache fronts an index backend: pass "
                "--remote-index (flat = the exact fused scan)")
        try:
            answer_cache = AnswerCacheSpec(
                capacity=args.answer_cache,
                **parse_answer_cache_opts(args.answer_cache_opt))
        except (TypeError, ValueError) as e:
            raise SystemExit(str(e))
    elif args.answer_cache_opt:
        raise SystemExit("--answer-cache-opt needs --answer-cache")

    if args.churn_rate < 0 or not 0.0 < args.churn_warm <= 1.0:
        raise SystemExit("--churn-rate must be >= 0 and --churn-warm in (0, 1]")
    if args.churn_rate > 0 and args.mesh_shards > 1 and index_spec is not None:
        raise SystemExit(
            "--churn-rate on a sharded mesh serves through the exact "
            "masked scan (DESIGN.md §15): drop --remote-index (mutating a "
            "sharded index backend online is a ROADMAP open item)")

    # resilient remote tier (DESIGN.md §11): any fault/deadline/hedge flag
    # switches the semantic-cache tier onto the resilient serving path
    from repro.serve.remote import FaultSpec, FaultyRemote, \
        parse_outage_windows
    from repro.serve.resilience import ResilienceConfig, RetryConfig

    faulty = (args.remote_fault_rate > 0 or args.remote_fault_corrupt > 0
              or args.remote_fault_outage
              or args.remote_fault_latency_ms != 5.0
              or args.remote_fault_seed != 0)
    resilient = (faulty or args.deadline_ms is not None
                 or args.hedge_ms is not None or args.retries is not None)
    remote = resilience = None
    if resilient:
        if args.mesh_shards > 1:
            raise SystemExit(
                "the resilient serving path needs the single-device cache "
                "(a fault-aware sharded step is a ROADMAP open item)")
        try:
            fault = FaultSpec(
                error_rate=args.remote_fault_rate,
                corrupt_rate=args.remote_fault_corrupt,
                latency_ms=args.remote_fault_latency_ms,
                outages=parse_outage_windows(args.remote_fault_outage),
                seed=args.remote_fault_seed)
        except ValueError as e:
            raise SystemExit(str(e))
        remote = FaultyRemote(fault)
        retry = (RetryConfig(max_retries=args.retries)
                 if args.retries is not None else RetryConfig())
        resilience = ResilienceConfig(deadline_ms=args.deadline_ms,
                                      retry=retry, hedge_ms=args.hedge_ms)

    mesh = None
    if args.mesh_shards > 1:
        if jax.device_count() < args.mesh_shards:
            raise SystemExit(
                f"--mesh-shards {args.mesh_shards} needs that many devices "
                f"(have {jax.device_count()})")
        if args.catalog % args.mesh_shards:
            raise SystemExit("--catalog must divide by --mesh-shards")
        mesh = jax.make_mesh((1, args.mesh_shards), ("data", "model"))

    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    rng = np.random.default_rng(0)

    # --- continuous batching engine -------------------------------------
    engine = ServeEngine(params, cfg, batch=args.batch,
                         s_max=args.prompt_len + args.max_tokens + 8)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, args.prompt_len),
                           jnp.int32) for _ in range(args.requests)]
    t0 = time.time()
    for i, p in enumerate(prompts):
        engine.submit(i, p, args.max_tokens)
    steps = 0
    while engine.step():
        steps += 1
    dt = time.time() - t0
    total_tokens = sum(len(t) for t in engine.done.values())
    print(f"continuous batching: {len(engine.done)} requests, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s), {steps} engine steps")

    # --- semantic cache tier ---------------------------------------------
    catalog = jnp.asarray(rng.normal(size=(args.catalog, cfg.d_model)),
                          jnp.float32)
    catalog = catalog / jnp.linalg.norm(catalog, axis=1, keepdims=True)
    payloads = [f"cached-result-{i}" for i in range(args.catalog)]

    def gen_fn(prompt_tokens):
        return generate(params, cfg, prompt_tokens[None], steps=4)

    # under churn the cache starts on the warm prefix of the catalog and
    # the cold rows stream in online (one expiry per insert: a rolling
    # window, the mutable-catalog regime of DESIGN.md §10)
    n_warm = (max(int(round(args.churn_warm * args.catalog)), 1)
              if args.churn_rate > 0 else args.catalog)
    if mesh is not None and n_warm % args.mesh_shards:
        # the sharded slab keeps its capacity a multiple of the mesh
        # (owner-shard routing is block arithmetic); round the warm
        # window up — --catalog already divides by --mesh-shards
        n_warm += args.mesh_shards - n_warm % args.mesh_shards
    lm = SemanticCachedLM(params, cfg, catalog[:n_warm], payloads[:n_warm],
                          gen_fn, h=args.cache_size, k=4, mesh=mesh,
                          index_spec=index_spec, policy_spec=policy_spec,
                          remote=remote, resilience=resilience,
                          answer_cache=answer_cache)
    insert_ptr, expire_ptr, acc = n_warm, 0, 0.0
    events = 0
    for i in range(args.requests):
        acc += args.churn_rate
        while acc >= 1.0 and insert_ptr < args.catalog:
            lm.add_documents(catalog[insert_ptr][None], [payloads[insert_ptr]])
            lm.remove_documents([expire_ptr])
            insert_ptr += 1
            expire_ptr += 1
            events += 1
            acc -= 1.0
        toks = jnp.asarray(rng.integers(0, cfg.vocab, args.prompt_len),
                           jnp.int32)
        lm.query(toks)
    s = lm.stats
    tier = (f"sharded x{args.mesh_shards}" if mesh is not None
            else "single-device")
    tier += f", policy={lm.policy_spec.to_dict()}"
    if args.policy == "acai":
        tier += f", index={(index_spec.to_dict() if index_spec else 'exact')}"
    if args.churn_rate > 0:
        tier += f", churn={args.churn_rate:g} ({events} insert/expire events)"
    print(f"semantic cache ({tier}): {s.requests} requests, "
          f"{s.served_local}/{s.requests * lm.k} objects local, "
          f"{s.generated} generations, NAG={lm.nag:.3f}")
    if lm.answer_cache is not None:
        st = lm.answer_cache.stats()
        print(f"answer cache (capacity={st['capacity']}): "
              f"hit rate {st['hit_rate']:.3f} "
              f"({st['hits']}/{st['hits'] + st['misses']}), "
              f"{st['entries']} entries, {st['invalidations']} "
              f"invalidations (remove={st['inv_remove']} "
              f"add={st['inv_add']} refresh={st['inv_refresh']}), "
              f"{st['scans_skipped']} scans skipped of "
              f"{st['scans'] + st['scans_skipped']}, "
              f"unloads={st['unloads']} reloads={st['reloads']}")
    if resilient:
        ses = lm.policy.session
        c = ses.counters
        pct = ses.latency_percentiles()
        print(f"resilience (fault={fault.to_dict()}): "
              f"{c.remote_failures} remote failures, {c.retries} retries, "
              f"{c.degraded} degraded, {c.shed} shed, "
              f"{c.deadline_misses} deadline misses, {c.hedges} hedges, "
              f"{c.fast_fails} breaker fast-fails, "
              f"{ses.breaker.transitions} breaker transitions, "
              f"p50={pct['p50_ms']:.1f}ms p99={pct['p99_ms']:.1f}ms")

    # --- online serving engine (DESIGN.md §12) ---------------------------
    if args.arrival != "off":
        from repro.serve import embed_prompt
        from repro.serve.arrivals import ArrivalSpec
        from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                                       ServiceModel, serve_trace_online)

        service = ServiceModel()
        rate = args.offered_load * service.capacity_rps(args.batch)
        try:
            arrival = ArrivalSpec(kind=args.arrival, rate_rps=max(rate, 1.0),
                                  seed=args.arrival_seed)
        except ValueError as e:
            raise SystemExit(str(e))
        # arrival-side requests reuse the LM's own embedding map, so the
        # engine serves exactly what the semantic-cache tier would see
        prompt_batch = jnp.stack([
            jnp.asarray(rng.integers(0, cfg.vocab, args.prompt_len),
                        jnp.int32) for _ in range(args.requests)])
        reqs = jax.jit(jax.vmap(embed_prompt, in_axes=(None, 0)))(
            params, prompt_batch)
        out = serve_trace_online(
            lm.policy, np.asarray(reqs), arrival,
            former=BatchFormerConfig(
                max_batch=args.batch,
                max_wait_ms=(args.batch_window_ms
                             if args.batch_window_ms > 0 else None)),
            admission=AdmissionConfig(queue_cap=args.queue_cap,
                                      deadline_ms=args.shed_deadline_ms),
            service=service, slo_ms=args.slo_ms)
        line = (f"online serving (arrival={args.arrival} "
                f"load={args.offered_load:g} window={args.batch_window_ms:g}ms"
                f"): {out['served']}/{out['requests']} served, "
                f"{out['shed_total']} shed, mean batch "
                f"{out['mean_batch']:.2f}, p50={out['p50_ms']:.1f}ms "
                f"p99={out['p99_ms']:.1f}ms p999={out['p999_ms']:.1f}ms "
                f"(queue p50={out['queue_p50_ms']:.1f}ms)")
        if args.slo_ms is not None:
            line += f", goodput@{args.slo_ms:g}ms={out['goodput_slo']:.3f}"
        print(line)


if __name__ == "__main__":
    main()
