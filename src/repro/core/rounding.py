"""Randomised rounding: DepRound (Byrka et al.) and CoupledRounding (App. F).

DEPROUND maps y in conv(X) to x in X with
  (1) E[x_i] = y_i,
  (2) sum_i x_i = sum_i y_i  (= h, exactly),
  (3) E[prod_{i in S} (1 - x_i)] <= prod_{i in S} (1 - y_i)   (neg. correlation)
— the three properties Lemma 2/3 of the paper need for the (1-1/e) bound.

Implemented as a jittable pairwise walk (lax.scan): carry one "active"
fractional coordinate, SIMPLIFY it against the next coordinate, freeze
whichever becomes integral.  O(N), runs in float64 internally so the
cardinality h is preserved exactly.

COUPLEDROUNDING (Algorithm 2) couples x_{t+1} to (x_t, y_t, y_{t+1})
component-wise so that  E[x_{t+1}] = y_{t+1}  and
E||x_{t+1} - x_t||_1 = ||y_{t+1} - y_t||_1  (Theorem F.1) — sub-linear
cache-update traffic (Theorem F.2) at the price of a capacity constraint
held only in expectation (Chernoff bound Eq. (81)).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_DELTA = 1e-9


def _simplify(p, q, u):
    """One SIMPLIFY step: returns (p', q') with at least one integral."""
    alpha = jnp.minimum(1.0 - p, q)
    beta = jnp.minimum(p, 1.0 - q)
    denom = alpha + beta
    take_first = u * jnp.maximum(denom, _DELTA) < beta
    p1 = jnp.where(take_first, p + alpha, p - beta)
    q1 = jnp.where(take_first, q - alpha, q + beta)
    degenerate = denom <= _DELTA  # both already integral
    return jnp.where(degenerate, p, p1), jnp.where(degenerate, q, q1)


@jax.jit
def depround(key: jax.Array, y: jax.Array) -> jax.Array:
    """DepRound: y in [0,1]^N with integral sum h -> x in {0,1}^N, sum x = h."""
    n = y.shape[0]
    yf = y.astype(jnp.float64) if jax.config.jax_enable_x64 else y.astype(jnp.float32)
    us = jax.random.uniform(key, (n - 1,), dtype=yf.dtype)

    def step(carry, inp):
        aidx, aval = carry
        i, yi, u = inp
        p1, q1 = _simplify(aval, yi, u)
        p_int = (p1 <= _DELTA * 10) | (p1 >= 1.0 - _DELTA * 10)
        out_idx = jnp.where(p_int, aidx, i)
        out_val = jnp.where(p_int, jnp.round(p1), jnp.round(q1))
        new_aidx = jnp.where(p_int, i, aidx)
        new_aval = jnp.where(p_int, q1, p1)
        return (new_aidx, new_aval), (out_idx, out_val)

    idxs = jnp.arange(1, n)
    (faidx, faval), (out_idx, out_val) = jax.lax.scan(
        step, (jnp.asarray(0), yf[0]), (idxs, yf[1:], us)
    )
    x = jnp.zeros((n,), y.dtype)
    x = x.at[out_idx].set(out_val.astype(y.dtype))
    x = x.at[faidx].set(jnp.round(faval).astype(y.dtype))
    return x


@jax.jit
def coupled_rounding(
    key: jax.Array, x_t: jax.Array, y_t: jax.Array, y_tp1: jax.Array
) -> jax.Array:
    """Algorithm 2: component-wise coupled rounding."""
    delta = y_tp1 - y_t
    u = jax.random.uniform(key, x_t.shape, dtype=y_t.dtype)
    p_evict = -delta / jnp.maximum(y_t, _DELTA)
    p_fetch = delta / jnp.maximum(1.0 - y_t, _DELTA)
    evict = (x_t > 0.5) & (delta < 0) & (u < p_evict)
    fetch = (x_t < 0.5) & (delta > 0) & (u < p_fetch)
    return jnp.where(evict, 0.0, jnp.where(fetch, 1.0, x_t)).astype(x_t.dtype)


@jax.jit
def independent_rounding(key: jax.Array, y: jax.Array) -> jax.Array:
    """Relaxed-capacity rounding (App. F): x_i ~ Bernoulli(y_i) independently.

    E[x] = y; occupancy concentrates within (1 +/- delta) h  (Eq. (81))."""
    return jax.random.bernoulli(key, y).astype(y.dtype)


@partial(jax.jit, static_argnames=())
def movement(x_new: jax.Array, x_old: jax.Array) -> jax.Array:
    """Number of fetches |{i: x goes 0->1}| — the update cost of App. F."""
    return jnp.sum(jnp.maximum(x_new - x_old, 0.0))
