"""Property-based (hypothesis) tests on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
import hypothesis.extra.numpy as hnp  # noqa: E402

from repro.core import gain as G
from repro.core import projection as P


def _dists(n):
    return hnp.arrays(
        np.float32, (n,),
        elements=st.floats(0.0, 10.0, width=32, allow_nan=False),
    )


def _fracs(n):
    return hnp.arrays(
        np.float32, (n,),
        elements=st.floats(0.0, 1.0, width=32, allow_nan=False),
    )


@settings(max_examples=60, deadline=None)
@given(d=_dists(20), y=_fracs(20), z=_fracs(20),
       lam=st.floats(0.0, 1.0, width=32),
       k=st.integers(1, 5), c_f=st.floats(0.05, 3.0))
def test_gain_concave_along_segments(d, y, z, lam, k, c_f):
    """G(lam y + (1-lam) z) >= lam G(y) + (1-lam) G(z)  (Sec. IV-D)."""
    dj = jnp.array(d)
    gy = float(G.gain_value(dj, jnp.array(y), k, c_f))
    gz = float(G.gain_value(dj, jnp.array(z), k, c_f))
    mid = lam * y + (1 - lam) * z
    gm = float(G.gain_value(dj, jnp.array(mid), k, c_f))
    assert gm >= lam * gy + (1 - lam) * gz - 1e-3 * (1 + abs(gm))


@settings(max_examples=60, deadline=None)
@given(d=_dists(20), y=_fracs(20), k=st.integers(1, 5), c_f=st.floats(0.05, 3.0))
def test_lemma1_sandwich(d, y, k, c_f):
    dj, yj = jnp.array(d), jnp.array(y)
    g = float(G.gain_value(dj, yj, k, c_f))
    low = float(G.lower_bound_l(dj, yj, k, c_f))
    assert low <= g + 1e-3 * (1 + abs(g))
    assert g <= low / (1 - 1 / np.e) + 1e-3 * (1 + abs(g))


@settings(max_examples=60, deadline=None)
@given(d=_dists(20), y=_fracs(20), k=st.integers(1, 5), c_f=st.floats(0.05, 3.0),
       i=st.integers(0, 19), delta=st.floats(0.0, 1.0, width=32))
def test_gain_monotone_in_cache_state(d, y, k, c_f, i, delta):
    """Storing more of any object never decreases the gain."""
    y2 = y.copy()
    y2[i] = min(1.0, y2[i] + delta)
    g1 = float(G.gain_value(jnp.array(d), jnp.array(y), k, c_f))
    g2 = float(G.gain_value(jnp.array(d), jnp.array(y2), k, c_f))
    assert g2 >= g1 - 1e-3 * (1 + abs(g1))


@settings(max_examples=60, deadline=None)
@given(d=_dists(24), k=st.integers(1, 5), c_f=st.floats(0.05, 3.0),
       bits=st.integers(0, 2 ** 24 - 1))
def test_serve_cost_never_exceeds_empty_cost(d, k, c_f, bits):
    x = np.array([(bits >> i) & 1 for i in range(24)], np.float32)
    res = G.serve(jnp.array(d), jnp.array(x), k, c_f)
    empty = float(G.empty_cache_cost(jnp.array(d), k, c_f))
    assert float(res.cost) <= empty + 1e-4
    assert float(res.gain) >= -1e-4


@settings(max_examples=40, deadline=None)
@given(z=hnp.arrays(np.float32, (60,),
                    elements=st.floats(0.0, 100.0, width=32, allow_nan=False)),
       h=st.integers(1, 59))
def test_projection_feasibility(z, h):
    z = z + np.float32(1e-6)  # keep strictly inside the entropy domain
    y = np.array(P.capped_simplex_negentropy(jnp.array(z), h))
    assert (y >= -1e-6).all() and (y <= 1 + 1e-5).all()
    assert abs(y.sum() - h) < 2e-3 * h + 1e-3


@settings(max_examples=40, deadline=None)
@given(z=hnp.arrays(np.float32, (60,),
                    elements=st.floats(-10.0, 10.0, width=32)),
       h=st.integers(1, 59))
def test_euclidean_projection_feasibility(z, h):
    y = np.array(P.capped_simplex_euclidean(jnp.array(z), h))
    assert (y >= -1e-6).all() and (y <= 1 + 1e-5).all()
    assert abs(y.sum() - h) < 1e-2


# ---------------------------------------------------------------------------
# online serving engine invariants (DESIGN.md §12)
# ---------------------------------------------------------------------------

_gaps = st.lists(st.floats(0.0, 20.0, allow_nan=False,
                           allow_infinity=False),
                 min_size=1, max_size=64)


@settings(max_examples=60, deadline=None)
@given(gaps=_gaps,
       max_batch=st.integers(1, 9),
       max_wait=st.one_of(st.none(), st.floats(0.0, 30.0)),
       queue_cap=st.one_of(st.none(), st.integers(1, 6)),
       deadline=st.one_of(st.none(), st.floats(1.0, 40.0)))
def test_engine_queue_conservation_and_monotone_timestamps(
        gaps, max_batch, max_wait, queue_cap, deadline):
    """Under arbitrary arrival bursts and window/admission configs: every
    submitted request is exactly one of {served, shed}, and per-request
    virtual timestamps are monotone (arrival <= batch-form <=
    completion)."""
    from repro.core.policy import shed_only_metrics
    from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                                   OnlineServingEngine, ServiceModel)

    t = len(gaps)
    times = np.cumsum(np.asarray(gaps))  # bursts = runs of zero gaps
    served_rids = []

    class Stub:
        def serve_update_batch(self, rs, ts=None):
            rs = np.atleast_2d(rs)
            served_rids.extend(int(r[0]) for r in rs)
            b = rs.shape[0]
            return shed_only_metrics(b)._replace(shed=np.zeros(b, np.int32))

    reqs = np.zeros((t, 4), np.float32)
    reqs[:, 0] = np.arange(t)
    eng = OnlineServingEngine(
        Stub(),
        former=BatchFormerConfig(max_batch=max_batch, max_wait_ms=max_wait),
        admission=AdmissionConfig(queue_cap=queue_cap, deadline_ms=deadline),
        service=ServiceModel(base_ms=1.0, per_request_ms=0.5))
    res = eng.run(reqs, times)

    # conservation: {served} and {shed} partition the submitted set
    assert res["requests"] == t
    assert res["served"] + res["shed_total"] == t
    shed = res["shed"]
    assert sorted(served_rids) == np.flatnonzero(~shed).tolist()
    assert res["served"] == len(served_rids)
    # the policy saw each served request exactly once
    assert len(set(served_rids)) == len(served_rids)
    # timestamps monotone per request, on the nondecreasing virtual clock
    assert (res["arrival_ms"] == times).all()
    assert (res["form_ms"] >= res["arrival_ms"] - 1e-9).all()
    assert (res["done_ms"] >= res["form_ms"] - 1e-9).all()
    # formed batches respect the window's size bound
    if res["batch_hist"]:
        assert max(res["batch_hist"]) <= max_batch
