"""Collective-budget regression pins (DESIGN.md §15).

`repro.core.distributed.collectives_per_step` statically walks the jaxpr
of a jitted sharded step and tallies cross-device collective primitives.
These tests pin the EXACT per-step budget of every sharded step variant:
the PR-2 path spent 11 collectives per exact step (4 merge all-gathers +
2 masked-psum state gathers + 3 subgradient-routing gathers + 2 in the
projection exchange); the fused layout spends 2 on a serving mesh.  A
refactor that reintroduces per-candidate or per-state gathers fails here
before it ever reaches a benchmark.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core import policy
from repro.core.distributed import (build_sharded_ivf, collectives_per_step,
                                    make_mutable_step_sharded,
                                    make_retrieval_step, make_step_sharded)
from repro.core.oma import OMAConfig

N, D, B = 256, 8, 8


@pytest.fixture(scope="module")
def cfg():
    return policy.AcaiConfig(h=16, k=4, c_f=1.0, c_remote=16, c_local=8,
                             oma=OMAConfig(eta=0.01, projection_topk=48))


@pytest.fixture(scope="module")
def catalog():
    return jax.random.normal(jax.random.PRNGKey(0), (N, D))


def _mesh(shape):
    return jax.make_mesh(shape, ("data", "model"))


def _static_counts(cfg, catalog, mesh, **kw):
    step = make_step_sharded(cfg, mesh, catalog, B, **kw)
    state = policy.init_state(N, cfg)
    return collectives_per_step(step, state, jnp.zeros((B, D)))


def _mutable_counts(cfg, catalog, mesh):
    step = make_mutable_step_sharded(cfg, mesh, B)
    state = policy.init_state(N, cfg)
    return collectives_per_step(step, state, jnp.zeros((B, D)), catalog,
                                jnp.ones((N,), bool))


def test_exact_step_serving_mesh_is_two_gathers(cfg, catalog):
    """(1, 1) serving mesh: ONE packed candidate merge + ONE packed
    projection exchange; the data-parallel routing gather is skipped on a
    size-1 batch axis.  11 -> 2 vs the PR-2 layout (5.5x)."""
    total, counts = _static_counts(cfg, catalog, _mesh((1, 1)))
    assert counts == {"all_gather": 2}, counts
    assert total == 2


def test_exact_step_data_parallel_adds_one_routing_gather(
        cfg, catalog, multi_device):
    """(2, 4): the packed [g, id] subgradient-routing gather over `data`
    is the only extra collective.  11 -> 3 (3.7x)."""
    total, counts = _static_counts(cfg, catalog, _mesh((2, 4)))
    assert counts == {"all_gather": 3}, counts
    assert total == 3


def test_mutable_step_costs_the_same_as_static(cfg, catalog, multi_device):
    """Catalog mutability (runtime slab + liveness mask, live-mask
    projection) adds ZERO communication."""
    assert _mutable_counts(cfg, catalog, _mesh((1, 1)))[1] == {
        "all_gather": 2}
    assert _mutable_counts(cfg, catalog, _mesh((2, 4)))[1] == {
        "all_gather": 3}


def test_ivf_and_chunk_paths_spend_one_overlap_gather(
        cfg, catalog, multi_device):
    """The approximate paths keep remote and cached-row merges separate so
    the remote exchange overlaps the local cached-row scan: exactly one
    extra all-gather vs the exact path, nothing else."""
    mesh = _mesh((2, 4))
    ivf = build_sharded_ivf(catalog, 4, nlist=8, nprobe=4)
    total, counts = _static_counts(cfg, catalog, mesh, ivf=ivf)
    assert counts == {"all_gather": 4}, counts
    total, counts = _static_counts(cfg, catalog, mesh, scan_chunk=64)
    assert counts == {"all_gather": 4}, counts


def test_retrieval_cell_budget(cfg, catalog, multi_device):
    """The roofline cell: merge + routing + projection gathers plus its
    two metric pmeans (psum-backed) — and nothing per candidate."""
    mesh = _mesh((2, 4))
    step = make_retrieval_step(mesh, n_shard=N // 4, d=D, c=16, k=4,
                               c_f=1.0, h=16, eta=0.01, top_a=32)
    total, counts = collectives_per_step(
        step, catalog, jnp.full((N,), 0.1), jnp.zeros((B, D)))
    assert counts == {"all_gather": 3, "psum": 2}, counts


def test_budget_is_independent_of_candidate_counts(cfg, catalog,
                                                   multi_device):
    """The tripwire this harness exists for: widening the candidate slabs
    must not widen the collective count (one packed payload, not one
    gather per candidate array)."""
    mesh = _mesh((2, 4))
    wide = policy.AcaiConfig(h=16, k=4, c_f=1.0, c_remote=48, c_local=24,
                             oma=OMAConfig(eta=0.01, projection_topk=48))
    assert (_static_counts(cfg, catalog, mesh)[0]
            == _static_counts(wide, catalog, mesh)[0] == 3)


def test_counts_are_a_dict_by_primitive(cfg, catalog):
    total, counts = _static_counts(cfg, catalog, _mesh((1, 1)))
    assert total == sum(counts.values())
    assert all(isinstance(v, int) and v > 0 for v in counts.values())
