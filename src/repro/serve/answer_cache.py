"""Answer-cache tier: exact top-k memoization in front of the index
(DESIGN.md §13).

AÇAI's remote index pays a full fused scan per query even when a
Zipf-skewed trace repeats the same hot queries thousands of times — the
head-heavy regime where classical similarity-caching analyses put all
the value.  This tier memoizes the index's **exact answers** `(dists,
ids)` keyed by query identity and serves repeats without touching the
scan, with three hard guarantees:

* **Bitwise parity** — a hit returns byte-identical arrays to what the
  uncached path would compute.  The contract that makes this cheap to
  guarantee: a batch is served from the store only when *every* row
  hits; any miss recomputes the full batch through the inner index
  (same shapes, same code as cache-off) and re-stores all rows.  Since
  the fused scans are per-row at a fixed batch shape, the recomputed
  values equal the stored ones bitwise (pinned by
  tests/test_answer_cache.py across every registered backend).
* **Precise churn invalidation** — `remove(ids)` drops exactly the
  entries whose answer contains a removed id (inverted id→entry map);
  `add(vectors)` invalidates only entries whose k-th distance reaches a
  new row (conservative radius check); `refresh()` bumps an epoch and
  flushes.  Backends whose mutations rewire existing structures (NSW's
  reverse links) or whose reported distances are approximate (IVFPQ's
  ADC) declare it via `answer_unstable_add/_remove` class flags and get
  a conservative full flush instead — parity beats hit-rate.
* **Idle unload** (the FAISS-unload analogue from the ChibiBooru
  exemplar): after `idle_unload_ms` virtual-clock ms without a scan,
  the wrapper offloads the inner index's heavy device structures
  (IVF invlists, NSW adjacency, LSH buckets …) to host memory and
  reloads them — bitwise intact, never rebuilt — on the first miss.
  Hits served while unloaded stay unloaded.

Key scheme: the default key is a 128-bit blake2b digest of the query
vector's float32 bytes ("hashed-vector identity" — trace-registry
requests with jitter 0 are catalog rows, so repeats collide exactly).
Direct API callers replaying a registered trace can pass `rids=`
(catalog-row request ids) to key by identity without hashing; the two
namespaces coexist.

`AnswerCacheSpec` is the serializable config knob, registry-style like
`IndexSpec`/`PolicySpec`: `AcaiCache(..., answer_cache=spec)`,
`build_policy(..., answer_cache=...)`, `SemanticCachedLM(...,
answer_cache=...)` and `launch/serve.py --answer-cache/--answer-cache-
opt` all take it (or its dict/int forms).  `capacity=0` is the
documented pass-through mode: identical serving code with hashing and
memoization bypassed — the cache-off arm of every parity pin.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "AnswerCacheSpec", "AnswerCache", "CachedIndex",
    "resolve_answer_cache_spec", "parse_answer_cache_opts",
]


@dataclasses.dataclass(frozen=True)
class AnswerCacheSpec:
    """Serializable answer-cache config (the `IndexSpec` of this tier).

    capacity        LRU entry budget; 0 = pass-through (cache machinery
                    installed, memoization bypassed — the cache-off arm
                    of the parity pins).
    hit_ms          virtual service time of an engine fast-path hit
                    (DESIGN.md §13): a hit's answer completes at
                    arrival + hit_ms without entering the batch former.
    idle_unload_ms  virtual-clock idle threshold after which the inner
                    index's heavy structures are offloaded to host
                    memory (None = never unload).
    """

    capacity: int = 4096
    hit_ms: float = 0.2
    idle_unload_ms: Optional[float] = None

    def __post_init__(self):
        if self.capacity < 0:
            raise ValueError(f"capacity must be >= 0: {self.capacity}")
        if self.hit_ms < 0:
            raise ValueError(f"hit_ms must be >= 0: {self.hit_ms}")
        if self.idle_unload_ms is not None and self.idle_unload_ms <= 0:
            raise ValueError(
                f"idle_unload_ms must be > 0 or None: {self.idle_unload_ms}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AnswerCacheSpec":
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(
                f"AnswerCacheSpec: unknown fields {sorted(unknown)} "
                f"(known: {sorted(f.name for f in dataclasses.fields(cls))})")
        return cls(**d)

    def with_params(self, **kw) -> "AnswerCacheSpec":
        return dataclasses.replace(self, **kw)


def resolve_answer_cache_spec(value) -> Optional[AnswerCacheSpec]:
    """Normalize every accepted `answer_cache=` form to a spec or None:
    None/False → None (no tier), True → default spec, int → capacity,
    dict → `from_dict`, spec → itself."""
    if value is None or value is False:
        return None
    if value is True:
        return AnswerCacheSpec()
    if isinstance(value, AnswerCacheSpec):
        return value
    if isinstance(value, int):
        return AnswerCacheSpec(capacity=value)
    if isinstance(value, dict):
        return AnswerCacheSpec.from_dict(value)
    raise TypeError(
        f"answer_cache must be None/bool/int/dict/AnswerCacheSpec, "
        f"got {type(value).__name__}")


def parse_answer_cache_opts(pairs) -> dict:
    """KEY=VALUE CLI opts → typed spec fields (the `parse_index_opts`
    idiom): int → float → str coercion, 'none' → None."""
    out: dict = {}
    for item in pairs or ():
        if "=" not in item:
            raise ValueError(
                f"--answer-cache-opt needs KEY=VALUE, got {item!r}")
        key, val = item.split("=", 1)
        if val.lower() in ("none", "null"):
            out[key] = None
            continue
        for cast in (int, float):
            try:
                out[key] = cast(val)
                break
            except ValueError:
                continue
        else:
            out[key] = val
    return out


class _Entry(NamedTuple):
    ids: np.ndarray    # (k,) int32 answer ids (-1 underflow slots)
    d: np.ndarray      # (k,) float32 answer distances (+inf on underflow)
    q: np.ndarray      # (dim,) float32 query copy (radius checks)
    kth: float         # largest finite distance; +inf when underfull
                       # (an underfull answer can always gain a new row)


def _vector_key(row: np.ndarray) -> bytes:
    """128-bit digest of the query's float32 bytes — hashed-vector
    identity.  Equal vectors (e.g. repeated catalog-row requests from
    the trace registry at jitter 0) collide exactly; distinct float32
    vectors collide with negligible probability."""
    buf = np.ascontiguousarray(row, dtype=np.float32)
    return hashlib.blake2b(buf.tobytes(), digest_size=16).digest()


class AnswerCache:
    """LRU memo of exact index answers with precise churn invalidation.

    Entries are keyed `(namespace key, k)` — the same query at two
    fan-outs is two entries.  The store is the source of truth for the
    batch-level contract enforced by `CachedIndex`: serve from memory
    only on an all-hit batch, otherwise recompute everything.
    """

    def __init__(self, spec: AnswerCacheSpec):
        self.spec = spec
        self._store: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._inv: dict[int, set] = {}     # object id -> entry keys
        self._qkeys: dict = {}             # namespace key -> refcount
        self.epoch = 0
        self.hits = self.misses = 0
        self.stores = self.evictions = 0
        self.invalidations = 0
        self.inv_remove = self.inv_add = self.inv_refresh = 0
        self.scans = self.scans_skipped = 0
        # per-serving-step deltas, drained by AcaiCache's metric booking
        self._step_hit_mask: Optional[np.ndarray] = None
        self._inval_since_take = 0

    # -- keys ---------------------------------------------------------------

    @staticmethod
    def _keys_of(rs: np.ndarray, k: int, rids=None) -> list:
        if rids is not None:
            rids = np.atleast_1d(np.asarray(rids))
            if len(rids) != len(rs):
                raise ValueError(
                    f"rids length {len(rids)} != batch {len(rs)}")
            return [(("rid", int(r)), k) for r in rids]
        return [(("vec", _vector_key(row)), k) for row in rs]

    # -- lookup / store -----------------------------------------------------

    def lookup_batch(self, rs: np.ndarray, k: int, rids=None):
        """Per-row entries (or None) + hit mask; counts hits/misses and
        refreshes LRU recency of present entries."""
        keys = self._keys_of(rs, k, rids)
        entries, mask = [], np.zeros(len(keys), bool)
        for i, key in enumerate(keys):
            e = self._store.get(key)
            if e is not None:
                self._store.move_to_end(key)
                mask[i] = True
            entries.append(e)
        self.hits += int(mask.sum())
        self.misses += int((~mask).sum())
        self._note_step(mask)
        return entries, mask

    def peek(self, row, k: Optional[int] = None) -> bool:
        """Non-counting presence probe (the engine's arrival-time fast
        path checks this without perturbing hit statistics or LRU
        order).  With `k=None` any fan-out of the query counts."""
        if self.spec.capacity == 0:
            return False
        nk = ("vec", _vector_key(np.asarray(row)))
        if k is None:
            return nk in self._qkeys
        return (nk, k) in self._store

    def store_batch(self, rs: np.ndarray, k: int, d: np.ndarray,
                    ids: np.ndarray, rids=None) -> None:
        if self.spec.capacity == 0:
            return
        keys = self._keys_of(rs, k, rids)
        for key, row, drow, irow in zip(keys, rs, d, ids):
            self._put(key, np.asarray(row, np.float32),
                      np.asarray(drow), np.asarray(irow))

    def _put(self, key, q, d, ids) -> None:
        if key in self._store:
            self._drop(key)  # overwrite: rebuild inverted-map links
        finite = np.isfinite(d) & (ids >= 0)
        kth = float(d[finite].max()) if finite.all() else float("inf")
        self._store[key] = _Entry(ids=ids.astype(np.int32, copy=True),
                                  d=np.asarray(d, copy=True), q=q.copy(),
                                  kth=kth)
        self._qkeys[key[0]] = self._qkeys.get(key[0], 0) + 1
        for oid in ids[ids >= 0].tolist():
            self._inv.setdefault(int(oid), set()).add(key)
        self.stores += 1
        while len(self._store) > self.spec.capacity:
            old = next(iter(self._store))
            self._drop(old)
            self.evictions += 1

    def _drop(self, key) -> None:
        e = self._store.pop(key)
        for oid in e.ids[e.ids >= 0].tolist():
            s = self._inv.get(int(oid))
            if s is not None:
                s.discard(key)
                if not s:
                    del self._inv[int(oid)]
        cnt = self._qkeys.get(key[0], 0) - 1
        if cnt <= 0:
            self._qkeys.pop(key[0], None)
        else:
            self._qkeys[key[0]] = cnt

    # -- invalidation (DESIGN.md §13) ---------------------------------------

    def invalidate_removed(self, ids) -> int:
        """Drop exactly the entries whose answer contains a removed id
        (inverted map walk — precise, not a flush)."""
        doomed = set()
        for oid in np.atleast_1d(np.asarray(ids)).tolist():
            doomed |= self._inv.get(int(oid), set())
        for key in doomed:
            self._drop(key)
        return self._book_invalidations(len(doomed), "remove")

    def invalidate_added(self, vectors) -> int:
        """Conservative radius check: a new row can only change an
        answer whose k-th distance reaches it, so invalidate entries
        with `min_j d2(q, v_j) <= kth` (float64, small safety margin —
        over-invalidating is safe, under-invalidating is not).
        Underfull answers (`kth = +inf`) always invalidate."""
        if not self._store:
            return 0
        v = np.atleast_2d(np.asarray(vectors, np.float64))
        keys = list(self._store.keys())
        q = np.stack([self._store[k].q for k in keys]).astype(np.float64)
        kth = np.array([self._store[k].kth for k in keys])
        d2 = ((q[:, None, :] - v[None, :, :]) ** 2).sum(-1).min(axis=1)
        hit = d2 <= kth * (1 + 1e-6) + 1e-6
        for key in (k for k, h in zip(keys, hit) if h):
            self._drop(key)
        return self._book_invalidations(int(hit.sum()), "add")

    def remap_ids(self, remap: np.ndarray) -> None:
        """Rewrite every stored answer's object ids through a compaction
        remap (DESIGN.md §14) and rebuild the inverted id→keys map in the
        new id space.  Entries never reference dead rows
        (`invalidate_removed` is precise), so every stored id must land
        on a new row; -1 underflow slots pass through.  The request-key
        namespace (`_qkeys`) is untouched — rids key *queries*, not
        objects — and the answers stay bitwise valid: compaction's remap
        is order-preserving and moves rows without changing distances."""
        remap = np.asarray(remap, np.int32)
        # build the remapped store + inverted map fully before committing,
        # so the dead-row error below leaves the cache untouched (a
        # half-remapped store with a stale inverted map would corrupt
        # every later invalidate_removed)
        store: "OrderedDict[tuple, _Entry]" = OrderedDict()
        inv: dict[int, set] = {}
        for key, e in self._store.items():
            ok = e.ids >= 0
            if ok.any() and (remap[e.ids[ok]] < 0).any():
                raise ValueError(
                    "remap_ids: stored answer references a dead row — "
                    "invalidate_removed must run before compaction")
            new_ids = np.where(ok, remap[np.clip(e.ids, 0, None)],
                               -1).astype(np.int32)
            store[key] = e._replace(ids=new_ids)
            for oid in new_ids[new_ids >= 0].tolist():
                inv.setdefault(int(oid), set()).add(key)
        self._store = store
        self._inv = inv
        self.epoch += 1  # the id space changed, the entries survived

    def flush(self, reason: str = "refresh") -> int:
        """Drop everything (epoch bump): `refresh()` rebuilds quantizer
        structures, and unstable-mutation backends route add/remove
        here too (parity over hit-rate)."""
        n = len(self._store)
        self._store.clear()
        self._inv.clear()
        self._qkeys.clear()
        self.epoch += 1
        return self._book_invalidations(n, reason)

    def _book_invalidations(self, n: int, reason: str) -> int:
        self.invalidations += n
        self._inval_since_take += n
        if reason == "remove":
            self.inv_remove += n
        elif reason == "add":
            self.inv_add += n
        else:
            self.inv_refresh += n
        return n

    # -- per-step metric deltas ---------------------------------------------

    def _note_step(self, mask: np.ndarray) -> None:
        self._step_hit_mask = mask

    def take_step_stats(self, batch: int):
        """Drain the last serving step's deltas: (hit mask (B,),
        invalidations since the previous drain).  Called by
        `AcaiCache._serve_batch_direct` to populate the `answer_*`
        StepMetrics counters."""
        mask = self._step_hit_mask
        if mask is None or len(mask) != batch:
            mask = np.zeros(batch, bool)
        self._step_hit_mask = None
        inval, self._inval_since_take = self._inval_since_take, 0
        return mask, inval

    # -- reporting ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._store)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "capacity": self.spec.capacity,
            "entries": len(self._store),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "stores": self.stores,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "inv_remove": self.inv_remove,
            "inv_add": self.inv_add,
            "inv_refresh": self.inv_refresh,
            "scans": self.scans,
            "scans_skipped": self.scans_skipped,
            "epoch": self.epoch,
        }


class CachedIndex:
    """Answer-cache front for any registered index backend: the full
    `Index` protocol (so `mutable_index_candidate_fn` and `AcaiCache`'s
    mutation hooks drive it unchanged), with `query` memoized through an
    `AnswerCache`, mutations routed through the invalidation rules, and
    the idle-unload hook on a virtual clock.

    Batch contract (the parity guarantee): a batch is answered from the
    store only when every row hits; any miss recomputes the whole batch
    through the inner index and re-stores it.  See module docstring.
    """

    #: attributes never offloaded: the mutable-slab assembly reads them
    #: every step, hits included.
    _KEEP_LOADED = ("embeddings", "valid")

    def __init__(self, inner, spec: AnswerCacheSpec):
        self.inner = inner
        self.spec = spec
        self.cache = AnswerCache(spec)
        self._now_ms = 0.0
        self._last_scan_ms = 0.0
        self._offloaded: list[str] = []   # attr names held on host
        self.unloads = self.reloads = 0

    # -- Index protocol proxies ---------------------------------------------

    @property
    def embeddings(self):
        return self.inner.embeddings

    @property
    def valid(self):
        return self.inner.valid

    @property
    def capacity(self):
        return self.inner.capacity

    @property
    def n_slots(self):
        return self.inner.n_slots

    @property
    def n(self):
        return self.inner.n

    @property
    def exact_distances(self):
        return getattr(self.inner, "exact_distances", False)

    def live_rows(self):
        return self.inner.live_rows()

    def memory_bytes(self) -> int:
        return self.inner.memory_bytes()

    # -- queries ------------------------------------------------------------

    def query(self, rs, k: int):
        # host-side normalisation: the lookup hashes host bytes, and a
        # hit must never pay a device round-trip — the scan paths hand
        # the batch to the inner index's jit boundary, which device-puts
        # the same bytes either way (bitwise-identical candidates)
        rs_np = np.atleast_2d(np.asarray(rs))
        if self.spec.capacity == 0:  # pass-through arm of the parity pins
            self._ensure_loaded()
            self._last_scan_ms = self._now_ms
            self.cache.scans += 1
            self.cache._note_step(np.zeros(rs_np.shape[0], bool))
            return self.inner.query(rs_np, k)
        entries, mask = self.cache.lookup_batch(rs_np, k)
        if mask.all():
            # all-hit: serve the memoized answers host-side — no scan,
            # no device transfer (and never reload an unloaded index);
            # the jitted slab assembly converts at its own boundary
            self.cache.scans_skipped += 1
            d = np.stack([e.d for e in entries])
            ids = np.stack([e.ids for e in entries])
            return d, ids
        self._ensure_loaded()
        self._last_scan_ms = self._now_ms
        self.cache.scans += 1
        d, ids = self.inner.query(rs_np, k)
        self.cache.store_batch(rs_np, k, np.asarray(d), np.asarray(ids))
        return d, ids

    # -- mutation hooks (invalidation rules, DESIGN.md §13) -----------------

    def add(self, vectors):
        self._ensure_loaded()
        ids = self.inner.add(vectors)
        if (getattr(self.inner, "answer_unstable_add", False)
                or not self.exact_distances):
            # graph-rewiring insertion (NSW reverse links) or
            # approximate reported distances (IVFPQ ADC): the radius
            # check cannot bound the answer drift — flush instead
            self.cache.flush("add")
        else:
            self.cache.invalidate_added(np.asarray(vectors))
        return ids

    def remove(self, ids) -> None:
        self._ensure_loaded()
        self.inner.remove(ids)
        if getattr(self.inner, "answer_unstable_remove", False):
            # e.g. NSW: the first tombstone flips beam-search masking,
            # which can reroute answers that never contained the id
            self.cache.flush("remove")
        else:
            self.cache.invalidate_removed(ids)

    def refresh(self) -> None:
        self._ensure_loaded()
        self.inner.refresh()
        self.cache.flush("refresh")

    def refresh_start(self) -> None:
        """Phase 1 of the double-buffered refresh (DESIGN.md §14): shadow
        rebuild in the inner index.  The stale structures — and the
        answers memoized over them — keep serving; no flush until the
        swap actually changes what the index would answer."""
        self._ensure_loaded()
        self.inner.refresh_start()

    def refresh_swap(self) -> None:
        """Phase 2: install the shadow, then flush (the store memoized
        the stale structure's answers).  A swap with no pending shadow
        (discarded by an interleaved mutation, or never started) installs
        nothing — the index is unchanged, so the store stays."""
        if not self.refresh_pending:
            self.inner.refresh_swap()  # inner no-op, kept for symmetry
            return
        self.inner.refresh_swap()
        self.cache.flush("refresh")

    @property
    def refresh_pending(self) -> bool:
        return bool(getattr(self.inner, "refresh_pending", False))

    def compact(self) -> np.ndarray:
        """Epoch compaction pass-through: compact the inner index and
        push the id remap into the stored answers.  The remap-and-keep
        path is safe only for structure-free exact backends
        (`answer_stable_compact`, today just flat): the remap is
        order-preserving, so even top-k tie-breaks survive renumbering.
        Every backend with auxiliary structures flushes — compaction
        rebuilds them over the live set (IVF re-trains its quantizer,
        LSH re-draws truncation-capped buckets), the same
        answer-changing rebuild for which `refresh()` flushes, so stored
        answers could diverge from the post-compaction index."""
        self._ensure_loaded()
        remap = self.inner.compact()
        if (getattr(self.inner, "answer_stable_compact", False)
                and self.exact_distances):
            self.cache.remap_ids(remap)
        else:
            self.cache.flush("compact")
        return remap

    # -- idle unload (virtual clock) ----------------------------------------

    def tick(self, now_ms: float) -> None:
        """Advance the virtual clock (the serving engine calls this at
        every dispatch instant); unload once the index has sat idle —
        no fused scan — for `idle_unload_ms`."""
        self._now_ms = max(self._now_ms, float(now_ms))
        if (self.spec.idle_unload_ms is not None and self.loaded
                and self._now_ms - self._last_scan_ms
                >= self.spec.idle_unload_ms):
            self.unload()

    @property
    def loaded(self) -> bool:
        return not self._offloaded

    def unload(self) -> int:
        """Offload the inner index's heavy device arrays (everything
        except the embeddings slab + liveness mask) to host memory.
        Returns bytes freed from the device.  The structures are moved,
        not rebuilt, so a later reload serves bitwise-identical answers
        (unlike `refresh()`, which re-trains quantizers)."""
        if not self.loaded:
            return 0
        freed = 0
        for name, val in list(vars(self.inner).items()):
            if name in self._KEEP_LOADED or not isinstance(val, jax.Array):
                continue
            setattr(self.inner, name, np.asarray(val))
            self._offloaded.append(name)
            freed += val.nbytes
        if self._offloaded:
            self.unloads += 1
        return freed

    def _ensure_loaded(self) -> None:
        if self.loaded:
            return
        for name in self._offloaded:
            val = getattr(self.inner, name)
            if isinstance(val, np.ndarray):
                setattr(self.inner, name, jnp.asarray(val))
        self._offloaded = []
        self.reloads += 1
        self._last_scan_ms = self._now_ms

    # -- reporting ----------------------------------------------------------

    def stats(self) -> dict:
        s = self.cache.stats()
        s.update(unloads=self.unloads, reloads=self.reloads,
                 loaded=self.loaded)
        return s

    def __repr__(self) -> str:
        return (f"CachedIndex({type(self.inner).__name__}, "
                f"capacity={self.spec.capacity}, "
                f"entries={len(self.cache)})")
