"""AÇAI core — the paper's contribution as composable JAX modules.

  costs       — dissimilarity/fetching cost model, augmented catalog (Sec. II/IV-A)
  gain        — service cost Eq. (5), caching gain Eq. (7), subgradient Eq. (55)
  mirror      — mirror maps (negative entropy / Euclidean)
  projection  — Bregman projections onto the capped simplex (Sec. IV-F)
  oma         — Online Mirror Ascent, Algorithm 1
  rounding    — DepRound + CoupledRounding (App. F)
  policy      — AcaiCache: serving (Eq. 2) + state updates, trace replay
  policy_api  — CachePolicy protocol, PolicySpec, build_policy registry (§9)
  baselines   — LRU, SIM-LRU, CLS-LRU, RND-LRU, QCACHE (Sec. II/V)
  trace       — synthetic traces (Sec. V-A) + TraceSpec scenario registry
  ref         — pure-numpy oracles for every equation (test-only)
"""

from repro.core.costs import CostModel, calibrate_fetch_cost, pairwise_dissimilarity
from repro.core.gain import gain_and_subgradient, gain_value, serve
from repro.core.oma import OMAConfig, oma_update, theoretical_eta, uniform_state
from repro.core.policy import (AcaiCache, AcaiConfig, init_state, make_replay,
                               make_replay_batched, make_step,
                               make_step_batched)
from repro.core.policy_api import (CachePolicy, PolicySpec, build_policy,
                                   parse_policy_opts, registered_policies)
from repro.core.rounding import coupled_rounding, depround, independent_rounding
from repro.core.trace import TraceSpec, build_trace, registered_traces

__all__ = [
    "AcaiCache",
    "AcaiConfig",
    "CachePolicy",
    "CostModel",
    "PolicySpec",
    "TraceSpec",
    "build_policy",
    "build_trace",
    "parse_policy_opts",
    "registered_policies",
    "registered_traces",
    "OMAConfig",
    "calibrate_fetch_cost",
    "coupled_rounding",
    "depround",
    "gain_and_subgradient",
    "gain_value",
    "independent_rounding",
    "init_state",
    "make_replay",
    "make_replay_batched",
    "make_step",
    "make_step_batched",
    "oma_update",
    "pairwise_dissimilarity",
    "serve",
    "theoretical_eta",
    "uniform_state",
]
