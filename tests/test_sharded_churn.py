"""Sharded-churn coverage (DESIGN.md §15).

The mutable-catalog serving mode on a mesh: bitwise 1-device parity with
the single-device mutable path (including churn, growth and compaction),
the invalidation invariant across real multi-device shards, the
heavy-removal projection edges (live count < top-A, an all-tombstoned
shard), owner-shard routing round-trips, and compaction-remap consistency
with the answer-cache inverted map.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import churn, policy, trace
from repro.core.distributed import (make_mutable_step_sharded, owner_shard,
                                    route_ids_by_owner, sharded_slab_append)
from repro.core.oma import OMAConfig

D = 8


@pytest.fixture(scope="module")
def cfg():
    # projection_topk == the sharded step's default top_a: the bitwise
    # contract's precondition (and small enough to stay < n_shard through
    # every capacity the growth/compaction schedule visits below)
    return policy.AcaiConfig(h=16, k=4, c_f=1.0, c_remote=16, c_local=8,
                             oma=OMAConfig(eta=0.01, projection_topk=48))


def _mesh(shape):
    return jax.make_mesh(shape, ("data", "model"))


def _rolling():
    params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"])
    catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
    events = trace.rolling_catalog_events(**params)
    n0 = churn.warm_size(params["n"], params["warm"])
    return catalog, reqs, events, n0


# ---------------------------------------------------------------------------
# bitwise 1-device parity: the sharded mutable path IS the mutable path
# ---------------------------------------------------------------------------

def test_mutable_step_bitwise_vs_single_device_with_tombstones(cfg):
    """One step, 40 tombstoned rows: make_mutable_step_sharded on a
    (1, 1) mesh == exact_mutable_candidates + make_mutable_step, bitwise
    in every carried state and every metric field."""
    n = 128
    cat = jax.random.normal(jax.random.PRNGKey(1), (n, D))
    alive = jnp.ones((n,), bool).at[jnp.arange(40)].set(False)
    st = policy.init_state(n, cfg, seed=3)
    st = policy.CacheState(jnp.where(alive, st.y, 0.0),
                           jnp.where(alive, st.x, 0.0), st.t, st.key)
    rs = jax.random.normal(jax.random.PRNGKey(2), (8, D))

    ids, d, valid = policy.exact_mutable_candidates(
        rs, st.x, cat, alive, cfg.c_remote, cfg.c_local)
    st_ref, m_ref = policy.make_mutable_step(cfg, 8)(st, ids, d, valid,
                                                     alive)
    st_sh, m_sh = make_mutable_step_sharded(cfg, _mesh((1, 1)), 8)(
        st, rs, cat, alive)

    for name in ("y", "x", "t"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_ref, name)),
            np.asarray(getattr(st_sh, name)), err_msg=name)
    for f in m_ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(m_ref, f)),
            np.asarray(getattr(m_sh, f)), err_msg=f)


def test_churn_replay_bitwise_on_1device_mesh(cfg):
    """The whole rolling-catalog churn replay — adds, removals, capacity
    bookkeeping AND epoch compaction — is bitwise identical between the
    plain AcaiCache and the (1, 1)-mesh sharded one: metrics, y, x."""
    catalog, reqs, events, n0 = _rolling()
    assert len(events) > 0

    plain = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0)
    res_p = churn.replay_with_churn(plain, catalog, reqs, events, batch=8,
                                    compact_every=24)
    shard = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0,
                             mesh=_mesh((1, 1)))
    res_s = churn.replay_with_churn(shard, catalog, reqs, events, batch=8,
                                    compact_every=24)

    assert res_p["compactions"] == res_s["compactions"] > 0
    for k in ("gain", "served_local", "occupancy", "fetched", "cost"):
        np.testing.assert_array_equal(res_p[k], res_s[k], err_msg=k)
    np.testing.assert_array_equal(np.asarray(plain.state.y),
                                  np.asarray(shard.state.y))
    np.testing.assert_array_equal(np.asarray(plain.state.x),
                                  np.asarray(shard.state.x))
    np.testing.assert_array_equal(np.asarray(plain.valid),
                                  np.asarray(shard.valid))


def test_sharded_append_growth_matches_single_device(cfg):
    """sharded_slab_append at P = 1 follows slab_append's growth schedule
    and writes bitwise; at P = 2 a straddling batch splits into owner-
    block runs, capacity stays mesh-aligned, ids stay monotonic."""
    from repro.index.base import slab_append

    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((70, D)).astype(np.float32)
    emb_np = rng.standard_normal((128, D)).astype(np.float32)
    valid_np = np.arange(128) < 100

    def slabs():  # the append DONATES its inputs -> fresh buffers per call
        return jnp.asarray(emb_np), jnp.asarray(valid_np)

    e1, v1, i1 = slab_append(*slabs(), 100, vecs)
    e2, v2, i2 = sharded_slab_append(*slabs(), 100, vecs, 1)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(i1, i2)

    e4, v4, i4 = sharded_slab_append(*slabs(), 100, vecs, 2)
    assert e4.shape[0] % 2 == 0
    np.testing.assert_array_equal(i4, np.arange(100, 170))
    np.testing.assert_array_equal(np.asarray(e4[100:170]), vecs)
    assert bool(v4[100:170].all()) and not bool(v4[170:].any())


# ---------------------------------------------------------------------------
# multi-device: invariants on real >1-shard meshes
# ---------------------------------------------------------------------------

def test_removed_never_served_across_shards(cfg, multi_device):
    """Rows removed from different owner shards hold zero y/x through
    every subsequent sharded OMA + rounding update (the invalidation
    invariant, shard-wise)."""
    n = 128
    rng = np.random.default_rng(5)
    cat = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    rq = jnp.asarray(rng.standard_normal((40, D)), jnp.float32)
    cache = policy.AcaiCache(cat, cfg, seed=0, mesh=_mesh((1, 4)))
    removed = [3, 40, 70, 101]  # one row per owner shard (n_shard = 32)
    assert sorted(set(owner_shard(removed, n, 4))) == [0, 1, 2, 3]
    cache.remove_objects(removed)
    jd = jnp.asarray(removed)
    for s in range(0, 40, 8):
        m = cache.serve_update_batch(rq[s:s + 8])
        assert float(jnp.abs(cache.state.y[jd]).sum()) == 0.0
        assert float(jnp.abs(cache.state.x[jd]).sum()) == 0.0
    assert float(m.occupancy[0]) <= cfg.h + 1e-6
    assert cache.live_count == n - len(removed)
    assert not np.intersect1d(np.asarray(cache.cached_ids), removed).size


def test_all_tombstoned_shard_projection_edge(cfg, multi_device):
    """Heavy removal: one shard fully tombstoned (live count 0 < top-A)
    and another below top-A.  The projection's padded zero heads keep the
    water-filling finite; serving stays green and dead mass stays dead."""
    n = 128  # (1, 2) mesh -> n_shard = 64, top_a = 48
    rng = np.random.default_rng(7)
    cat = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    rq = jnp.asarray(rng.standard_normal((16, D)), jnp.float32)
    cache = policy.AcaiCache(cat, cfg, seed=0, mesh=_mesh((1, 2)))
    # kill ALL of shard 1's rows [64, 128) + most of shard 0's (8 live)
    cache.remove_objects(list(range(56, 128)))
    assert cache.live_count == 56
    cache.remove_objects(list(range(8, 56)))
    assert cache.live_count == 8
    for s in range(0, 16, 8):
        m = cache.serve_update_batch(rq[s:s + 8])
    y = np.asarray(cache.state.y)
    assert np.isfinite(y).all()
    assert float(np.abs(y[8:]).sum()) == 0.0
    assert np.isfinite(np.asarray(m.gain_int)).all()
    assert float(m.occupancy[0]) <= cfg.h + 1e-6


def test_churn_replay_multi_device_green(cfg, multi_device):
    """The rolling-catalog churn suite cell runs green on a real 2-shard
    mesh: every event applies, compaction keeps the slab mesh-aligned,
    and the final live window matches the schedule."""
    catalog, reqs, events, n0 = _rolling()
    cache = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0,
                             mesh=_mesh((1, 2)))
    res = churn.replay_with_churn(cache, catalog, reqs, events, batch=8,
                                  compact_every=24)
    assert res["events_applied"] == len(events)
    assert res["compactions"] > 0
    assert cache.live_count == n0
    assert cache.catalog.shape[0] % 2 == 0
    assert np.isfinite(res["gain"]).all()


def test_mesh_mutation_guards(cfg, multi_device):
    """Sharded *index* configurations still reject mutation (the exact
    masked scan is the only mutable sharded serving path), and mis-
    aligned capacities are caught before anything is touched."""
    rng = np.random.default_rng(0)
    cat = jnp.asarray(rng.standard_normal((128, D)), jnp.float32)
    chunked = policy.AcaiCache(cat, cfg, seed=0, mesh=_mesh((1, 2)),
                               sharded_kwargs={"scan_chunk": 64})
    with pytest.raises(NotImplementedError, match="sharded"):
        chunked.add_objects(np.zeros((2, D), np.float32))
    assert not chunked._mutated


# ---------------------------------------------------------------------------
# owner-shard routing round-trips (global-id arithmetic)
# ---------------------------------------------------------------------------

def _assert_roundtrip(ids, cap, p):
    groups = route_ids_by_owner(ids, cap, p)
    shards = [s for s, _ in groups]
    assert shards == sorted(set(shards))  # ascending, unique
    block = cap // p
    back = []
    for s, gids in groups:
        assert ((gids >= s * block) & (gids < (s + 1) * block)).all()
        # relative order within a group survives routing
        orig = [i for i in ids if s * block <= i < (s + 1) * block]
        assert gids.tolist() == orig
        back.extend(gids.tolist())
    assert sorted(back) == sorted(ids)  # a permutation of the input


def test_owner_routing_roundtrip_property():
    """Global ids survive owner-shard routing round-trips: the groups
    partition the batch by owner block, preserve relative order, and
    concatenate back to a permutation of the input.  Runs under
    hypothesis when installed; otherwise a seeded sweep of the same
    property (the repo ships no hypothesis dependency)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(max_examples=60, deadline=None)
        @given(st.integers(1, 4).map(lambda e: 2 ** e),
               st.integers(0, 6),
               st.lists(st.integers(0, 1023), min_size=0, max_size=40))
        def prop(p, cap_pow, raw):
            cap = p * (2 ** cap_pow)
            ids = [i % cap for i in raw]
            _assert_roundtrip(ids, cap, p)

        prop()
    except ImportError:
        rng = np.random.default_rng(42)
        for _ in range(200):
            p = int(2 ** rng.integers(0, 4))
            cap = p * int(2 ** rng.integers(0, 7))
            k = int(rng.integers(0, 41))
            ids = rng.integers(0, cap, size=k).tolist()
            _assert_roundtrip(ids, cap, p)
    # P = 1 is the identity group — the single-device path, bitwise
    assert [(0, [7, 3, 7])] == [
        (s, g.tolist()) for s, g in route_ids_by_owner([7, 3, 7], 64, 1)]
    with pytest.raises(ValueError, match="divide"):
        owner_shard([0], 130, 4)


# ---------------------------------------------------------------------------
# compaction remap vs the answer-cache inverted map
# ---------------------------------------------------------------------------

def test_compaction_remap_consistent_with_answer_cache(cfg, multi_device):
    """A sharded compact's id remap pushed through AnswerCache.remap_ids
    keeps the inverted map exactly consistent: every stored id lands on
    the row now holding the same embedding, and the inverted index maps
    each new id back to precisely the entries that reference it."""
    from repro.serve.answer_cache import AnswerCache, AnswerCacheSpec

    n = 128
    rng = np.random.default_rng(11)
    cat = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    cache = policy.AcaiCache(cat, cfg, seed=0, mesh=_mesh((1, 2)))

    ac = AnswerCache(AnswerCacheSpec(capacity=16))
    qs = rng.standard_normal((3, D)).astype(np.float32)
    stored = np.array([[2, 90, 31], [64, 5, 100], [31, 2, 127]], np.int32)
    ac.store_batch(qs, 3, np.ones((3, 3), np.float32), stored)

    removed = [0, 7, 40, 70, 111]  # none referenced by the entries
    cache.remove_objects(removed)
    assert ac.invalidate_removed(removed) == 0
    old_emb = np.asarray(cache.catalog)
    remap = cache.compact()

    assert cache.catalog.shape[0] % 2 == 0  # mesh-aligned new capacity
    ac.remap_ids(remap)
    entries = list(ac._store.values())
    new_emb = np.asarray(cache.catalog)
    for e_old, e_new in zip(stored, entries):
        np.testing.assert_array_equal(remap[e_old], e_new.ids)
        # the remapped row holds the same object (same embedding)
        np.testing.assert_array_equal(old_emb[e_old], new_emb[e_new.ids])
    for oid, keys in ac._inv.items():
        assert all(oid in ac._store[k].ids for k in keys)
    all_ids = {int(i) for e in entries for i in e.ids}
    assert set(ac._inv) == all_ids


def test_mutable_sharded_rejects_non_negentropy(cfg):
    euclid = dataclasses.replace(
        cfg, oma=dataclasses.replace(cfg.oma, mirror="euclidean"))
    with pytest.raises(NotImplementedError, match="negentropy"):
        make_mutable_step_sharded(euclid, _mesh((1, 1)), 8)
