"""Fig. 5: robustness — AÇAI over eta spanning 2 orders of magnitude vs
SIM-LRU / CLS-LRU over their (k', C_theta) grid."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    k = 10
    c_f = s.cf_table[50]
    out = {"acai": {}, "SIM-LRU": {}, "CLS-LRU": {}}
    for h in ((50, 1000) if full else (50, 200)):
        base = 0.05 / c_f
        vals = []
        for mult in (0.1, 0.3, 1.0, 3.0, 10.0):
            m, dt = common.run_acai(s, h=h, k=k, c_f=c_f, eta=base * mult)
            v = B.nag(m["gain"], k, c_f)[-1]
            vals.append(v)
            common.emit(f"fig5/{kind}/h{h}/ACAI-eta{mult}x", dt * 1e6, f"{v:.4f}")
        out["acai"][h] = vals
        spread = (max(vals) - min(vals)) / max(max(vals), 1e-9)
        common.emit(f"fig5/{kind}/h{h}/ACAI-spread", 0.0, f"{spread:.3f}")

        for name in ("SIM-LRU", "CLS-LRU"):
            vals_b = []
            for kp in {k, 2 * k, min(4 * k, h)}:
                for ct in (1.0 * c_f, 1.5 * c_f, 2.0 * c_f):
                    m, dtb = common.run_baseline(
                        s, name, h=h, k=k, c_f=c_f, k_prime=kp, c_theta=ct)
                    v = B.nag(m["gain"], k, c_f)[-1]
                    vals_b.append(v)
                    common.emit(f"fig5/{kind}/h{h}/{name}-k{kp}-ct{ct:.2f}",
                                dtb * 1e6, f"{v:.4f}")
            out[name][h] = vals_b
            spread_b = (max(vals_b) - min(vals_b)) / max(max(vals_b), 1e-9)
            common.emit(f"fig5/{kind}/h{h}/{name}-spread", 0.0, f"{spread_b:.3f}")
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
