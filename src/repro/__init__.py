"""repro — AÇAI: Ascent Similarity Caching with Approximate Indexes.

A production-grade JAX serving/training framework whose retrieval tier
implements the AÇAI similarity-caching policy (Si Salem, Neglia, Carra 2021)
with approximate kNN indexes, online mirror ascent cache updates, and
multi-pod distribution via pjit/shard_map.
"""

__version__ = "1.0.0"
