"""Shared transformer layers: norms, rotary embeddings (RoPE + M-RoPE),
GQA attention (bias / sliding-window / encoder variants), SwiGLU MLP.

Functional style: params are plain nested dicts (pytrees); every layer is a
pair (init_fn, apply_fn).  dtype policy: params in cfg.dtype (bf16),
layernorm/softmax/rotary math in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the head-dim pair indices are split into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, H, D); positions3: (3, B, S).
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                       # (half,)
    # build per-pair position: section s of the half-dim uses positions3[s]
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )                                                   # (half,)
    pos = positions3.astype(jnp.float32)                # (3, B, S)
    pos_per_pair = pos[sec_id]                          # (half, B, S)
    ang = jnp.moveaxis(pos_per_pair, 0, -1) * freqs     # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA family)
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    return p


def _attention_mask(q_len: int, kv_len: int, q_offset, cfg: ModelConfig,
                    kv_positions: Optional[jax.Array] = None):
    """(q_len, kv_len) additive mask in f32. q_offset = absolute position of
    the first query row (decode: cache length)."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    k_pos = (jnp.arange(kv_len)[None, :] if kv_positions is None
             else kv_positions[None, :])
    ok = jnp.ones((q_len, kv_len), bool)
    if cfg.causal:
        ok &= k_pos <= q_pos
    if cfg.sliding_window:
        ok &= k_pos > q_pos - cfg.sliding_window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _sdpa(q, k, v, mask):
    """q: (B,S,H,D) k,v: (B,T,KV,D) grouped; returns (B,S,H,D)."""
    b, s, h, dd = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, s, kvh, groups, dd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dd)
    logits = logits + mask[None, None, None]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


# Above this KV length, prefill/train attention switches to the chunked
# online-softmax (flash) path: O(S * CHUNK) live logits instead of O(S^2).
FLASH_THRESHOLD = 8192
FLASH_CHUNK = 2048


def _sdpa_flash(q, k, v, q_offset, cfg: "ModelConfig", written_upto=None,
                chunk: int | None = None):
    """Flash-style attention: lax.scan over KV chunks with running
    (max, denom, acc).  Linear memory in T — required for the 32k prefill
    and 500k shapes.  Only used when T % chunk == 0 (all assigned shapes).
    The body is checkpointed so the backward pass recomputes per-chunk
    logits instead of storing them (O(S*chunk) residuals, not O(S*T))."""
    b, s, h, dd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dd).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(s)
    scale = 1.0 / jnp.sqrt(dd)
    chunk = chunk or cfg.flash_chunk
    nchunks = t // chunk

    def body(carry, j):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, 1)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg,
                            k_blk.astype(jnp.float32)) * scale
        k_pos = j * chunk + jnp.arange(chunk)
        ok = jnp.ones((s, chunk), bool)
        if cfg.causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if cfg.sliding_window:
            ok &= k_pos[None, :] > q_pos[:, None] - cfg.sliding_window
        if written_upto is not None:
            ok &= k_pos[None, :] < written_upto
        logits = jnp.where(ok[None, None, None], logits, -jnp.inf)

        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # rows with no valid key yet keep m = -inf; guard the exp shift
        shift = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - shift[..., None])
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        rescale = jnp.where(jnp.isfinite(m), jnp.exp(m - shift), 0.0)
        l_new = l * rescale + jnp.sum(p, axis=-1)
        acc_new = acc * rescale[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  jnp.arange(nchunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, -2, 1)  # (b,kvh,g,s,d) -> (b,s,kvh,g,d)
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def attention_core(q, k, v, q_offset, cfg: "ModelConfig", kv_positions=None,
                   written_upto=None):
    """Dispatch between the dense-mask and flash paths."""
    s, t = q.shape[1], k.shape[1]
    thresh = cfg.flash_threshold or FLASH_THRESHOLD
    use_flash = (s > 1 and t >= thresh and t % (cfg.flash_chunk or FLASH_CHUNK) == 0
                 and kv_positions is None)
    if use_flash:
        if cfg.use_pallas_attention and jax.default_backend() == "tpu":
            # VMEM-resident Pallas kernel: block logits never touch HBM
            # (§Perf).  Static q_offset/written_upto only (prefill path).
            from repro.kernels import ops as _kops
            if not hasattr(q_offset, "aval") and (
                    written_upto is None or not hasattr(written_upto, "aval")):
                return _kops.flash_attention(
                    q, k, v, causal=cfg.causal, window=cfg.sliding_window,
                    q_offset=int(q_offset),
                    written_upto=None if written_upto is None
                    else int(written_upto))
        return _sdpa_flash(q, k, v, q_offset, cfg, written_upto)
    mask = _attention_mask(s, t, q_offset, cfg, kv_positions=kv_positions)
    if written_upto is not None:
        mask = jnp.where(jnp.arange(t)[None, :] < written_upto, mask, -jnp.inf)
    if kv_positions is not None:
        mask = jnp.where(kv_positions[None, :] >= 0, mask, -jnp.inf)
    return _sdpa(q, k, v, mask)


def attention(p, x, positions, cfg: ModelConfig, cache=None,
              cache_len=None, positions3=None):
    """Full-sequence (training/prefill) or incremental (decode) attention.

    cache: None, or dict {k: (B, S_max, KV, D), v: ...} updated in place
           (functionally) at positions [cache_len, cache_len + S).
    Returns (out, new_cache).
    """
    b, s, d = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)

    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_emb == "mrope":
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)

    if cache is None:
        # training / cache-free forward (flash path kicks in for long S)
        out = attention_core(q, k, v, 0, cfg)
        new_cache = None
    else:
        s_max = cache["k"].shape[1]
        ring = cfg.sliding_window and s_max <= cfg.sliding_window
        last = cache_len + s - 1  # absolute position of the newest token
        if ring:
            # ring buffer: slot(p) = p mod W. After writing, slot j holds
            # absolute position  p(j) = last - ((last - j) mod W)  (< 0 if
            # the slot has never been written).
            if s == 1:
                slot = jnp.mod(cache_len, s_max)
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            else:
                # prefill: place the last W tokens at their ring slots
                j = jnp.arange(s_max)
                src = last - jnp.mod(last - j, s_max)      # abs pos per slot
                gather = jnp.clip(src, 0, s - 1)
                ck = jnp.take(k, gather, axis=1).astype(cache["k"].dtype)
                cv = jnp.take(v, gather, axis=1).astype(cache["v"].dtype)
            new_cache = {"k": ck, "v": cv}
            slot_pos = last - jnp.mod(last - jnp.arange(s_max), s_max)
            if s > 1:
                # prefill attention runs over the full (windowed) sequence;
                # the ring above is only the cache for subsequent decode.
                out = attention_core(q, k, v, 0, cfg)
            else:
                out = attention_core(q, new_cache["k"], new_cache["v"],
                                     cache_len, cfg, kv_positions=slot_pos)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
            new_cache = {"k": ck, "v": cv}
            out = attention_core(q, ck, cv, cache_len, cfg,
                                 written_upto=cache_len + s)

    out = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    return out, new_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    dt = dtype_of(cfg)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], cfg.d_model, d_ff, dt),
        "wo": dense_init(ks[2], d_ff, cfg.d_model, dt),
    }
    if cfg.ffn_act == "swiglu":
        p["wg"] = dense_init(ks[1], cfg.d_model, d_ff, dt)
    return p


def mlp(p, x):
    if "wg" in p:  # SwiGLU
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    h = jax.nn.relu(x @ p["wi"])  # squared-ReLU (nemotron family)
    return (h * h) @ p["wo"]
