"""qwen1.5-0.5b [dense] — QKV bias (hf:Qwen/Qwen1.5-0.5B).

24L d_model=1024 16H (GQA kv=16, i.e. MHA) d_ff=2816 vocab=151936.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    qkv_bias=True, tie_embeddings=True,
)
