"""End-to-end system behaviour: the full AÇAI stack (indexes -> policy ->
rounding) on a trace, exercised through the public object API."""

import numpy as np
import jax.numpy as jnp

from repro.core import oma, policy, trace
from repro.core.costs import calibrate_fetch_cost


def test_acai_cache_object_api():
    catalog, reqs, _ = trace.amazon_like(n=1200, d=16, t=300, seed=2)
    cat = jnp.array(catalog)
    c_f = float(calibrate_fetch_cost(cat, kth=20, sample=128))
    cfg = policy.AcaiConfig(h=60, k=5, c_f=c_f, c_remote=32, c_local=8,
                            oma=oma.OMAConfig(eta=0.1 / c_f))
    cache = policy.AcaiCache(cat, cfg, seed=0)
    gains = []
    for r in reqs:
        m = cache.serve_update(jnp.array(r))
        gains.append(float(m.gain_int))
    nag = cache.normalized_gain(sum(gains), len(gains))
    assert 0.0 < nag <= 1.0
    assert np.isfinite(np.array(gains)).all()
    late = np.mean(gains[-100:])
    early = np.mean(gains[:50])
    assert late >= early  # learns
    ids = np.array(cache.cached_ids)
    assert 30 <= len(ids) <= 90  # coupled rounding: occupancy ~ h


def test_state_is_reproducible():
    catalog, reqs, _ = trace.sift_like(n=800, d=8, t=200, seed=3)
    cat = jnp.array(catalog)
    cfg = policy.AcaiConfig(h=40, k=5, c_f=1.0, c_remote=32, c_local=8)
    fn = policy.exact_candidate_fn(cat, 32, 8)
    replay = policy.make_replay(cfg, fn)
    s1, m1 = replay(policy.init_state(800, cfg, seed=5), jnp.array(reqs))
    s2, m2 = replay(policy.init_state(800, cfg, seed=5), jnp.array(reqs))
    np.testing.assert_array_equal(np.array(s1.x), np.array(s2.x))
    np.testing.assert_allclose(np.array(m1.gain_int), np.array(m2.gain_int))
