"""Fig. 7: dissecting AÇAI — how much of the edge over the 2nd-best policy
comes from the approximate indexes (serving rule) vs the OMA updates.

Protocol (paper Sec. V-C): augment the second-best baseline with AÇAI's
index-based serving (per-object local/remote composition) while keeping its
LRU-style update rule; the augmented-minus-plain share of the improvement is
attributed to the indexes, the rest to OMA.
"""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    h = 1000 if full else 200
    c_f = s.cf_table[50]
    ks = (10, 20, 30, 50, 100) if full else (5, 10, 20)
    out = {}
    for k in ks:
        m, dt = common.run_acai(s, h=h, k=k, c_f=c_f,
                                c_remote=max(64, 4 * k), c_local=max(16, k))
        acai = B.nag(m["gain"], k, c_f)[-1]

        # 2nd best = best tuned baseline
        best_name, best_nag = None, -1.0
        for name in ("SIM-LRU", "CLS-LRU", "QCACHE"):
            v, _, _ = common.tune_baseline(s, name, h=h, k=k, c_f=c_f)
            if v > best_nag:
                best_name, best_nag = name, v
        # augmented second-best (indexes grafted on, updates unchanged)
        aug = -1.0
        for kp in (k, 2 * k):
            m_aug, _ = common.run_baseline(s, best_name, h=h, k=k, c_f=c_f,
                                           k_prime=kp, c_theta=1.5 * c_f,
                                           augmented=True)
            aug = max(aug, B.nag(m_aug["gain"], k, c_f)[-1])

        total = acai - best_nag
        from_idx = max(min(aug - best_nag, total), 0.0)
        share_idx = from_idx / max(total, 1e-9)
        out[k] = (acai, best_nag, aug, share_idx)
        common.emit(f"fig7/{kind}/k{k}/ACAI", dt * 1e6, f"{acai:.4f}")
        common.emit(f"fig7/{kind}/k{k}/2nd({best_name})", 0.0, f"{best_nag:.4f}")
        common.emit(f"fig7/{kind}/k{k}/2nd+index", 0.0, f"{aug:.4f}")
        common.emit(f"fig7/{kind}/k{k}/share_from_indexes", 0.0, f"{share_idx:.2f}")
        common.emit(f"fig7/{kind}/k{k}/share_from_oma", 0.0, f"{1 - share_idx:.2f}")
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
