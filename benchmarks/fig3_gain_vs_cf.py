"""Fig. 3: gain vs retrieval cost c_f = avg distance to i-th neighbour."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    k = 10
    h = 1000 if full else 200
    out = {}
    for i, c_f in sorted(s.cf_table.items()):
        m, dt = common.run_acai(s, h=h, k=k, c_f=c_f)
        acai = B.nag(m["gain"], k, c_f)[-1]
        common.emit(f"fig3/{kind}/cf@{i}/ACAI", dt * 1e6, f"{acai:.4f}")
        best = -1.0
        for name in ("SIM-LRU", "CLS-LRU"):
            nagv, _, dtb = common.tune_baseline(s, name, h=h, k=k, c_f=c_f)
            common.emit(f"fig3/{kind}/cf@{i}/{name}", dtb * 1e6, f"{nagv:.4f}")
            best = max(best, nagv)
        out[i] = (acai, best)
        common.emit(f"fig3/{kind}/cf@{i}/improvement", 0.0,
                    f"{(acai - best) / max(best, 1e-9):+.2%}")
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
