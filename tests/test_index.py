"""ANN index substrate: recall, integrity, PQ codec behaviour."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import trace
from repro.index import (FlatIndex, IVFFlatIndex, IVFPQIndex, LSHIndex,
                         NSWIndex, PQCodec)


@pytest.fixture(scope="module")
def clustered():
    catalog, reqs, _ = trace.amazon_like(n=4000, d=32, t=64, seed=0)
    cat = jnp.array(catalog)
    q = jnp.array(reqs[:64])
    truth = np.array(FlatIndex(cat).query(q, 10)[1])
    return cat, q, truth


def _recall(index, q, truth, k=10):
    ids = np.array(index.query(q, k)[1])
    return np.mean([len(set(ids[b]) & set(truth[b])) / k for b in range(q.shape[0])])


def test_flat_exact(clustered):
    cat, q, truth = clustered
    d, i = FlatIndex(cat).query(q, 10)
    assert (np.array(d) >= -1e-5).all()
    assert np.array_equal(np.array(i), truth)


def test_ivf_recall(clustered):
    cat, q, truth = clustered
    assert _recall(IVFFlatIndex(cat, nlist=64, nprobe=12), q, truth) > 0.9


def test_ivfpq_recall(clustered):
    cat, q, truth = clustered
    assert _recall(IVFPQIndex(cat, nlist=64, nprobe=12, m=8, refine=4),
                   q, truth) > 0.8


def test_lsh_recall(clustered):
    cat, q, truth = clustered
    assert _recall(LSHIndex(cat, tables=16, bits=8), q, truth) > 0.8


def test_nsw_recall(clustered):
    # multi-expansion beam search (expand=2 default) lifts the clustered-
    # trace recall from the seed's 0.842 to 0.95; pinned with margin.
    cat, q, truth = clustered
    assert _recall(NSWIndex(cat, degree=16, beam=64, steps=32), q, truth) > 0.93


def test_nsw_recall_uniform():
    catalog, reqs, _ = trace.sift_like(n=4000, d=32, t=64, seed=1)
    cat, q = jnp.array(catalog), jnp.array(reqs[:64])
    truth = np.array(FlatIndex(cat).query(q, 10)[1])
    # measured 1.0 at expand=2; pinned with margin
    assert _recall(NSWIndex(cat, degree=16, beam=48, steps=24), q, truth) > 0.95


def test_pq_codec_roundtrip_error_decreases_with_m():
    rng = np.random.default_rng(0)
    data = jnp.array(rng.normal(size=(1500, 32)).astype(np.float32))
    errs = []
    for m in (2, 4, 8):
        codec = PQCodec(data, m=m)
        rec = codec.decode(codec.encode(data))
        errs.append(float(jnp.mean(jnp.sum((rec - data) ** 2, -1))))
    assert errs[0] > errs[1] > errs[2]


def test_ivf_probes_more_lists_higher_recall(clustered):
    cat, q, truth = clustered
    r1 = _recall(IVFFlatIndex(cat, nlist=64, nprobe=1, seed=3), q, truth)
    r8 = _recall(IVFFlatIndex(cat, nlist=64, nprobe=16, seed=3), q, truth)
    assert r8 >= r1
    assert r8 > 0.9
