"""Version shims for jax APIs that moved between releases.

The repo targets the current jax API surface but must run on the pinned
environment (jax 0.4.x).  Policy: every shim resolves the NEW name first,
falls back to the old location, and keeps the new-API keyword spelling at
the call sites so that dropping a shim is a one-line change.

`shard_map` history:
  * jax <= 0.4.x / 0.5.x: ``jax.experimental.shard_map.shard_map`` with the
    replication-check keyword spelled ``check_rep``;
  * jax >= 0.6: promoted to ``jax.shard_map`` with the keyword renamed to
    ``check_vma`` (varying-manual-axes).

Call sites import from here — never from ``jax`` / ``jax.experimental``
directly — so `core/distributed.py`, `models/moe.py` and the dry-run all
lower on every supported jax version.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

if hasattr(jax, "shard_map"):  # jax >= 0.6
    SHARD_MAP_IMPL = "jax.shard_map"
    _shard_map = jax.shard_map
else:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

    SHARD_MAP_IMPL = "jax.experimental.shard_map.shard_map"

# The promotion to jax.shard_map and the check_rep -> check_vma keyword
# rename happened in different releases, so key the keyword spelling off
# the resolved function's actual signature, not its location.
_REP_KW = ("check_vma"
           if "check_vma" in inspect.signature(_shard_map).parameters
           else "check_rep")


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True, **kw: Any) -> Callable:
    """New-API signature (``check_vma``) mapped onto whichever
    implementation and keyword spelling this jax provides."""
    kw[_REP_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
