import os
import sys

# Give the whole suite an 8-virtual-device CPU platform (powers of two up
# to (2, 4) meshes) so the sharded tests exercise real >1-shard meshes
# in-process instead of only 1-device parity.  XLA reads the flag at
# backend init, so it must be set before anything imports jax; if a
# runner imported jax first (or set its own device count) we leave the
# environment alone and the `multi_device` fixture skips with a reason.
if ("jax" not in sys.modules
        and "--xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", "")).strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def multi_device():
    """Session guarantee of >= 8 devices for real multi-shard meshes.

    Yields the device count.  Skips (with the reason) when the platform
    could not be virtualised — e.g. jax was already initialised by an
    earlier import, or a TPU/GPU runner pins its own topology."""
    import jax

    if jax.device_count() < 8:
        pytest.skip(
            f"needs 8 virtual devices, have {jax.device_count()} "
            f"(XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r} was set too "
            f"late or overridden)")
    return jax.device_count()


def make_instance(rng, n=24, k=4, c_f=0.7, scale=2.0):
    d = (rng.random(n) * scale).astype(np.float32)
    y = rng.random(n).astype(np.float32)
    x = (rng.random(n) < 0.4).astype(np.float32)
    return d, y, x, k, c_f
