"""Sharding-annotation context.

Model code stays mesh-agnostic: layers call `annotate(x, axes)` with
*logical* axes; when a launcher activates a mesh context the call becomes
jax.lax.with_sharding_constraint (guiding the SPMD partitioner at the
places XLA's default propagation is weak — MoE dispatch buffers,
activation boundaries); otherwise it is the identity.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current() -> Optional[dict]:
    return getattr(_STATE, "ctx", None)


def mesh_active() -> bool:
    return _current() is not None


@contextlib.contextmanager
def mesh_context(mesh: Mesh, batch_axes: tuple):
    """batch_axes: mesh axes carrying the batch dim, e.g. ('pod', 'data')."""
    prev = _current()
    _STATE.ctx = {"mesh": mesh, "batch_axes": batch_axes}
    try:
        yield
    finally:
        _STATE.ctx = prev


def _resolve(axis, ctx):
    if axis == "batch":
        return ctx["batch_axes"]
    return axis


def annotate(x: jax.Array, axes: Sequence) -> jax.Array:
    """axes entries: None | 'model' | 'data' | 'batch' (logical).  Axes whose
    size does not divide the mesh axis are dropped silently."""
    ctx = _current()
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ax_size(a):
        if isinstance(a, tuple):
            n = 1
            for t in a:
                n *= sizes[t]
            return n
        return sizes[a]

    resolved = []
    for dim, axis in zip(x.shape, axes):
        axis = _resolve(axis, ctx)
        if axis is None or dim % ax_size(axis) != 0:
            resolved.append(None)
        else:
            resolved.append(axis)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
