"""Batched AÇAI pipeline: B=1 bit-exactness, mini-batch quality, serving tier."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import oma, policy, trace
from repro.core.costs import calibrate_fetch_cost
from repro.index import IVFFlatIndex
from repro.index.candidates import index_candidate_fn, index_candidate_fn_batched


@pytest.fixture(scope="module")
def setup():
    catalog, reqs, _ = trace.sift_like(n=800, d=16, t=512, seed=0)
    cat = jnp.array(catalog)
    c_f = float(calibrate_fetch_cost(cat, kth=50, sample=256))
    cfg = policy.AcaiConfig(h=48, k=8, c_f=c_f, c_remote=32, c_local=16,
                            oma=oma.OMAConfig(eta=0.05 / c_f))
    return cat, jnp.array(reqs), cfg


def _nag(m, k, c_f):
    g = np.asarray(m.gain_int)
    return float(g.sum()) / (k * c_f * g.shape[0])


def test_b1_bit_exact_vs_sequential(setup):
    """make_replay_batched at B=1 is make_replay, bit for bit (512 reqs)."""
    cat, reqs, cfg = setup
    fn = policy.exact_candidate_fn(cat, cfg.c_remote, cfg.c_local)
    fnb = policy.exact_candidate_fn_batched(cat, cfg.c_remote, cfg.c_local)
    s0 = policy.init_state(cat.shape[0], cfg)
    st_a, m_a = policy.make_replay(cfg, fn)(s0, reqs)
    st_b, m_b = policy.make_replay_batched(cfg, fnb, 1)(s0, reqs)
    for name in policy.StepMetrics._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(m_a, name)), np.asarray(getattr(m_b, name)),
            err_msg=name,
        )
    np.testing.assert_array_equal(np.asarray(st_a.y), np.asarray(st_b.y))
    np.testing.assert_array_equal(np.asarray(st_a.x), np.asarray(st_b.x))
    assert int(st_a.t) == int(st_b.t)


def test_b8_nag_comparable(setup):
    """Mini-batch (B=8) replay reaches NAG comparable to sequential."""
    cat, reqs, cfg = setup
    fn = policy.exact_candidate_fn(cat, cfg.c_remote, cfg.c_local)
    fnb = policy.exact_candidate_fn_batched(cat, cfg.c_remote, cfg.c_local)
    s0 = policy.init_state(cat.shape[0], cfg)
    _, m_seq = policy.make_replay(cfg, fn)(s0, reqs)
    _, m_b8 = policy.make_replay_batched(cfg, fnb, 8)(s0, reqs)
    nag_seq = _nag(m_seq, cfg.k, cfg.c_f)
    nag_b8 = _nag(m_b8, cfg.k, cfg.c_f)
    assert nag_b8 > 0.95 * nag_seq, (nag_b8, nag_seq)


def test_batched_metrics_shapes_and_occupancy(setup):
    cat, reqs, cfg = setup
    fnb = policy.exact_candidate_fn_batched(cat, cfg.c_remote, cfg.c_local)
    s0 = policy.init_state(cat.shape[0], cfg)
    st, m = policy.make_replay_batched(cfg, fnb, 64)(s0, reqs)
    assert m.gain_int.shape == (512,)
    assert int(st.t) == 512
    # fetched books per batch (on its last request); occupancy stays near h
    occ = np.asarray(m.occupancy)
    assert abs(occ.mean() - cfg.h) < 0.25 * cfg.h


def test_index_candidates_batched_matches_per_request(setup):
    cat, reqs, cfg = setup
    index = IVFFlatIndex(cat, nlist=32, nprobe=8)
    fnb = index_candidate_fn_batched(index, cat, cfg.c_remote, cfg.c_local,
                                     h=cfg.h)
    fn = index_candidate_fn(index, cat, cfg.c_remote, cfg.c_local, h=cfg.h)
    rng = np.random.default_rng(0)
    x = jnp.zeros(cat.shape[0]).at[rng.choice(cat.shape[0], 48, False)].set(1.0)
    ids_b, d_b, v_b = fnb(reqs[:8], x)
    for i in range(8):
        ids_s, d_s, v_s = fn(reqs[i], x)
        np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_b[i]))
        np.testing.assert_array_equal(np.asarray(d_s), np.asarray(d_b[i]))
        np.testing.assert_array_equal(np.asarray(v_s), np.asarray(v_b[i]))


def test_index_local_candidates_only_cached(setup):
    """The local slab points exclusively at cached rows (no full-catalog
    scan can sneak uncached objects in)."""
    cat, reqs, cfg = setup
    n = cat.shape[0]
    index = IVFFlatIndex(cat, nlist=32, nprobe=8)
    fnb = index_candidate_fn_batched(index, cat, cfg.c_remote, cfg.c_local,
                                     h=cfg.h)
    rng = np.random.default_rng(1)
    x = jnp.zeros(n).at[rng.choice(n, 48, False)].set(1.0)
    ids, d, valid = fnb(reqs[:16], x)
    loc = np.asarray(ids[:, cfg.c_remote:])
    vloc = np.asarray(valid[:, cfg.c_remote:])
    assert (np.asarray(x)[np.clip(loc, 0, n - 1)][vloc] > 0.5).all()


def test_ivf_batched_replay_close_to_exact(setup):
    cat, reqs, cfg = setup
    index = IVFFlatIndex(cat, nlist=32, nprobe=8)
    fnb_ivf = index_candidate_fn_batched(index, cat, cfg.c_remote, cfg.c_local,
                                         h=cfg.h)
    fnb_ex = policy.exact_candidate_fn_batched(cat, cfg.c_remote, cfg.c_local)
    s0 = policy.init_state(cat.shape[0], cfg)
    _, m_ivf = policy.make_replay_batched(cfg, fnb_ivf, 8)(s0, reqs)
    _, m_ex = policy.make_replay_batched(cfg, fnb_ex, 8)(s0, reqs)
    assert _nag(m_ivf, cfg.k, cfg.c_f) > 0.8 * _nag(m_ex, cfg.k, cfg.c_f)


def test_depround_cadence_survives_batching(setup):
    """The depround re-round period stays ~round_every at B>1 (a multiple
    of M inside the batch window triggers it), not lcm(B, round_every)."""
    cat, reqs, cfg = setup
    import dataclasses
    cfg_dr = dataclasses.replace(
        cfg, oma=dataclasses.replace(cfg.oma, rounding="depround",
                                     round_every=20))
    fnb = policy.exact_candidate_fn_batched(cat, cfg.c_remote, cfg.c_local)
    step = policy.make_step_batched(cfg_dr, fnb, 8)
    s0 = policy.init_state(cat.shape[0], cfg_dr, start="empty")
    # t=16: 20 ∈ [16, 24) -> must re-round (occupancy jumps 0 -> h)
    st = policy.CacheState(s0.y, s0.x, jnp.asarray(16, jnp.int32), s0.key)
    st_fire, _ = step(st, reqs[:8])
    assert float(jnp.sum(st_fire.x)) == cfg_dr.h
    # t=8 with M=20: no multiple of 20 in [8, 16) -> x stays frozen
    st = policy.CacheState(s0.y, s0.x, jnp.asarray(8, jnp.int32), s0.key)
    st_frozen, _ = step(st, reqs[:8])
    assert float(jnp.sum(st_frozen.x)) == 0.0
    # full replay keeps depround's exact-occupancy invariant on every
    # re-rounded state
    _, m = policy.make_replay_batched(cfg_dr, fnb, 8)(
        policy.init_state(cat.shape[0], cfg_dr), reqs)
    occ = np.asarray(m.occupancy)
    np.testing.assert_array_equal(occ, cfg_dr.h)


def test_acai_cache_serve_update_batch(setup):
    cat, reqs, cfg = setup
    cache = policy.AcaiCache(cat, cfg, seed=0)
    m1 = cache.serve_update(reqs[0])
    assert m1.gain_int.shape == ()
    mb = cache.serve_update_batch(reqs[1:9])
    assert mb.gain_int.shape == (8,)
    assert mb.served_local.shape == (8,)
    assert int(cache.state.t) == 9
    assert float(jnp.sum(cache.state.x)) > 0
