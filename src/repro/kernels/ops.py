"""Jitted public wrappers for the Pallas kernels.

Handle padding to block multiples, dtype promotion, backend dispatch
(interpret=True automatically on non-TPU backends so the same call sites
run in CI/CPU and on real hardware), and the partial-top-k merge.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ivf_scan as ivf_scan_kernel
from repro.kernels import l2 as l2_kernel
from repro.kernels import l2_topk as l2_topk_kernel
from repro.kernels import pq_adc as pq_adc_kernel
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(a: jax.Array, mult: int, value=0.0) -> jax.Array:
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=value)


@partial(jax.jit, static_argnames=("interpret",))
def pairwise_l2(q: jax.Array, x: jax.Array, *, interpret: bool | None = None):
    """(Q, D) x (N, D) -> (Q, N) squared L2 via the Pallas kernel."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = q.shape[0], x.shape[0]
    qp = _pad_rows(q, l2_kernel.BQ)
    xp = _pad_rows(x, l2_kernel.BN)
    out = l2_kernel.pairwise_l2_pallas(qp, xp, interpret=interp)
    return out[:qq, :n]


@partial(jax.jit, static_argnames=("interpret",))
def pq_adc(lut: jax.Array, codes: jax.Array, *, interpret: bool | None = None):
    """ADC scan: lut (Q, M, C) x codes (N, M) -> (Q, N)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = lut.shape[0], codes.shape[0]
    lp = _pad_rows(lut, pq_adc_kernel.BQ)
    cp = _pad_rows(codes, pq_adc_kernel.BN)
    out = pq_adc_kernel.pq_adc_pallas(lp, cp, interpret=interp)
    return out[:qq, :n]


@partial(jax.jit, static_argnames=("k", "interpret"))
def topk_l2(q: jax.Array, x: jax.Array, k: int, *, interpret: bool | None = None):
    """Fused blocked distance+top-k: returns (dists (Q,k), ids (Q,k))."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = q.shape[0], x.shape[0]
    qp = _pad_rows(q, l2_topk_kernel.BQ)
    xp = _pad_rows(x, l2_topk_kernel.BN)
    pd, pi = l2_topk_kernel.l2_topk_pallas(qp, xp, k, n_valid=n, interpret=interp)
    neg, pos = jax.lax.top_k(-pd, k)
    ids = jnp.take_along_axis(pi, pos, axis=1)
    return (-neg)[:qq], ids[:qq]


@partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_scan_topk(q: jax.Array, x: jax.Array, cand: jax.Array, k: int, *,
                  interpret: bool | None = None):
    """Fused gather + L2 + top-k over per-query candidate id lists.

    q (B, d), x (N, d) catalog, cand (B, P) int32 with -1 = invalid slot
    (inverted-list padding, dedup sentinels).  Returns (dists (B, k),
    ids (B, k)); underflowing slots come back as dist = +inf, id = -1.

    The fused kernel's per-block extraction handles k up to its tile width
    (BP = 128); larger k falls back to the XLA reference, which has no
    such limit.
    """
    if k > ivf_scan_kernel.BP:
        return ref.ivf_scan_ref(q, x, cand, k)
    interp = (not _on_tpu()) if interpret is None else interpret
    b, p = cand.shape
    qp = _pad_rows(q, ivf_scan_kernel.BQ)
    cp = _pad_rows(cand, ivf_scan_kernel.BQ, value=-1)
    padp = (-p) % ivf_scan_kernel.BP
    if padp:
        cp = jnp.pad(cp, ((0, 0), (0, padp)), constant_values=-1)
    pd, pi = ivf_scan_kernel.ivf_scan_pallas(qp, x, cp, k, interpret=interp)
    neg, pos = jax.lax.top_k(-pd, k)
    ppos = jnp.take_along_axis(pi, pos, axis=1)          # positions in P axis
    ids = jnp.take_along_axis(cp, ppos, axis=1)
    ids = jnp.where(jnp.isfinite(neg), ids, -1)
    return (-neg)[:b], ids[:b]


# jnp fallbacks, exported for benchmarking kernel vs XLA-fused baseline.
pairwise_l2_xla = jax.jit(ref.pairwise_l2_ref)
pq_adc_xla = jax.jit(ref.pq_adc_ref)
topk_l2_xla = jax.jit(ref.l2_topk_ref, static_argnames=("k",))
ivf_scan_xla = jax.jit(ref.ivf_scan_ref, static_argnames=("k",))


def topk_l2_auto(q: jax.Array, x: jax.Array, k: int):
    """Hot-path dispatch: compiled Pallas kernel on TPU, fused XLA reference
    elsewhere (interpret-mode Pallas is a correctness harness, not a perf
    path — see kernel_bench)."""
    if _on_tpu():
        return topk_l2(q, x, k)
    return topk_l2_xla(q, x, k)


def ivf_scan_auto(q: jax.Array, x: jax.Array, cand: jax.Array, k: int):
    """Hot-path dispatch for the fused IVF scan (same policy as
    topk_l2_auto)."""
    if _on_tpu():
        return ivf_scan_topk(q, x, cand, k)
    return ivf_scan_xla(q, x, cand, k)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                   "written_upto", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    written_upto=None, interpret: bool | None = None):
    """Pallas flash attention: q (B,S,H,D), k/v (B,T,KV,D) -> (B,S,H,Dv)."""
    from repro.kernels import flash_attention as fa

    interp = (not _on_tpu()) if interpret is None else interpret
    s = q.shape[1]
    bq = min(fa.BQ, s)
    bk = min(fa.BK, k.shape[1])
    pad = (-s) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset,
                                    written_upto=written_upto,
                                    bq=bq, bk=bk, interpret=interp)
    return out[:, :s]
