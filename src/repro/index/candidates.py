"""Candidate-set builders wiring the ANN indexes into the AÇAI policy.

Same signature as repro.core.policy.exact_candidate_fn:
    fn(r, x) -> (ids (C,), dists (C,), valid (C,))
Remote candidates come from the (approximate) remote-catalog index with an
exact re-rank of the retrieved embeddings (AÇAI evaluates true costs on the
retrieved set); local candidates come from a flat scan of the cached
objects (h is small — this *is* the local index at bench scale; an NSWIndex
drops in for larger local catalogs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.costs import BIG_COST
from repro.core.policy import dedup_mask


def index_candidate_fn(index, catalog: jax.Array, c_remote: int, c_local: int):
    n = catalog.shape[0]

    def fn(r: jax.Array, x: jax.Array):
        _, ids_remote = index.query(r[None, :], c_remote)
        ids_remote = ids_remote[0]
        # exact re-rank distances on the retrieved candidates
        d_full_remote = jnp.sum(
            (catalog[jnp.clip(ids_remote, 0, None)] - r[None, :]) ** 2, axis=-1
        )
        miss = ids_remote < 0
        ids_remote = jnp.where(miss, n, ids_remote)  # n = invalid sentinel

        d_all = jnp.sum((catalog - r[None, :]) ** 2, axis=-1)
        d_cached = jnp.where(x > 0.5, d_all, jnp.inf)
        _, ids_local = jax.lax.top_k(-d_cached, c_local)

        ids = jnp.concatenate([ids_remote, ids_local])
        valid = dedup_mask(ids, n)
        cached_ok = jnp.concatenate(
            [jnp.ones((c_remote,), bool), x[ids_local] > 0.5]
        )
        valid = valid & cached_ok
        d = jnp.where(
            valid,
            jnp.sum((catalog[jnp.clip(ids, 0, n - 1)] - r[None, :]) ** 2, -1),
            BIG_COST,
        )
        return ids, d, valid

    return fn
