"""Online serving sweep (DESIGN.md §12) — BENCH_serving.json.

Latency-vs-offered-load curves through the online serving engine
(`repro.serve.queue`): seeded arrival processes feed a request queue, the
dynamic batch former coalesces pending requests into `serve_update_batch`
calls, and admission control sheds on queue depth — all on the virtual
clock (deterministic, nothing sleeps).  The grid is {poisson,
flash_crowd} × {0.5, 0.8, 1.2}·capacity × {acai, sim_lru, qcache}, plus
one closed-loop row per policy (offered load there adapts to service
capacity, so it has no load axis).  Per row: p50/p99/p999 end-to-end
latency with the queue/service split, goodput at the SLO, shed share,
batch-size histogram, NAG, and the p50 serving-step wall time.

Built-in check, every run: the engine's fixed-window configuration
(pure size trigger at the offline batch, no admission) must be *bitwise*
identical — per-request gain AND policy state (y, x) — to
`make_replay_batched` over the same trace, the same drift pin
tests/test_serving_engine.py asserts (the PR-6 fault-rate-0 discipline).
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common
from repro.core import trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel, calibrate_fetch_cost
from repro.serve.arrivals import ArrivalSpec
from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                               OnlineServingEngine, ServiceModel,
                               fixed_window_engine)

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"

BATCH = 8
WINDOW_MS = 5.0       # batch-former max wait
SLO_MS = 25.0         # goodput latency target (virtual ms)
QUEUE_CAP = 8 * BATCH  # admission: shed beyond this queue depth
LOADS = (0.5, 0.8, 1.2)  # fraction of ServiceModel capacity at BATCH
ARRIVAL_SEED = 11


def _policies(c_f: float, h: int, k: int):
    """(label, PolicySpec) cells of the sweep — same trio as the
    resilience suite so the two benches share row identities."""
    return (
        ("acai", PA.PolicySpec("acai", {"h": h, "k": k, "batch": BATCH})),
        ("sim_lru", PA.PolicySpec("sim_lru",
                                  {"h": h, "k": k, "k_prime": 2 * k,
                                   "c_theta": 1.5 * c_f})),
        ("qcache", PA.PolicySpec("qcache", {"h": h, "k": k})),
    )


def _arrival(kind: str, rate_rps: float) -> ArrivalSpec:
    if kind == "closed_loop":
        # population sized so the loop can saturate the server when
        # think time is short relative to service time
        return ArrivalSpec(kind="closed_loop", users=2 * BATCH,
                           think_ms=2.0, seed=ARRIVAL_SEED)
    return ArrivalSpec(kind=kind, rate_rps=rate_rps, seed=ARRIVAL_SEED)


def _run_cell(label, spec, arrival, load, catalog, reqs, cm, service):
    pol = PA.build_policy(spec, catalog, cm, seed=0)
    eng = OnlineServingEngine(
        pol,
        former=BatchFormerConfig(max_batch=BATCH, max_wait_ms=WINDOW_MS),
        admission=AdmissionConfig(queue_cap=QUEUE_CAP),
        service=service)
    t0 = time.time()
    res = eng.run(reqs, arrival, slo_ms=SLO_MS)
    wall = time.time() - t0
    served = max(res["served"], 1)
    return {
        "policy": spec.to_dict(), "label": label,
        "arrival": arrival.to_dict(), "offered_load": load,
        "offered_rps": (arrival.rate_rps
                        if arrival.kind != "closed_loop" else None),
        "slo_ms": SLO_MS,
        "nag": round(float(res["gain"].sum()) / (pol.k * pol.c_f * served),
                     4),
        "goodput_slo": round(res["goodput_slo"], 4),
        "shed_share": round(res["shed_share"], 4),
        "hit_ratio": round(float(res["hit"][~res["shed"]].mean()), 4),
        "p50_ms": round(res["p50_ms"], 3),
        "p99_ms": round(res["p99_ms"], 3),
        "p999_ms": round(res["p999_ms"], 3),
        "queue_p50_ms": round(res["queue_p50_ms"], 3),
        "queue_p99_ms": round(res["queue_p99_ms"], 3),
        "service_p50_ms": round(res["service_p50_ms"], 3),
        "mean_batch": round(res["mean_batch"], 3),
        "batch_hist": res["batch_hist"],
        "batches": res["batches"],
        "max_queue_depth": res["max_queue_depth"],
        "p50_step_us": round(res["p50_step_s"] * 1e6, 1),
        "us_per_request": round(wall / max(res["requests"], 1) * 1e6, 2),
        "requests": res["requests"],
        "served": res["served"],
    }


def _assert_offline_pin(spec, catalog, reqs, cm, service):
    """The drift pin, run on every bench invocation: fixed-window engine
    == make_replay_batched, bitwise, on gain AND state (y, x)."""
    pol_on = PA.build_policy(spec, catalog, cm, seed=0)
    pol_off = PA.build_policy(spec, catalog, cm, seed=0)
    res = fixed_window_engine(pol_on, BATCH, service).run(
        reqs, _arrival("poisson", 0.8 * service.capacity_rps(BATCH)))
    ref = pol_off.replay(reqs)
    assert np.array_equal(res["gain"], ref["gain"]), (
        "fixed-window online engine diverged from make_replay_batched "
        "(gain)")
    for field in ("y", "x"):
        a = np.asarray(getattr(pol_on.cache.state, field))
        b = np.asarray(getattr(pol_off.cache.state, field))
        assert np.array_equal(a, b), (
            f"fixed-window online engine diverged from make_replay_batched "
            f"(state.{field})")
    common.emit("serving/bitwise-pin", 0.0,
                "fixed-window engine == make_replay_batched (gain, y, x)")


def main(full: bool = False, kind: str = None) -> None:
    if kind not in (None, "sift"):
        raise ValueError(
            "the serving suite sweeps arrival processes on the sift_like "
            "trace (load is the variable under study); --trace does not "
            "apply here")
    n, t, d = (20000, 8192, 32) if full else (2000, 2048, 16)
    h, k = (400, 10) if full else (64, 8)

    import jax
    import jax.numpy as jnp

    catalog, reqs, _ = trace.sift_like(n=n, d=d, t=t, jitter=0.05, seed=17)
    c_f = float(calibrate_fetch_cost(jnp.asarray(catalog),
                                     kth=min(50, n - 1), sample=256))
    cm = CostModel(c_f=c_f)
    service = ServiceModel()
    cap = service.capacity_rps(BATCH)

    _assert_offline_pin(PA.PolicySpec("acai", {"h": h, "k": k,
                                               "batch": BATCH}),
                        catalog, reqs, cm, service)

    rows = []
    for arr_kind in ("poisson", "flash_crowd"):
        for load in LOADS:
            arrival = _arrival(arr_kind, load * cap)
            for label, spec in _policies(c_f, h, k):
                row = _run_cell(label, spec, arrival, load, catalog, reqs,
                                cm, service)
                rows.append(row)
                common.emit(
                    f"serving/{arr_kind}/x{load:g}/{label}",
                    row["p50_ms"],
                    f"p99={row['p99_ms']:.1f}ms;"
                    f"goodput={row['goodput_slo']:.3f};"
                    f"shed={row['shed_share']:.3f};"
                    f"batch={row['mean_batch']:.2f}")
    for label, spec in _policies(c_f, h, k):
        row = _run_cell(label, spec, _arrival("closed_loop", 0.0), None,
                        catalog, reqs, cm, service)
        rows.append(row)
        common.emit(
            f"serving/closed_loop/{label}", row["p50_ms"],
            f"p99={row['p99_ms']:.1f}ms;goodput={row['goodput_slo']:.3f};"
            f"batch={row['mean_batch']:.2f}")

    BENCH_JSON.write_text(json.dumps(
        {"full": full, "n": n, "d": d, "t": t, "h": h, "k": k,
         "batch": BATCH, "c_f": round(c_f, 6),
         "service": service.to_dict(), "capacity_rps": round(cap, 3),
         "window_ms": WINDOW_MS, "slo_ms": SLO_MS, "queue_cap": QUEUE_CAP,
         "loads": list(LOADS), "arrival_seed": ARRIVAL_SEED,
         "backend": jax.default_backend(), "bitwise_pin": True,
         "rows": rows}, indent=2) + "\n")
    common.emit("serving/json", 0.0, str(BENCH_JSON.name))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    main(ap.parse_args().full)
