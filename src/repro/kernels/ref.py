"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_l2_ref(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(Q, D), (N, D) -> (Q, N) squared euclidean distances, float32."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    xn = jnp.sum(x * x, axis=-1)[None, :]
    return jnp.maximum(qn - 2.0 * (q @ x.T) + xn, 0.0)


def pq_adc_ref(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Asymmetric-distance computation.

    lut:   (Q, M, C) per-query, per-subspace distance tables (float32)
    codes: (N, M)    PQ codes, integer in [0, C)
    out:   (Q, N)    dist[q, n] = sum_m lut[q, m, codes[n, m]]
    """
    q, m, c = lut.shape
    onehot = jnp.equal(codes[..., None], jnp.arange(c)[None, None, :])
    return jnp.einsum("qmc,nmc->qn", lut.astype(jnp.float32),
                      onehot.astype(jnp.float32))


def l2_topk_ref(q: jnp.ndarray, x: jnp.ndarray, k: int, valid=None):
    """Exact top-k smallest distances: returns (dists (Q,k), ids (Q,k)).

    `valid` (N,) bool marks live catalog rows (mutable-catalog tombstone
    mask, DESIGN.md §10): masked rows never surface, and queries with
    fewer than k live rows underflow as dist = +inf, id = -1.  With
    valid=None the output is exactly the unmasked scan (no -1s possible).
    """
    import jax

    d = pairwise_l2_ref(q, x)
    if valid is None:
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, idx
    d = jnp.where(valid[None, :], d, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, jnp.where(jnp.isfinite(neg), idx, -1)


def ivf_scan_ref(q: jnp.ndarray, x: jnp.ndarray, cand: jnp.ndarray, k: int,
                 valid=None):
    """Gathered-candidate top-k: the oracle for kernels.ivf_scan.

    q (B, d), x (N, d), cand (B, P) int32 with -1 marking invalid slots.
    Returns (dists (B, k), ids (B, k)); ids = -1 (dist = +inf) when a query
    has fewer than k valid candidates — including the structural case
    k > P (a narrower slab than requested underflows rather than crashing,
    matching the Pallas path, whose slab is tile-padded).

    `valid` (N,) bool is the mutable-catalog tombstone mask (DESIGN.md
    §10): candidate ids whose row is tombstoned are treated as -1 slots,
    so a removed object can never surface from a stale inverted list.
    """
    import jax

    q = q.astype(jnp.float32)
    if valid is not None:
        cand = jnp.where(
            (cand >= 0) & valid[jnp.clip(cand, 0, x.shape[0] - 1)], cand, -1)
    x = x.astype(jnp.float32)
    embs = x[jnp.clip(cand, 0, None)]                  # (B, P, d)
    diff = embs - q[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)
    d = jnp.where(cand >= 0, d, jnp.inf)
    kk = min(k, cand.shape[1])
    neg, pos = jax.lax.top_k(-d, kk)
    ids = jnp.take_along_axis(cand, pos, axis=1)
    ids = jnp.where(jnp.isfinite(neg), ids, -1)
    if kk < k:
        ids = jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
        neg = jnp.pad(neg, ((0, 0), (0, k - kk)),
                      constant_values=-jnp.inf)
    return -neg, ids
