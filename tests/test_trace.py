"""Trace generators + TraceSpec registry: determinism under a fixed seed,
tiny-catalog robustness of the Zipf calibration, and spec round-trips."""

import numpy as np
import pytest

from repro.core import trace
from repro.core.trace import (TINY_TRACE_KWARGS, TraceSpec, build_trace,
                              ranked_popularity, registered_traces)


@pytest.mark.parametrize("name", sorted(registered_traces()))
def test_deterministic_under_fixed_seed(name):
    kw = TINY_TRACE_KWARGS[name]
    c1, r1, i1 = build_trace(name, **kw)
    c2, r2, i2 = build_trace(name, **kw)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(i1, i2)
    # a different seed moves the requests
    _, r3, i3 = build_trace(name, **{**kw, "seed": 99})
    assert not np.array_equal(i1, i3)


@pytest.mark.parametrize("name", sorted(registered_traces()))
def test_contract_shapes(name):
    kw = TINY_TRACE_KWARGS[name]
    catalog, reqs, ids = build_trace(name, **kw)
    n, t = kw["n"], kw["t"]
    assert catalog.shape == (n, kw["d"]) and catalog.dtype == np.float32
    assert reqs.shape == (t, kw["d"]) and reqs.dtype == np.float32
    assert ids.shape == (t,)
    assert ids.min() >= 0 and ids.max() < n
    # requests are for catalog points (the exact k=1 target exists)
    np.testing.assert_array_equal(reqs, catalog[ids])


@pytest.mark.parametrize("n", [2, 3, 8, 50, 150, 199])
def test_zipf_calibration_tiny_catalogs(n):
    """The ranked-popularity fit window degenerates below n ~ 200; the
    calibration must stay finite and the sampler usable."""
    catalog, reqs, ids = trace.sift_like(n=n, d=4, t=32, seed=0)
    assert np.isfinite(reqs).all()
    assert ids.max() < n
    beta = trace._zipf_calibrate_beta(np.sort(np.linalg.norm(
        catalog - catalog.mean(0, keepdims=True), axis=1)))
    assert np.isfinite(beta) and beta > 0


def test_ranked_popularity_deterministic():
    _, _, ids = trace.sift_like(n=500, d=8, t=2000, seed=3)
    p1 = ranked_popularity(ids, 500)
    p2 = ranked_popularity(ids, 500)
    np.testing.assert_array_equal(p1, p2)
    assert p1.shape == (500,)
    assert (np.diff(p1) <= 0).all()          # sorted descending
    assert p1.sum() == 2000
    # the sift-like trace is head-heavy (Zipf-calibrated tail)
    assert p1[:50].sum() > 0.3 * 2000


def test_flash_crowd_shocks_shift_popularity():
    """During shock windows a small object set dominates the traffic."""
    kw = dict(n=2000, d=8, t=4000, shocks=2, shock_len=0.1,
              shock_objects=10, shock_share=0.9, seed=7)
    _, _, ids = trace.flash_crowd(**kw)
    _, _, base_ids = trace.sift_like(n=2000, d=8, t=4000, seed=7)
    # shock windows: evenly spaced, width 400 — inside one, the top-10
    # objects take most of the traffic
    width = 400
    starts = np.linspace(0, 4000 - width, 4)[1:-1].astype(int)
    for s in starts:
        window = ids[s:s + width]
        top10 = np.sort(np.bincount(window, minlength=2000))[-10:].sum()
        assert top10 > 0.6 * width, (s, top10)


def test_adversarial_phases_are_far_apart():
    """Consecutive phases concentrate on well-separated catalog regions:
    the mean embedding jumps by more than the within-phase spread."""
    catalog, reqs, ids = trace.adversarial(n=2000, d=16, t=4000, phases=4,
                                           seed=11)
    bounds = np.linspace(0, 4000, 5).astype(int)
    centers, spreads = [], []
    for a, b in zip(bounds[:-1], bounds[1:]):
        emb = reqs[a:b]
        centers.append(emb.mean(0))
        spreads.append(np.linalg.norm(emb - emb.mean(0), axis=1).mean())
    jumps = [np.linalg.norm(c1 - c0)
             for c0, c1 in zip(centers[:-1], centers[1:])]
    assert min(jumps) > np.mean(spreads), (jumps, spreads)


def test_trace_spec_roundtrip_and_registry():
    spec = TraceSpec("flash_crowd", {"n": 512, "shocks": 3})
    assert TraceSpec.from_dict(spec.to_dict()) == spec
    assert spec.with_params(shocks=5).params["shocks"] == 5
    assert hash(spec) == hash(TraceSpec("flash_crowd",
                                        {"shocks": 3, "n": 512}))
    assert {"sift_like", "amazon_like", "flash_crowd",
            "adversarial"} <= set(registered_traces())
    c, r, i = build_trace(spec, t=32, d=8)          # overrides merge
    assert c.shape == (512, 8) and r.shape == (32, 8)
    with pytest.raises(ValueError, match="unknown trace"):
        build_trace("zipfian")
    with pytest.raises(ValueError, match="unknown trace"):
        TraceSpec.from_dict({"name": "zipfian"})
    with pytest.raises(ValueError, match="'name'"):
        TraceSpec.from_dict({"n": 4})
    with pytest.raises(ValueError, match="spec field"):
        TraceSpec("sift_like", {"name": "sift_like"})
