"""Mutable catalog: serve a rolling content window with online updates.

The workload real edge caches live in (DESIGN.md §10): content is
continuously published and expired, so the catalog — and the (approximate)
index AÇAI's serving rule needs over it — must mutate online.  This
example builds a `rolling_catalog` trace, replays it through AÇAI over an
IVF index while the schedule inserts/expires objects between mini-batches
(`add_objects` / `remove_objects`), refreshes the index periodically, and
prints what churn costs: NAG with and without refresh, mutation overhead,
and the removed-object invariant.

  PYTHONPATH=src python examples/churn_rolling_catalog.py
  PYTHONPATH=src python examples/churn_rolling_catalog.py --tiny
"""

import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import CostModel, PolicySpec, TraceSpec, build_policy, build_trace
from repro.core import churn
from repro.core.costs import calibrate_fetch_cost
from repro.core.trace import rolling_catalog_events
from repro.index import IndexSpec


def run(tspec, catalog, reqs, events, c_f, h, k, index_spec, refresh_every):
    n0 = churn.warm_size(tspec.params["n"], tspec.params["warm"])
    pol = build_policy(PolicySpec("acai", {"h": h, "k": k}), catalog[:n0],
                       CostModel(c_f=c_f), index_spec=index_spec, seed=0)
    res = churn.replay_with_churn(pol, catalog, reqs, events, batch=8,
                                  refresh_every=refresh_every)
    nag = float(res["gain"].sum()) / (k * c_f * res["requests"])
    return pol, res, nag


def main(tiny: bool = False):
    n, t, h, k = (512, 256, 24, 4) if tiny else (4000, 4096, 150, 10)
    rate = 0.1
    tspec = TraceSpec("rolling_catalog",
                      {"n": n, "d": 32, "t": t, "churn_rate": rate,
                       "warm": 0.5, "seed": 17})
    catalog, reqs, _ = build_trace(tspec)
    events = rolling_catalog_events(**tspec.params)
    n0 = churn.warm_size(n, 0.5)
    c_f = float(calibrate_fetch_cost(jnp.asarray(catalog[:n0]),
                                     kth=min(50, n0 - 1)))
    ispec = IndexSpec("ivf", {"nlist": max(n0 // 40, 4), "nprobe": 8})
    print(f"trace {tspec.to_dict()}")
    print(f"{len(events)} insert+expire event groups, live window {n0}, "
          f"index {ispec.to_dict()}\n")

    for every, label in ((0, "never refresh"),
                         (max(t // 8, 8), f"refresh every {max(t // 8, 8)}")):
        pol, res, nag = run(tspec, catalog, reqs, events, c_f, h, k, ispec,
                            every)
        print(f"{label:22s} NAG={nag:.4f}  hit={res['hit'].mean():.3f}  "
              f"p50 step {res['p50_step_s'] * 1e6:.0f}us  "
              f"mutation {res['mutation_s'] * 1e3:.0f}ms  "
              f"refresh {res['refresh_s'] * 1e3:.0f}ms")

    # the invalidation invariant: every expired object carries zero cache
    # mass — it can never be served or fetched again
    removed = np.concatenate([ev[2] for ev in events])
    mass = float(jnp.abs(pol.cache.state.y[jnp.asarray(removed)]).sum())
    print(f"\nexpired objects: {len(removed)}, residual cache mass: {mass}")
    print(f"live objects: {pol.cache.live_count} (rolling window held)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-fast sizes (CI smoke)")
    main(ap.parse_args().tiny)
