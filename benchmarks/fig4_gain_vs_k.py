"""Fig. 4: gain vs number of neighbours k in {10,20,30,50,100}, h=1000."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    h = 1000 if full else 200
    ks = (10, 20, 30, 50, 100) if full else (5, 10, 20, 40)
    c_f = s.cf_table[50]
    out = {}
    for k in ks:
        m, dt = common.run_acai(s, h=h, k=k, c_f=c_f,
                                c_remote=max(64, 4 * k), c_local=max(16, k))
        acai = B.nag(m["gain"], k, c_f)[-1]
        common.emit(f"fig4/{kind}/k{k}/ACAI", dt * 1e6, f"{acai:.4f}")
        best = -1.0
        for name in ("SIM-LRU", "CLS-LRU"):
            nagv, _, dtb = common.tune_baseline(s, name, h=h, k=k, c_f=c_f)
            common.emit(f"fig4/{kind}/k{k}/{name}", dtb * 1e6, f"{nagv:.4f}")
            best = max(best, nagv)
        out[k] = (acai, best)
        common.emit(f"fig4/{kind}/k{k}/improvement", 0.0,
                    f"{(acai - best) / max(best, 1e-9):+.2%}")
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
