"""Pallas TPU flash-attention (forward) kernel.

The §Perf analysis (EXPERIMENTS.md) shows prefill_32k memory-bound on the
XLA flash path: every KV-chunk's (BQ, BK) logits block round-trips HBM
(~10 TB/step for qwen2-72b).  This kernel keeps the entire online-softmax
inner loop in VMEM: HBM traffic collapses to the q/k/v reads + out write.

Layout: grid (B, H, S/BQ).  Per grid cell the kernel sees
  q   (BQ, D)      — its query block
  k,v (T, D)       — the full KV stream of its (b, kv-head) pair
                     (T <= 32k: 8 MiB bf16 in VMEM — fits; longer T would
                     stream via a 4th grid axis)
and loops over T in BK-sized steps with a fori_loop carrying
(m, l, acc) — classic FlashAttention-2 scheduling, MXU-shaped blocks.

GQA: the k/v BlockSpec index_map folds the q-head onto its kv head
(h // group).  Masking supports causal, sliding-window and a written-upto
bound (decode prefill), driven by the absolute q_offset.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 512
BK = 512
NEG_INF = float("-inf")


def _flash_kernel(causal: bool, window: int, q_offset: int,
                  written_upto: int | None, bk: int,
                  q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0, 0].astype(jnp.float32)         # (BQ, D)
    t = k_ref.shape[2]
    bq, d = q.shape
    scale = 1.0 / (d ** 0.5)
    i = pl.program_id(2)
    q_pos = q_offset + i * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)

    def body(j, carry):
        m, l, acc = carry
        k_blk = k_ref[0, 0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[0, 0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (BQ, BK)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window:
            ok &= k_pos > q_pos - window
        if written_upto is not None:
            ok &= k_pos < written_upto
        logits = jnp.where(ok, logits, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(logits, axis=1))
        shift = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - shift[:, None])
        p = jnp.where(ok, p, 0.0)
        rescale = jnp.where(jnp.isfinite(m), jnp.exp(m - shift), 0.0)
        l_new = l * rescale + jnp.sum(p, axis=1)
        acc_new = acc * rescale[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, v_ref.shape[3]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, t // bk, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    window: int = 0, q_offset: int = 0, written_upto: int | None = None,
    bq: int = BQ, bk: int = BK, interpret: bool = False,
) -> jax.Array:
    """q (B, S, H, D); k/v (B, T, KV, D) -> (B, S, H, Dv).

    S % bq == 0 and T % bk == 0 (the ops wrapper pads S; all assigned
    shapes already satisfy T)."""
    b, s, h, d = q.shape
    t, kvh, dv = k.shape[1], k.shape[2], v.shape[3]
    g = h // kvh
    assert s % bq == 0 and t % bk == 0, (s, t)

    qt = q.transpose(0, 2, 1, 3)      # (B, H, S, D)
    kt = k.transpose(0, 2, 1, 3)      # (B, KV, T, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, causal, window, q_offset,
                               written_upto, bk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, s // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, t, d),
                         lambda bi, hi, si, g=g: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, t, dv),
                         lambda bi, hi, si, g=g: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda bi, hi, si: (bi, hi, si, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
        interpret=interpret,
    )(qt[:, :, :, :], kt, vt)
    return out.transpose(0, 2, 1, 3)  # (B, S, H, Dv)
