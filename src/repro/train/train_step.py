"""Training step: loss, grad-accum microbatching, clipping, optimizer.

Loss = next-token cross-entropy (text/vision) or masked cluster prediction
(audio encoder) + router load-balance aux + MTP aux (deepseek).

`make_train_step(cfg, opt_cfg, accum)` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jit/pjit; with accum > 1 the global batch is split into
microbatches scanned sequentially (gradient accumulation), which is also
the compute/communication overlap lever: each microbatch's backward
all-reduces overlap the next microbatch's compute under XLA latency hiding.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import cross_entropy, forward, mtp_loss
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train.batching import forward_kwargs


class TrainMetrics(NamedTuple):
    loss: jax.Array
    ce_loss: jax.Array
    aux_loss: jax.Array
    grad_norm: jax.Array


def loss_fn(params, cfg: ModelConfig, batch):
    out = forward(params, cfg, train=True, **forward_kwargs(cfg, batch))
    if cfg.causal:
        if "labels" in batch:
            labels, mask = batch["labels"], batch.get("loss_mask")
            logits = out.logits
        else:
            logits = out.logits[:, :-1]
            labels = batch["tokens"][:, 1:]
            mask = None
        ce = cross_entropy(logits, labels, mask)
        extra = jnp.zeros((), jnp.float32)
        if cfg.mtp_depth and "tokens" in batch:
            toks = batch["tokens"]
            b, s = toks.shape
            pos = jnp.broadcast_to(jnp.arange(s), (b, s))
            hid = out.hidden[:, -s:]
            extra = 0.1 * mtp_loss(params, cfg, hid, toks, pos)
    else:
        # encoder: masked-prediction over all positions
        ce = cross_entropy(out.logits, batch["labels"], batch.get("loss_mask"))
        extra = jnp.zeros((), jnp.float32)
    aux = cfg.router_aux_coef * out.aux_loss
    total = ce + aux + extra
    return total, (ce, out.aux_loss)


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig,
                    accum: int = 1, grad_shardings=None):
    """grad_shardings: optional sharding tree (matching params) applied to
    the accumulated-gradient scan carry — pins the per-microbatch gradient
    reduction to a reduce-scatter into the FSDP layout instead of a full
    all-reduce (§Perf: 'grad-RS' iteration)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def compute_grads(params, batch):
        if accum == 1:
            (total, (ce, aux)), grads = grad_fn(params, cfg, batch)
            return total, ce, aux, _constrain(grads)

        def micro(carry, mb):
            acc = carry
            (total, (ce, aux)), grads = grad_fn(params, cfg, mb)
            grads = _constrain(grads)
            acc = jax.tree.map(jnp.add, acc, (grads, total, ce, aux))
            acc = (_constrain(acc[0]),) + acc[1:]
            return acc, None

        def split_one(name, x):
            if name == "positions3":  # (3, B, S): batch axis is 1
                r = x.reshape((3, accum, x.shape[1] // accum) + x.shape[2:])
                return jnp.moveaxis(r, 1, 0)
            return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

        split = {k: split_one(k, v) for k, v in batch.items()}
        zero = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
                jnp.zeros((), jnp.float32))
        (grads, total, ce, aux), _ = jax.lax.scan(micro, zero, split)
        inv = 1.0 / accum
        grads = jax.tree.map(lambda g: g * inv, grads)
        return total * inv, ce * inv, aux * inv, grads

    def train_step(params, opt_state, batch, step):
        total, ce, aux, grads = compute_grads(params, batch)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_opt = opt_lib.apply_opt(
            cfg.optimizer, grads, opt_state, params, opt_cfg)
        return new_params, new_opt, TrainMetrics(
            loss=total, ce_loss=ce, aux_loss=aux, grad_norm=gnorm)

    return train_step


def init_train_state(key, cfg: ModelConfig):
    from repro.models import init_params

    params = init_params(key, cfg)
    opt_state = opt_lib.init_opt(cfg.optimizer, params)
    return params, opt_state
