"""Approximate kNN index substrate (paper Sec. III).

AÇAI keeps two indexes at the edge server:
  * local catalog  (dynamic, h objects)  — graph index (NSW, HNSW-style)
    or flat scan: h is small enough that both are sub-millisecond.
  * remote catalog (static, N objects)   — IVF-Flat / IVF-PQ (FAISS-style)
    with compact codes, or LSH.

All indexes are JAX-native with static shapes (dense padded bucket tables,
fixed-width beams) so queries jit and shard; the TPU adaptations are
documented in DESIGN.md §3.  Builds run once in numpy/JAX at setup time.
"""

from repro.index.exact import FlatIndex
from repro.index.ivf import IVFFlatIndex
from repro.index.kmeans import kmeans
from repro.index.lsh import LSHIndex
from repro.index.nsw import NSWIndex
from repro.index.pq import IVFPQIndex, PQCodec

__all__ = [
    "FlatIndex",
    "IVFFlatIndex",
    "IVFPQIndex",
    "LSHIndex",
    "NSWIndex",
    "PQCodec",
    "kmeans",
]
