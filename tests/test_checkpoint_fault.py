"""Checkpoint save/restore, crash-resume, straggler monitor, elastic reshard."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_ARCHS
from repro.configs.shapes import ShapeSpec
from repro.train import OptConfig, init_train_state, make_train_step
from repro.train import checkpoint
from repro.train.batching import synthetic_batch
from repro.train.data import SyntheticDataset
from repro.train.fault import StragglerMonitor, TrainLoop


@pytest.fixture()
def small_state():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    return cfg, {"params": params, "opt": opt}


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path, small_state):
    cfg, state = small_state
    checkpoint.save(str(tmp_path), 7, state)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    restored = checkpoint.restore(str(tmp_path), 7, state)
    _trees_equal(state, restored)
    # bf16 dtypes survive the uint16 view round-trip
    assert restored["params"]["embed"].dtype == state["params"]["embed"].dtype


def test_checkpoint_gc_keeps_latest(tmp_path, small_state):
    cfg, state = small_state
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, state, keep=2)
    assert checkpoint.all_steps(str(tmp_path)) == [4, 5]


def test_atomic_commit_no_tmp_left(tmp_path, small_state):
    cfg, state = small_state
    checkpoint.save(str(tmp_path), 1, state)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_crash_resume_loop(tmp_path, small_state):
    """Inject a failure mid-run; the loop must resume from the checkpoint
    and produce the same final state as an uninterrupted run."""
    cfg, state0 = small_state
    shape = ShapeSpec("train", 16, 2, "train")
    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3)))
    dataset = SyntheticDataset(cfg, shape)

    def make_loop_step(crash_at, crashed):
        def loop_step(state, batch, step):
            if step == crash_at and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected failure")
            p, o, _ = step_fn(state["params"], state["opt"], batch, step)
            return {"params": p, "opt": o}
        return loop_step

    # interrupted run
    crashed = {"done": False}
    loop = TrainLoop(make_loop_step(7, crashed), jax.tree.map(jnp.copy, state0),
                     str(tmp_path / "a"), ckpt_every=5)
    final_a = loop.run(10, lambda s: dataset.batch(s))
    assert crashed["done"] and loop.restarts == 1

    # clean run
    loop_b = TrainLoop(make_loop_step(-1, {"done": True}),
                       jax.tree.map(jnp.copy, state0), str(tmp_path / "b"),
                       ckpt_every=5)
    final_b = loop_b.run(10, lambda s: dataset.batch(s))
    _trees_equal(final_a, final_b)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0)
    for s in range(20):
        mon.record(s, 0.1)
    assert not mon.flagged
    mon.record(20, 0.5)
    assert mon.flagged and mon.flagged[-1][0] == 20


def test_elastic_restore_changes_placement(tmp_path, small_state):
    """Restore with an explicit sharding tree (1-device mesh here; the same
    path re-shards onto any mesh at scale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg, state = small_state
    checkpoint.save(str(tmp_path), 3, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored = checkpoint.restore(str(tmp_path), 3, state, shardings)
    _trees_equal(state, restored)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf.sharding, NamedSharding)
