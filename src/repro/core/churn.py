"""Churn replay driver: a trace + an insert/expire schedule, one policy.

The mutable-catalog harness (DESIGN.md §10/§14): `replay_with_churn`
drives any `CachePolicy` (or a bare `AcaiCache`) through a request trace
while a `rolling_catalog_events`-style schedule mutates the catalog
between mini-batch steps — insertions through the policy's
`add_objects`, expiries through `remove_objects`, plus an optional
periodic refresh cadence and an optional epoch-compaction cadence.

Refresh is double-buffered when the policy supports it (DESIGN.md §14):
at a due boundary the driver calls `refresh_start()` (shadow rebuild —
the stale structures keep serving the next mini-batch) and installs the
shadow with `refresh_swap()` at the *next* boundary, before that
boundary's events.  Only the swap is serving-visible, booked separately
as `refresh_stall_s` next to the total `refresh_s`.  Policies without
the two-phase hooks fall back to the blocking `refresh()` (stall =
full rebuild).

Compaction renumbers slab rows, so the driver keeps a global-id → slab-id
translation (`remap` pushed by `pol.compact()`) and routes every
schedule id through it; before the first compaction the mapping is the
identity and the driver asserts the policy's monotonic id assignment
reproduces the trace's row ids exactly (a mismatch means the caller
built the policy on the wrong catalog slice).

Wall time is booked in three channels so the churn bench can show the
refresh-amortization trade-off rather than one blended number:
serving steps (`p50_step_s`), mutation (`mutation_s`, decomposed into
`mutation_device_s` — blocked donated-update dispatch, via
`repro.index.base.device_mutation_seconds` — and `mutation_host_s`,
the bookkeeping remainder), and refresh (`refresh_s` total,
`refresh_stall_s` serving-visible).

At churn_rate = 0 the schedule is empty: the policy never leaves its
static jitted path, and an AÇAI replay is bit-consistent with
`make_replay_batched` on the same trace (pinned by
tests/test_mutable_index.py).

The driver is mesh-agnostic (DESIGN.md §15): an `AcaiCache(mesh=...)`
routes the same `add_objects`/`remove_objects`/`compact` calls to owner
shards by global-id arithmetic and serves through
`make_mutable_step_sharded`, so sharded churn needs no driver changes —
on a 1-device mesh the whole replay is bitwise identical to the plain
cache (pinned by tests/test_sharded_churn.py).
"""

from __future__ import annotations

import time
from typing import Sequence

import numpy as np


def warm_size(n: int, warm: float) -> int:
    """Live-window population of a rolling_catalog trace (shared rounding
    with `trace.rolling_catalog_events`)."""
    return max(int(round(warm * n)), 1)


def _initial_rows(pol) -> int:
    """Slab rows the policy was built with (the warm-prefix size) — the
    identity span of the global-id → slab-id translation."""
    for obj in (pol, getattr(pol, "cache", None)):
        n = getattr(obj, "_n_slots", None)
        if n is not None:
            return int(n)
    oracle = getattr(pol, "oracle", None)
    if oracle is not None:
        return int(oracle.catalog.shape[0])
    raise TypeError(
        f"cannot infer the policy's initial row count for compaction id "
        f"translation: {type(pol).__name__}")


def replay_with_churn(pol, catalog: np.ndarray, reqs: np.ndarray,
                      events: Sequence, *, batch: int = 8,
                      refresh_every: int = 0,
                      compact_every: int = 0) -> dict:
    """Replay `reqs` through `pol` while `events` mutate the catalog.

    Args:
      pol: a CachePolicy (or AcaiCache) exposing `serve_update_batch`,
        `add_objects`, `remove_objects` and `refresh` (plus, optionally,
        the two-phase `refresh_start`/`refresh_swap` and `compact`),
        built over the trace catalog's warm prefix.
      catalog: the full (N, d) object universe of the trace — insert
        events read their embeddings here.
      reqs: (T, d) request stream; the tail not filling a mini-batch is
        dropped (the make_replay_batched convention).
      events: [(step, insert_ids, remove_ids), ...] with ascending steps
        (e.g. `trace.rolling_catalog_events(**spec.params)`); an event
        fires before the mini-batch containing request `step`.  Events
        landing in the truncated trace tail are applied after the last
        mini-batch, so the catalog always ends in the schedule's final
        state.
      batch: requests per mini-batch step.
      refresh_every: refresh cadence in *requests* (0 = never) — the
        amortization knob.  Two-phase when the policy supports it:
        shadow rebuild at the due boundary, swap at the next one.
      compact_every: epoch-compaction cadence in requests (0 = never):
        `pol.compact()` after the boundary's events, with the returned
        remap folded into the driver's schedule-id translation.

    Returns:
      dict of per-request metric arrays (gain, cost, served_local, hit,
      fetched, occupancy) plus `p50_step_s` (serving steps only),
      `mutation_s` / `mutation_host_s` / `mutation_device_s`,
      `refresh_s` / `refresh_stall_s`, `compact_s`, `events_applied`,
      `compactions`, `requests`.
    """
    from repro.index.base import device_mutation_seconds

    reqs = np.asarray(reqs)
    t = reqs.shape[0]
    tt = (t // batch) * batch
    if tt == 0:
        raise ValueError(
            f"trace of {t} requests is shorter than one mini-batch "
            f"(batch={batch})")
    pending = sorted(events, key=lambda ev: ev[0])
    out = {k: [] for k in ("gain", "cost", "served_local", "fetched",
                           "occupancy")}
    times: list[float] = []
    mutation_s = refresh_s = refresh_stall_s = compact_s = 0.0
    mutation_dev_s = 0.0
    applied = compactions = 0
    next_refresh = refresh_every
    next_compact = compact_every
    two_phase = (hasattr(pol, "refresh_start")
                 and hasattr(pol, "refresh_swap"))
    swap_pending = False
    # global trace id -> slab row id; identity until the first compaction
    g2s = np.full(catalog.shape[0], -1, np.int64)
    n0 = _initial_rows(pol)
    g2s[:n0] = np.arange(n0)
    compacted = False
    ev_i = 0

    def apply_event(ins, rem) -> None:
        nonlocal mutation_s, mutation_dev_s, applied
        t0 = time.time()
        dev0 = device_mutation_seconds()
        if len(ins):
            ins = np.asarray(ins)
            got = np.asarray(pol.add_objects(catalog[ins]))
            if not compacted:
                assert (got == ins).all(), (
                    f"row-id misalignment: schedule inserts {ins}, policy "
                    f"assigned {got} — was the policy built on "
                    f"catalog[:n_warm]?")
            g2s[ins] = got
        if len(rem):
            rem = np.asarray(rem)
            slab = g2s[rem]
            assert (slab >= 0).all(), (
                f"schedule removes never-inserted rows {rem[slab < 0]}")
            pol.remove_objects(slab.astype(np.int32))
            g2s[rem] = -1
        mutation_s += time.time() - t0
        mutation_dev_s += device_mutation_seconds() - dev0
        applied += 1

    for s in range(0, tt, batch):
        # (1) install a pending refresh shadow — before this boundary's
        # events, which would invalidate it; the swap is the only
        # serving-visible piece of the two-phase refresh
        if swap_pending:
            t0 = time.time()
            pol.refresh_swap()
            dt = time.time() - t0
            refresh_s += dt
            refresh_stall_s += dt
            swap_pending = False
        # (2) this boundary's churn events
        while ev_i < len(pending) and pending[ev_i][0] < s + batch:
            _, ins, rem = pending[ev_i]
            apply_event(ins, rem)
            ev_i += 1
        # (3) epoch compaction on its own cadence, after the events so
        # freshly removed rows are reclaimed immediately
        if compact_every and s >= next_compact:
            t0 = time.time()
            remap = np.asarray(pol.compact())
            compact_s += time.time() - t0
            live = g2s >= 0
            g2s[live] = remap[g2s[live]]
            assert (g2s[live] >= 0).all(), "compaction dropped live rows"
            compacted = True
            compactions += 1
            next_compact += compact_every
        # (4) start a refresh shadow rebuild; stale structures keep
        # serving until the swap at the next boundary
        if refresh_every and s >= next_refresh:
            t0 = time.time()
            if two_phase:
                pol.refresh_start()
                swap_pending = True
                refresh_s += time.time() - t0
            else:
                pol.refresh()
                dt = time.time() - t0
                refresh_s += dt
                refresh_stall_s += dt  # blocking: the whole rebuild stalls
            next_refresh += refresh_every
        t0 = time.time()
        m = pol.serve_update_batch(reqs[s:s + batch])
        times.append(time.time() - t0)
        out["gain"].append(np.asarray(m.gain_int, np.float64))
        out["cost"].append(np.asarray(m.cost, np.float64))
        out["served_local"].append(np.asarray(m.served_local))
        out["fetched"].append(np.asarray(m.fetched))
        out["occupancy"].append(np.asarray(m.occupancy, np.float64))
    # drain: a still-pending shadow is installed (it reflects the live
    # rows as of its start boundary; mutations below would discard it),
    # then events landing in the truncated trace tail (t % batch != 0)
    # are applied so the final catalog state always matches the
    # schedule's end state and events_applied == len(events)
    if swap_pending:
        t0 = time.time()
        pol.refresh_swap()
        dt = time.time() - t0
        refresh_s += dt
        refresh_stall_s += dt
        swap_pending = False
    while ev_i < len(pending):
        _, ins, rem = pending[ev_i]
        apply_event(ins, rem)
        ev_i += 1
    res = {k: np.concatenate(v) for k, v in out.items()}
    res["hit"] = res["served_local"] > 0
    res["p50_step_s"] = float(np.percentile(times, 50)) if times else 0.0
    res["mutation_s"] = mutation_s
    res["mutation_device_s"] = mutation_dev_s
    res["mutation_host_s"] = max(mutation_s - mutation_dev_s, 0.0)
    res["refresh_s"] = refresh_s
    res["refresh_stall_s"] = refresh_stall_s
    res["compact_s"] = compact_s
    res["events_applied"] = applied
    res["compactions"] = compactions
    res["requests"] = int(tt)
    return res
