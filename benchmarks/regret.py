"""Theorem IV.1: (1-1/e)-regret grows sub-linearly — time-averaged regret
against the best static allocation decays like 1/sqrt(T)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import baselines as B
from repro.core import gain as G
from repro.core import theoretical_eta


def _static_best_gain(s, x_static, k, c_f, requests):
    vals = []
    for r in requests[:: max(len(requests) // 400, 1)]:
        d = jnp.sum((s.cat_j - jnp.array(r)[None, :]) ** 2, -1)
        vals.append(float(G.gain_value(d, jnp.array(x_static), k, c_f)))
    return float(np.mean(vals))


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    h, k = (1000, 10) if full else (100, 10)
    c_f = s.cf_table[50]
    n = s.catalog.shape[0]

    # static-in-hindsight comparator: greedy popularity allocation
    near = s.oracle.ids[:, 0]
    top = np.bincount(near, minlength=n).argsort()[::-1][:h]
    x_static = np.zeros(n, np.float32)
    x_static[top] = 1.0
    psi = 1 - 1 / np.e

    out = {}
    for t_len in ((2000, 8000, 30000) if full else (500, 1500, 4000)):
        reqs = s.requests[:t_len]
        eta = theoretical_eta(float(np.sqrt(s.cf_table[50])), c_f, h, n, t_len)
        m, dt = common.run_acai(s, h=h, k=k, c_f=c_f, requests=reqs, eta=eta)
        static_avg = _static_best_gain(s, x_static, k, c_f, reqs)
        avg_gain = m["gain"].mean()
        regret_rate = psi * static_avg - avg_gain  # per-step psi-regret
        out[t_len] = regret_rate
        common.emit(f"regret/{kind}/T{t_len}", dt * 1e6,
                    f"psi_regret_per_step={regret_rate:.4f}")
    ts = sorted(out)
    # fit: regret_rate ~ c / sqrt(T) -> rate(T1)/rate(T3) ~ sqrt(T3/T1)
    common.emit(f"regret/{kind}/decay", 0.0,
                f"rate@{ts[0]}={out[ts[0]]:.4f};rate@{ts[-1]}={out[ts[-1]]:.4f}")
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
