# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver.

Default: reduced CPU-friendly sizes (minutes).  ``--full`` = paper-scale.
``--suite NAME`` runs a single harness; ``--list`` prints every
registered suite, experiment grid, policy and trace scenario.  The
roofline/dry-run analyses are separate (``python -m benchmarks.roofline``
after ``launch/dryrun.py``) since they operate on compiled artifacts, not
wall time.

The fig1..fig8 suites are thin wrappers over named grids of the
config-driven experiment harness (``benchmarks/experiments.py``); the
``experiments`` suite is the canonical cross-policy × cross-scenario
sweep and writes BENCH_experiments.json at the repo root.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", "--suite", dest="only", default=None,
                    help="run a single suite (e.g. --suite experiments)")
    ap.add_argument("--trace", default=None,
                    help="restrict to one trace scenario (sift|amazon "
                         "aliases or any registered scenario name)")
    ap.add_argument("--list", action="store_true",
                    help="print registered suites/grids/policies/traces")
    args = ap.parse_args()

    from benchmarks import (answer_cache_bench, backends_bench, churn_bench,
                            distributed_bench,
                            experiments, fig1_gain_vs_requests,
                            fig2_gain_vs_h, fig3_gain_vs_cf, fig4_gain_vs_k,
                            fig5_sensitivity, fig6_mirror_maps, fig7_dissect,
                            fig8_rounding, kernel_bench, regret,
                            resilience_bench, serve_bench, serving_bench)

    # name -> (fn, trace kinds, BENCH json the suite writes at the repo
    # root, or None for CSV-only suites) — the third column is what
    # ``--list`` prints and ``scripts/check_bench_schema.py`` validates
    suites = {
        "fig1": (fig1_gain_vs_requests.main, ["sift", "amazon"], None),
        "fig2": (fig2_gain_vs_h.main, ["sift"], None),
        "fig3": (fig3_gain_vs_cf.main, ["sift"], None),
        "fig4": (fig4_gain_vs_k.main, ["sift"], None),
        "fig5": (fig5_sensitivity.main, ["sift"], None),
        "fig6": (fig6_mirror_maps.main, ["sift"], None),
        "fig7": (fig7_dissect.main, ["sift", "amazon"], None),
        "fig8": (fig8_rounding.main, ["amazon"], None),
        "regret": (regret.main, ["sift"], None),
        "kernels": (kernel_bench.main, ["sift"], None),
        "serve": (serve_bench.main, ["sift"], None),
        # batched request pipeline: the B∈{1,8,64} throughput trajectory,
        # tracked per PR
        "pipeline": (serve_bench.pipeline_main, ["sift"],
                     "BENCH_pipeline.json"),
        # sharded multi-device replay (8 placeholder devices, subprocess):
        # shards∈{1,4,8} × B∈{8,64}
        "distributed": (distributed_bench.main, ["sift"],
                        "BENCH_distributed.json"),
        # unified-index-API sweep: every registered backend × B∈{8,64},
        # NAG + p50 latency + recall vs flat
        "backends": (backends_bench.main, ["sift"], "BENCH_backends.json"),
        # unified-policy-API sweep: every registered policy × every
        # registered trace scenario
        "experiments": (experiments.main, [None], "BENCH_experiments.json"),
        # mutable-catalog sweep: rolling_catalog churn rates × policies +
        # the refresh-amortization curve
        "churn": (churn_bench.main, ["sift"], "BENCH_churn.json"),
        # resilient serving tier: fault scenarios × policies through the
        # retry/degrade ladder (DESIGN.md §11)
        "resilience": (resilience_bench.main, ["sift"],
                       "BENCH_resilience.json"),
        # online serving engine: arrival processes × offered loads ×
        # policies through the queue/batch-former/admission path
        # (DESIGN.md §12); asserts the fixed-window bitwise pin against
        # make_replay_batched every run
        "serving": (serving_bench.main, ["sift"], "BENCH_serving.json"),
        # answer-cache tier: exact top-k memoization hit-rate/latency/NAG
        # across zipf / flash_crowd / rolling_catalog × cache on/off
        # (DESIGN.md §13); asserts NAG-neutrality (bitwise gain, state and
        # served-id parity vs the pass-through arm) every run
        "answer_cache": (answer_cache_bench.main, ["sift"],
                         "BENCH_answer_cache.json"),
    }

    if args.list:
        print("registered suites:")
        for name, (_fn, kinds, bench_json) in suites.items():
            ks = ",".join(k or "all-traces" for k in kinds)
            out = f" -> {bench_json}" if bench_json else ""
            print(f"  {name:12s} ({ks}){out}")
        print(experiments.list_grids())
        return

    print("name,us_per_call,derived")
    failures = 0
    for name, (fn, kinds, _bench_json) in suites.items():
        if args.only and args.only != name:
            continue
        for kind in ([args.trace] if args.trace else kinds):
            t0 = time.time()
            try:
                fn(args.full, kind)
                print(f"# {name}/{kind} done in {time.time() - t0:.0f}s",
                      file=sys.stderr)
            except SystemExit as e:  # a suite (or its subprocess wrapper)
                # called sys.exit: a non-zero/None-coded exit is a dead
                # suite and must fail the run, not silently end it
                if e.code not in (0, None):
                    failures += 1
                    print(f"# {name}/{kind} FAILED (exit {e.code}) "
                          f"after {time.time() - t0:.0f}s",
                          file=sys.stderr)
            except Exception:  # noqa: BLE001 — keep the suite running
                failures += 1
                print(f"# {name}/{kind} FAILED after {time.time() - t0:.0f}s",
                      file=sys.stderr)
                traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
