"""Unit tests: gain / cost / serve vs the pure-numpy oracle (paper App. A/B)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gain as G
from repro.core import ref
from conftest import make_instance


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("k", [1, 3, 7])
def test_gain_fractional_matches_eq7(seed, k):
    rng = np.random.default_rng(seed)
    d, y, x, _, c_f = make_instance(rng, n=30, k=k)
    got = float(G.gain_value(jnp.array(d), jnp.array(y), k, c_f))
    want = ref.gain_fractional(d, y, k, c_f)
    assert got == pytest.approx(want, rel=1e-4, abs=1e-4)


@pytest.mark.parametrize("seed", range(8))
def test_gain_integral_matches_lemma6(seed):
    """Eq. (7) at integral x == empty_cost - Eq. (5) cost (Lemma 6)."""
    rng = np.random.default_rng(seed)
    d, y, x, k, c_f = make_instance(rng)
    got = float(G.gain_value(jnp.array(d), jnp.array(x), k, c_f))
    want = ref.gain_integral(d, x, k, c_f)
    assert got == pytest.approx(want, rel=1e-4, abs=1e-4)


@pytest.mark.parametrize("seed", range(8))
def test_serve_cost_matches_eq5(seed):
    rng = np.random.default_rng(seed)
    d, y, x, k, c_f = make_instance(rng)
    res = G.serve(jnp.array(d), jnp.array(x), k, c_f)
    assert float(res.cost) == pytest.approx(ref.cost_integral(d, x, k, c_f), abs=1e-4)
    assert float(res.gain) == pytest.approx(ref.gain_integral(d, x, k, c_f), abs=1e-4)


@pytest.mark.parametrize("seed", range(12))
def test_serve_is_optimal_composition_eq2(seed):
    """Greedy augmented-entry selection == brute-force arg min of Eq. (2)."""
    rng = np.random.default_rng(seed)
    d = rng.random(10).astype(np.float32)
    x = (rng.random(10) < 0.5).astype(np.float32)
    res = G.serve(jnp.array(d), jnp.array(x), 3, 0.3)
    assert float(res.cost) == pytest.approx(
        ref.best_answer_bruteforce(d, x, 3, 0.3), abs=1e-5
    )


@pytest.mark.parametrize("seed", range(8))
def test_lemma1_bounds(seed):
    rng = np.random.default_rng(seed)
    d, y, x, k, c_f = make_instance(rng)
    g = float(G.gain_value(jnp.array(d), jnp.array(y), k, c_f))
    low = float(G.lower_bound_l(jnp.array(d), jnp.array(y), k, c_f))
    low_ref = ref.lower_bound(d, y, k, c_f)
    assert low == pytest.approx(low_ref, rel=1e-4, abs=1e-4)
    assert low <= g + 1e-4
    assert g <= low / (1 - 1 / np.e) + 1e-3


def test_gain_with_padded_candidates():
    """Padding/duplicate slots (BIG cost, zero weight) must be neutral."""
    rng = np.random.default_rng(3)
    d, y, x, k, c_f = make_instance(rng, n=20)
    from repro.core.costs import BIG_COST

    d_pad = np.concatenate([d, np.full(12, BIG_COST, np.float32)])
    y_pad = np.concatenate([y, np.zeros(12, np.float32)])
    a = float(G.gain_value(jnp.array(d), jnp.array(y), k, c_f))
    b = float(G.gain_value(jnp.array(d_pad), jnp.array(y_pad), k, c_f))
    assert a == pytest.approx(b, rel=1e-5)


def test_empty_cache_zero_gain():
    rng = np.random.default_rng(4)
    d, y, x, k, c_f = make_instance(rng)
    zero = jnp.zeros_like(jnp.array(y))
    assert float(G.gain_value(jnp.array(d), zero, k, c_f)) == pytest.approx(0.0, abs=1e-5)


def test_full_cache_max_gain():
    """Caching everything ⇒ gain = k*c_f (all fetch costs saved)."""
    rng = np.random.default_rng(5)
    d, y, x, k, c_f = make_instance(rng)
    ones = jnp.ones_like(jnp.array(y))
    assert float(G.gain_value(jnp.array(d), ones, k, c_f)) == pytest.approx(
        k * c_f, rel=1e-5
    )
