"""State-of-the-art similarity-caching baselines (paper Sec. II and Sec. V).

All baselines maintain an ordered list of key->value pairs (key = a past
request embedding, value = its k' closest catalog objects) and update it
LRU-style; the cache size is h objects, i.e. h // k' entries (the paper's
inefficiency (i): overlapping value sets still consume separate slots).

  LRU      — exact-match only (k' = k): hit iff the request equals a key.
  SIM-LRU  — l = 1: hit iff the closest key is within C_theta.
  CLS-LRU  — SIM-LRU + hypersphere-center updates (medoid of served history).
  RND-LRU  — SIM-LRU with randomised miss: P(miss | d) increasing in d.
  QCACHE   — k' = k, l > 1: merge the l closest entries' values; hit iff
             >= 2 selected objects are *guaranteed* true neighbours (ball
             containment argument of Falchi et al.) or the distance profile
             matches the stored entries' profiles.

They are deliberately plain numpy + python: these are sequential
data-structure policies used as baselines; the JAX hot path is AÇAI itself.

`augmented=True` gives every policy AÇAI's serving rule (Fig. 7/11-13 of the
paper): the answer is composed per-object from the union of cached objects
(cost c_d) and the server's kNN (cost c_d + c_f), while the cache-update
logic stays untouched.  This isolates how much of AÇAI's edge comes from
the indexes vs from the OMA updates.

Geometric tests (QCACHE) run in *Euclidean* distance (triangle inequality);
costs are whatever the CostModel says (squared Euclidean by default), exactly
as in the paper's experiments.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Optional

import numpy as np


# --------------------------------------------------------------------------
# Server oracle: precomputed exact kNN answers for every trace request.
# --------------------------------------------------------------------------

class ServerOracle:
    """Exact kNN answers from the remote server, precomputed in batch."""

    def __init__(self, catalog: np.ndarray, requests: np.ndarray, kmax: int,
                 chunk: int = 512):
        self.catalog = catalog.astype(np.float32)
        t = requests.shape[0]
        kmax = min(kmax, catalog.shape[0])
        self.kmax = kmax
        self.ids = np.empty((t, kmax), np.int32)
        self.d2 = np.empty((t, kmax), np.float32)  # squared euclidean
        cn = (self.catalog ** 2).sum(1)
        for s in range(0, t, chunk):
            q = requests[s:s + chunk].astype(np.float32)
            d2 = (q ** 2).sum(1)[:, None] - 2.0 * q @ self.catalog.T + cn[None, :]
            np.maximum(d2, 0.0, out=d2)
            part = np.argpartition(d2, kmax - 1, axis=1)[:, :kmax]
            pd = np.take_along_axis(d2, part, axis=1)
            order = np.argsort(pd, axis=1, kind="stable")
            self.ids[s:s + chunk] = np.take_along_axis(part, order, axis=1)
            self.d2[s:s + chunk] = np.take_along_axis(pd, order, axis=1)

    def knn(self, t: int, k: int):
        return self.ids[t, :k], self.d2[t, :k]

    def empty_cost(self, t: int, k: int, c_f: float, metric: str = "sqeuclidean"):
        d = self.d2[t, :k] if metric == "sqeuclidean" else np.sqrt(self.d2[t, :k])
        return float(d.sum() + k * c_f)


def _dist2(q: np.ndarray, pts: np.ndarray) -> np.ndarray:
    diff = pts - q[None, :]
    return np.maximum((diff * diff).sum(1), 0.0)


@dataclasses.dataclass
class StepResult:
    cost: float
    gain: float
    hit: bool
    served_local: int
    fetched: int  # objects fetched into the cache this step


class _Entry:
    __slots__ = ("key_emb", "value_ids", "value_d2_key", "history")

    def __init__(self, key_emb, value_ids, value_d2_key):
        self.key_emb = key_emb
        self.value_ids = value_ids            # (k',) catalog ids
        self.value_d2_key = value_d2_key      # (k',) squared dist to key
        self.history: deque = deque(maxlen=16)


class KeyValueCache:
    """Shared machinery for the LRU-family policies."""

    name = "base"

    def __init__(self, catalog: np.ndarray, oracle: ServerOracle, *, h: int,
                 k: int, c_f: float, k_prime: Optional[int] = None,
                 c_theta: Optional[float] = None, metric: str = "sqeuclidean",
                 augmented: bool = False, seed: int = 0):
        self.catalog = catalog
        self.oracle = oracle
        self.h, self.k = h, k
        self.k_prime = k_prime or k
        self.max_entries = max(h // self.k_prime, 1)
        self.c_f = c_f
        self.c_theta = c_theta if c_theta is not None else 1.5 * c_f
        self.metric = metric
        self.augmented = augmented
        self.rng = np.random.default_rng(seed)
        self.entries: "OrderedDict[int, _Entry]" = OrderedDict()  # MRU first
        self._next_id = 0

    # -- cost helpers -------------------------------------------------------

    def _cost(self, d2: np.ndarray) -> np.ndarray:
        return d2 if self.metric == "sqeuclidean" else np.sqrt(d2)

    def cached_object_ids(self) -> np.ndarray:
        if not self.entries:
            return np.empty((0,), np.int32)
        return np.unique(np.concatenate([e.value_ids for e in self.entries.values()]))

    # -- LRU bookkeeping ----------------------------------------------------

    def _touch(self, eid: int):
        self.entries.move_to_end(eid, last=False)

    def _insert(self, r_emb: np.ndarray, ids: np.ndarray, d2: np.ndarray) -> int:
        eid = self._next_id
        self._next_id += 1
        self.entries[eid] = _Entry(r_emb.copy(), ids.copy(), d2.copy())
        self.entries.move_to_end(eid, last=False)
        evicted = 0
        while len(self.entries) > self.max_entries:
            self.entries.popitem(last=True)
            evicted += 1
        return evicted

    # -- serving ------------------------------------------------------------

    def _serve_from_ids(self, t: int, r_emb: np.ndarray, local_ids: np.ndarray
                        ) -> StepResult:
        """AÇAI-style per-object composition over local_ids + server kNN."""
        srv_ids, srv_d2 = self.oracle.knn(t, self.k)
        if local_ids.size:
            loc_d2 = _dist2(r_emb, self.catalog[local_ids])
            costs = np.concatenate([self._cost(loc_d2), self._cost(srv_d2) + self.c_f])
            obj = np.concatenate([local_ids, srv_ids])
            is_local = np.concatenate([np.ones(local_ids.size, bool),
                                       np.zeros(self.k, bool)])
        else:
            costs = self._cost(srv_d2) + self.c_f
            obj = srv_ids
            is_local = np.zeros(self.k, bool)
        # dedup: a cached object also in the server answer keeps the cheap copy
        order = np.argsort(costs, kind="stable")
        seen, pick = set(), []
        for p in order:
            if int(obj[p]) in seen:
                continue
            seen.add(int(obj[p]))
            pick.append(p)
            if len(pick) == self.k:
                break
        pick = np.array(pick)
        cost = float(costs[pick].sum())
        served_local = int(is_local[pick].sum())
        gain = self.oracle.empty_cost(t, self.k, self.c_f, self.metric) - cost
        return StepResult(cost, gain, served_local > 0, served_local, 0)

    def _answer_cost_local(self, t: int, r_emb: np.ndarray, ids: np.ndarray
                           ) -> StepResult:
        """Serve k objects entirely from `ids` (approximate hit)."""
        d2 = _dist2(r_emb, self.catalog[ids])
        order = np.argsort(d2, kind="stable")[: self.k]
        cost = float(self._cost(d2[order]).sum())
        gain = self.oracle.empty_cost(t, self.k, self.c_f, self.metric) - cost
        return StepResult(cost, gain, True, self.k, 0)

    def _answer_cost_miss(self, t: int) -> StepResult:
        cost = self.oracle.empty_cost(t, self.k, self.c_f, self.metric)
        return StepResult(cost, 0.0, False, 0, self.k_prime)

    # -- per-policy hooks ---------------------------------------------------

    def _closest_entry(self, r_emb: np.ndarray):
        if not self.entries:
            return None, np.inf
        eids = list(self.entries.keys())
        keys = np.stack([self.entries[e].key_emb for e in eids])
        d2 = _dist2(r_emb, keys)
        j = int(np.argmin(d2))
        return eids[j], self._cost(np.array([d2[j]]))[0]

    def _is_hit(self, t: int, r_emb: np.ndarray):
        raise NotImplementedError

    def _on_hit(self, t, r_emb, eid):
        self._touch(eid)

    def step(self, t: int, r_emb: np.ndarray) -> StepResult:
        hit, eid = self._is_hit(t, r_emb)
        if hit:
            self._on_hit(t, r_emb, eid)
            entry = self.entries[eid]
            if self.augmented:
                res = self._serve_from_ids(t, r_emb, self.cached_object_ids())
            else:
                res = self._answer_cost_local(t, r_emb, entry.value_ids)
            return res
        ids, d2 = self.oracle.knn(t, self.k_prime)
        self._insert(r_emb, ids, d2)
        if self.augmented:
            res = self._serve_from_ids(t, r_emb, self.cached_object_ids())
            return StepResult(res.cost, res.gain, res.hit, res.served_local,
                              self.k_prime)
        return self._answer_cost_miss(t)


class LRU(KeyValueCache):
    """Naive exact-match similarity cache (paper Sec. V-B)."""

    name = "LRU"

    def __init__(self, *args, **kwargs):
        kwargs.setdefault("k_prime", kwargs.get("k", None))
        super().__init__(*args, **kwargs)
        self._key_lookup: dict[bytes, int] = {}

    def _is_hit(self, t, r_emb):
        eid = self._key_lookup.get(r_emb.tobytes())
        return (eid is not None and eid in self.entries), eid

    def _insert(self, r_emb, ids, d2):
        evicted = super()._insert(r_emb, ids, d2)
        eid = next(iter(self.entries))
        self._key_lookup[r_emb.tobytes()] = eid
        return evicted


class SimLRU(KeyValueCache):
    name = "SIM-LRU"

    def _is_hit(self, t, r_emb):
        eid, d = self._closest_entry(r_emb)
        return (eid is not None and d <= self.c_theta), eid


class RndLRU(SimLRU):
    """SIM-LRU with randomised hit decision: P(miss) grows with d."""

    name = "RND-LRU"

    def _is_hit(self, t, r_emb):
        eid, d = self._closest_entry(r_emb)
        if eid is None:
            return False, None
        p_miss = min(1.0, float(d) / max(self.c_theta, 1e-12))
        return (self.rng.random() >= p_miss), eid


class ClsLRU(SimLRU):
    """SIM-LRU + center updates: on a hit the entry's key moves to the medoid
    of its served-request history (pushes intersecting hyperspheres apart)."""

    name = "CLS-LRU"

    def _on_hit(self, t, r_emb, eid):
        super()._on_hit(t, r_emb, eid)
        e = self.entries[eid]
        e.history.append(r_emb.copy())
        if len(e.history) >= 2:
            hist = np.stack(e.history)
            cand = self.catalog[e.value_ids]
            # medoid: cached object minimising total distance to the history
            tot = ((cand[:, None, :] - hist[None, :, :]) ** 2).sum(-1).sum(1)
            new_center = cand[int(np.argmin(tot))]
            e.key_emb = new_center.copy()
            e.value_d2_key = _dist2(new_center, cand)


class QCache(KeyValueCache):
    """QCACHE (Falchi et al. 2012): k' = k, search the l closest entries."""

    name = "QCACHE"

    def __init__(self, *args, l: Optional[int] = None, theta_guaranteed: int = 2,
                 profile_tol: float = 1.25, **kwargs):
        kwargs.setdefault("k_prime", kwargs.get("k"))
        super().__init__(*args, **kwargs)
        self.l = l  # None => all entries (paper: l = h/k)
        self.theta_guaranteed = theta_guaranteed
        self.profile_tol = profile_tol

    def _is_hit(self, t, r_emb):
        if not self.entries:
            return False, None
        eids = list(self.entries.keys())
        keys = np.stack([self.entries[e].key_emb for e in eids])
        dk = np.sqrt(_dist2(r_emb, keys))  # euclidean for geometry
        take = np.argsort(dk, kind="stable")
        if self.l is not None:
            take = take[: self.l]
        merged_ids, merged_guard = [], []
        for j in take:
            e = self.entries[eids[int(j)]]
            rho = float(np.sqrt(e.value_d2_key.max()))  # covering radius
            guard = rho - dk[int(j)]  # guarantee margin for this entry
            merged_ids.append(e.value_ids)
            merged_guard.append(np.full(e.value_ids.shape, guard))
        ids = np.concatenate(merged_ids)
        guard = np.concatenate(merged_guard)
        d_obj = np.sqrt(_dist2(r_emb, self.catalog[ids]))
        # keep best copy per object id
        order = np.argsort(d_obj, kind="stable")
        seen, pick = set(), []
        for p in order:
            if int(ids[p]) in seen:
                continue
            seen.add(int(ids[p]))
            pick.append(p)
            if len(pick) == self.k:
                break
        if len(pick) < self.k:
            return False, take[0] if len(take) else None
        pick = np.array(pick)
        guaranteed = int((d_obj[pick] <= guard[pick] + 1e-12).sum())
        if guaranteed >= self.theta_guaranteed:
            return True, eids[int(take[0])]
        # distance-profile test: mean distance of the selected k vs the mean
        # key->value distance profile of the stored entries
        prof = np.mean([np.sqrt(e.value_d2_key).mean()
                        for e in self.entries.values()])
        if d_obj[pick].mean() <= self.profile_tol * prof:
            return True, eids[int(take[0])]
        return False, eids[int(take[0])]

    def _on_hit(self, t, r_emb, eid):
        # touch every contributing entry (paper: pairs that contributed move
        # to the front); we touch the closest one — the dominant contributor.
        self._touch(eid)

    def step(self, t, r_emb):
        # QCACHE serves from the merged value sets, not a single entry.
        hit, eid = self._is_hit(t, r_emb)
        if hit:
            self._on_hit(t, r_emb, eid)
            ids = self.cached_object_ids()
            if self.augmented:
                return self._serve_from_ids(t, r_emb, ids)
            return self._answer_cost_local(t, r_emb, ids)
        ids, d2 = self.oracle.knn(t, self.k_prime)
        self._insert(r_emb, ids, d2)
        if self.augmented:
            res = self._serve_from_ids(t, r_emb, self.cached_object_ids())
            return StepResult(res.cost, res.gain, res.hit, res.served_local,
                              self.k_prime)
        return self._answer_cost_miss(t)


POLICIES = {p.name: p for p in (LRU, SimLRU, ClsLRU, RndLRU, QCache)}


def run_policy(policy: KeyValueCache, requests: np.ndarray):
    """Replay a trace; returns dict of per-step metric arrays."""
    t_total = requests.shape[0]
    gain = np.zeros(t_total)
    cost = np.zeros(t_total)
    hits = np.zeros(t_total, bool)
    fetched = np.zeros(t_total, np.int32)
    for t in range(t_total):
        res = policy.step(t, requests[t])
        gain[t], cost[t], hits[t], fetched[t] = res.gain, res.cost, res.hit, res.fetched
    return {"gain": gain, "cost": cost, "hit": hits, "fetched": fetched}


def nag(gains: np.ndarray, k: int, c_f: float) -> np.ndarray:
    """Normalised average gain curve, Eq. (11)."""
    return np.cumsum(gains) / (k * c_f * np.arange(1, gains.shape[0] + 1))
