#!/usr/bin/env python
"""Validate committed BENCH_*.json files against the README field schema.

The README's "`BENCH_*.json` schema reference" section documents every
tracked benchmark JSON with a `| field | meaning |` table.  This script
keeps code and docs honest in both directions, for every BENCH_*.json
committed at the repo root:

* every field occurring in the file's `rows` must be documented in the
  README table (no silently-added columns), and
* every documented field must occur in at least one row (no stale docs
  for removed columns), and
* the load-bearing columns in REQUIRED_COLUMNS must be present — those
  carry the analysis a suite exists for (e.g. the distributed suite
  without `collectives_per_step`/`n` can't locate break-even), so a
  refactor that drops one fails here even if it also updates the README.

A BENCH file with no README section at all, or a file whose top level
has no `rows` list, is an error too.  Exits non-zero with a per-file
report on any violation.

  PYTHONPATH=src python scripts/check_bench_schema.py
"""

from __future__ import annotations

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
README = ROOT / "README.md"

# per-file columns that must appear in the rows (checked against the
# union of row keys, like the README comparison)
REQUIRED_COLUMNS: dict[str, set[str]] = {
    "BENCH_distributed.json": {"n", "shards", "collectives_per_step",
                               "bit_consistent", "requests_per_s"},
    "BENCH_churn.json": {"shards", "compactions", "mutation_ms"},
}

# a schema section opens with the bold filename marker ...
_SECTION = re.compile(r"\*\*`(BENCH_[a-z_]+\.json)`\*\*")
# ... and documents row fields as "| `a` / `b` | meaning |" table lines
_TABLE_ROW = re.compile(r"^\|([^|]+)\|")


def readme_schemas(text: str) -> dict[str, set[str]]:
    """filename -> documented row-field names, parsed from the README."""
    schemas: dict[str, set[str]] = {}
    current: set[str] | None = None
    for line in text.splitlines():
        m = _SECTION.search(line)
        if m:
            current = schemas.setdefault(m.group(1), set())
            continue
        if current is None:
            continue
        t = _TABLE_ROW.match(line.strip())
        if not t:
            continue
        cell = t.group(1).strip()
        if cell in ("field", "---", ":---"):
            continue
        for name in cell.split("/"):
            name = name.strip().strip("`").strip()
            if name and re.fullmatch(r"[A-Za-z0-9_]+", name):
                current.add(name)
    return {f: s for f, s in schemas.items() if s}


def row_fields(path: pathlib.Path) -> set[str] | None:
    """Union of keys across the file's `rows`; None if there is no
    well-formed rows list (itself a contract violation)."""
    data = json.loads(path.read_text())
    rows = data.get("rows")
    if not isinstance(rows, list) or not rows:
        return None
    fields: set[str] = set()
    for r in rows:
        if not isinstance(r, dict):
            return None
        fields |= set(r)
    return fields


def main() -> int:
    schemas = readme_schemas(README.read_text())
    bench_files = sorted(ROOT.glob("BENCH_*.json"))
    if not bench_files:
        print("no BENCH_*.json at the repo root — nothing to check")
        return 0
    failures = 0
    for path in bench_files:
        name = path.name
        documented = schemas.get(name)
        if documented is None:
            print(f"FAIL {name}: no `| field | meaning |` schema table in "
                  f"README.md (add one under the schema reference section)")
            failures += 1
            continue
        present = row_fields(path)
        if present is None:
            print(f"FAIL {name}: no non-empty `rows` list of objects")
            failures += 1
            continue
        undocumented = sorted(present - documented)
        stale = sorted(documented - present)
        missing = sorted(REQUIRED_COLUMNS.get(name, set()) - present)
        if missing:
            print(f"FAIL {name}: required columns absent from every row: "
                  f"{missing}")
            failures += 1
        if undocumented:
            print(f"FAIL {name}: fields in rows but not in the README "
                  f"table: {undocumented}")
        if stale:
            print(f"FAIL {name}: fields documented in README but absent "
                  f"from every row: {stale}")
        if undocumented or stale:
            failures += 1
        else:
            print(f"ok   {name}: {len(present)} fields match the README "
                  f"table")
    # sections documenting a file that is not committed are only a
    # warning: suites may be run selectively
    for name in sorted(set(schemas) - {p.name for p in bench_files}):
        print(f"warn {name}: documented in README but not committed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
