"""Assigned input-shape sets (same four for every LM arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``.  long_500k requires sub-quadratic
attention (SSM / hybrid / sliding-window); encoder-only archs have no
decode shapes.  Skips are recorded per-arch in DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# reduced variants for CPU smoke tests
SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 128, 1, "decode"),
}


def runnable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(ok, reason-if-skipped) per the assignment's skip rules."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""
