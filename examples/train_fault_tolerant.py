"""Train a ~100M-param LM for a few hundred steps with the fault-tolerant
loop: checkpoints every N steps, an injected mid-run crash, automatic
resume from the latest checkpoint, straggler monitoring.

  PYTHONPATH=src python examples/train_fault_tolerant.py
"""

import dataclasses
import tempfile

import jax

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.train import OptConfig, init_train_state, make_train_step
from repro.train.data import SyntheticDataset
from repro.train.fault import StragglerMonitor, TrainLoop

# ~100M params: 12L, d=512, vocab=32k
# full run: 12L/d512/32k vocab (~100M). CPU CI default below finishes in
# ~2 min; pass --full for the 100M configuration, --tiny for the
# seconds-fast smoke (2L/d128, a dozen steps).
import sys
FULL = "--full" in sys.argv
TINY = "--tiny" in sys.argv
CFG = ModelConfig(name="lm-100m",
                  n_layers=12 if FULL else (2 if TINY else 4),
                  d_model=512 if FULL else (128 if TINY else 256),
                  n_heads=8, n_kv_heads=4,
                  d_ff=2048 if FULL else (512 if TINY else 1024),
                  vocab=32000 if FULL else (2000 if TINY else 8000),
                  remat=False)
STEPS = 240 if FULL else (12 if TINY else 60)
CRASH_AT = 100 if FULL else (5 if TINY else 25)


def main():
    shape = ShapeSpec("train", 128, 8, "train")
    params, opt_state = init_train_state(jax.random.PRNGKey(0), CFG)
    print(f"params: {CFG.param_count() / 1e6:.0f}M")
    step_fn = jax.jit(make_train_step(CFG, OptConfig(lr=3e-4)))
    dataset = SyntheticDataset(CFG, shape)

    crashed = {"done": False}
    losses = []

    def loop_step(state, batch, step):
        if step == CRASH_AT and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")  # simulated preemption
        p, o, metrics = step_fn(state["params"], state["opt"], batch, step)
        losses.append(float(metrics.loss))
        if step % 20 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f}")
        return {"params": p, "opt": o}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoop(loop_step, {"params": params, "opt": opt_state},
                         ckpt_dir, ckpt_every=4 if TINY else 40,
                         monitor=StragglerMonitor())
        loop.run(STEPS, lambda s: dataset.batch(s))
        print(f"\nfinished {STEPS} steps with {loop.restarts} restart(s) "
              f"(crash injected at step {CRASH_AT}).")
        print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f} "
              f"(decreased: {losses[-1] < losses[0]})")


if __name__ == "__main__":
    main()
