"""Distributed AÇAI retrieval step == single-device reference (subprocess
with 8 placeholder devices; same discipline as launch/dryrun.py), plus the
sharded replay twin of the batched pipeline."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import oma, policy, trace
    from repro.core.distributed import (build_sharded_ivf,
                                        make_replay_sharded,
                                        make_retrieval_step, reference_step)

    rng = np.random.default_rng(0)
    N, d, B, C, k, h = 512, 16, 8, 24, 4, 32
    catalog = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    y0 = jnp.full((N,), h / N, jnp.float32)
    reqs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    kw = dict(n_shard=N // 4, d=d, c=C, k=k, c_f=1.0, h=h, eta=0.05,
              top_a=h + 16, batch_axes=("data",))
    step = make_retrieval_step(mesh, **kw)
    y1, ans, metrics = jax.jit(step)(catalog, y0, reqs)
    y_ref, ans_ref = reference_step(catalog, y0, reqs, c=C, k=k, c_f=1.0,
                                    h=h, eta=0.05, top_a=h + 16)
    err = float(jnp.abs(y1 - y_ref).max())
    # answers: compare the (sorted) answered object-id sets per request
    same = all(set(np.array(a).tolist()) == set(np.array(b).tolist())
               for a, b in zip(np.array(ans), np.array(ans_ref)))

    # ---- scan_chunk > 0: the fused-kernel local scan (ops.topk_l2_fused)
    step_c = make_retrieval_step(mesh, scan_chunk=50, **kw)
    y1c, ansc, _ = jax.jit(step_c)(catalog, y0, reqs)
    err_chunk = float(jnp.abs(y1c - y_ref).max())
    same_chunk = all(set(np.array(a).tolist()) == set(np.array(b).tolist())
                     for a, b in zip(np.array(ansc), np.array(ans_ref)))

    # ---- sharded IVF: each shard probes only its own inverted lists
    ivf = build_sharded_ivf(catalog, 4, nlist=16, nprobe=8)
    step_i = make_retrieval_step(mesh, ivf=ivf, **kw)
    y1i, ansi, mi = jax.jit(step_i)(catalog, y0, reqs)
    ivf_sum_ok = abs(float(jnp.sum(y1i)) - h) < 1e-2
    ivf_ids_ok = bool((np.array(ansi) >= 0).all()
                      and (np.array(ansi) < N).all())

    # ---- sharded replay (2 data x 4 model) vs single-device batched replay
    T = 256
    trace_cat, trace_reqs, _ = trace.sift_like(n=N, d=d, t=T, seed=0)
    tcat, treqs = jnp.array(trace_cat), jnp.array(trace_reqs)
    cfg = policy.AcaiConfig(h=h, k=k, c_f=1.0, c_remote=24, c_local=8,
                            oma=oma.OMAConfig(eta=0.05,
                                              projection_topk=2 * h + 64))
    s0 = policy.init_state(N, cfg)
    fnb = policy.exact_candidate_fn_batched(tcat, cfg.c_remote, cfg.c_local)
    _, m_b = policy.make_replay_batched(cfg, fnb, 8)(s0, treqs)
    _, m_s = jax.jit(make_replay_sharded(cfg, mesh, tcat, 8))(s0, treqs)
    nag_b = float(np.sum(np.asarray(m_b.gain_int))) / (k * 1.0 * T)
    nag_s = float(np.sum(np.asarray(m_s.gain_int))) / (k * 1.0 * T)

    print(json.dumps({"yerr": err, "answers_match": bool(same),
                      "yerr_chunk": err_chunk,
                      "answers_match_chunk": bool(same_chunk),
                      "ivf_sum_ok": ivf_sum_ok, "ivf_ids_ok": ivf_ids_ok,
                      "ivf_gain": float(mi["gain"]),
                      "nag_batched": nag_b, "nag_sharded": nag_s,
                      "gain": float(metrics["gain"]),
                      "ndev": jax.device_count()}))
""")


@pytest.fixture(scope="module")
def child_result():
    out = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=600,
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_matches_reference(child_result):
    res = child_result
    assert res["ndev"] == 8
    assert res["yerr"] < 2e-4, res
    assert res["answers_match"], res
    # uniform init y = h/N < 0.5 => thresholded cache starts empty => zero
    # gain on the first step; it must never be negative.
    assert res["gain"] >= 0


def test_distributed_scan_chunk_fused_path(child_result):
    """scan_chunk > 0 routes the local scan through ops.topk_l2_fused; the
    numerics must match the full-matrix reference just as tightly."""
    res = child_result
    assert res["yerr_chunk"] < 2e-4, res
    assert res["answers_match_chunk"], res


def test_distributed_sharded_ivf(child_result):
    """Per-shard IVF probing keeps the OMA state on the capped simplex and
    answers with real catalog ids (approximate candidates, exact update)."""
    res = child_result
    assert res["ivf_sum_ok"], res
    assert res["ivf_ids_ok"], res
    assert res["ivf_gain"] >= 0


def test_sharded_replay_nag_close_to_batched(child_result):
    """make_replay_sharded on a (2, 4) mesh reaches the quality of the
    single-device batched replay (same trace, same config)."""
    res = child_result
    assert res["nag_sharded"] > 0.95 * res["nag_batched"], res
    assert res["nag_sharded"] > 0


def test_sharded_replay_bit_consistent_on_1device_mesh():
    """On a 1-device mesh make_replay_sharded IS make_replay_batched with
    exact candidates — every carried state and metric, bit for bit."""
    from repro.core import oma, policy, trace
    from repro.core.distributed import make_replay_sharded

    catalog, reqs, _ = trace.sift_like(n=800, d=16, t=128, seed=0)
    cat, reqs = jnp.array(catalog), jnp.array(reqs)
    a = 2 * 48 + 64
    cfg = policy.AcaiConfig(h=48, k=8, c_f=1.0, c_remote=32, c_local=16,
                            oma=oma.OMAConfig(eta=0.05, projection_topk=a))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fnb = policy.exact_candidate_fn_batched(cat, cfg.c_remote, cfg.c_local)
    s0 = policy.init_state(cat.shape[0], cfg)
    for b in (1, 8):
        st_a, m_a = policy.make_replay_batched(cfg, fnb, b)(s0, reqs)
        st_b, m_b = make_replay_sharded(cfg, mesh, cat, b, top_a=a)(s0, reqs)
        for name in policy.StepMetrics._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(m_a, name)),
                np.asarray(getattr(m_b, name)), err_msg=f"B={b} {name}")
        np.testing.assert_array_equal(np.asarray(st_a.y), np.asarray(st_b.y))
        np.testing.assert_array_equal(np.asarray(st_a.x), np.asarray(st_b.x))
        assert int(st_a.t) == int(st_b.t)


def test_acai_cache_mesh_serving_path():
    """AcaiCache(mesh=...) serves single requests and mini-batches through
    the sharded step (1-device mesh here; the API the serving tier uses)."""
    from repro.core import oma, policy, trace

    catalog, reqs, _ = trace.sift_like(n=400, d=16, t=32, seed=1)
    cat, reqs = jnp.array(catalog), jnp.array(reqs)
    cfg = policy.AcaiConfig(h=32, k=4, c_f=1.0, c_remote=16, c_local=8,
                            oma=oma.OMAConfig(eta=0.05))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache = policy.AcaiCache(cat, cfg, seed=0, mesh=mesh)
    m1 = cache.serve_update(reqs[0])
    assert m1.gain_int.shape == ()
    mb = cache.serve_update_batch(reqs[1:9])
    assert mb.gain_int.shape == (8,)
    assert int(cache.state.t) == 9
    assert abs(float(jnp.sum(cache.state.y)) - cfg.h) < 1e-2
