"""Serving substrate: prefill/decode engine with KV/SSM caches, continuous
batching, the AÇAI semantic cache tier, the resilient remote tier
(fault-injected backend + retry/hedge/deadline/degrade, DESIGN.md §11),
the online serving engine (arrival processes + request queue +
dynamic batch former + admission control on the virtual clock,
DESIGN.md §12), and the answer-cache tier (exact top-k memoization in
front of the index with precise churn invalidation and idle unload,
DESIGN.md §13)."""

from repro.serve.answer_cache import (AnswerCache, AnswerCacheSpec,
                                      CachedIndex, parse_answer_cache_opts,
                                      resolve_answer_cache_spec)
from repro.serve.arrivals import (ARRIVAL_KINDS, ArrivalSpec,
                                  ClosedLoopSource, OpenLoopSource,
                                  arrival_times, make_source)
from repro.serve.engine import ServeEngine, generate, make_decode_step, make_prefill
from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                               OnlineServingEngine, RequestRecord,
                               ServiceModel, fixed_window_engine,
                               serve_trace_online)
from repro.serve.remote import (FaultSpec, FaultyRemote, OracleRemote,
                                RemoteBackend, parse_outage_windows,
                                payload_ok)
from repro.serve.resilience import (CircuitBreaker, RemoteSession,
                                    ResilienceConfig, ResilientPolicy,
                                    RetryConfig, replay_resilient,
                                    simulate_request)
from repro.serve.semantic_cache import SemanticCachedLM, embed_prompt

__all__ = ["ARRIVAL_KINDS", "AdmissionConfig", "AnswerCache",
           "AnswerCacheSpec", "ArrivalSpec",
           "BatchFormerConfig", "CachedIndex", "CircuitBreaker",
           "ClosedLoopSource",
           "FaultSpec", "FaultyRemote", "OnlineServingEngine",
           "OpenLoopSource", "OracleRemote", "RemoteBackend",
           "RemoteSession", "RequestRecord", "ResilienceConfig",
           "ResilientPolicy", "RetryConfig", "SemanticCachedLM",
           "ServeEngine", "ServiceModel", "arrival_times", "embed_prompt",
           "fixed_window_engine", "generate", "make_decode_step",
           "make_prefill", "make_source", "parse_answer_cache_opts",
           "parse_outage_windows",
           "payload_ok", "replay_resilient", "resolve_answer_cache_spec",
           "serve_trace_online", "simulate_request"]
