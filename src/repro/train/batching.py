"""Per-arch batch construction + ShapeDtypeStruct input specs.

The modality frontends are STUBS per the assignment: audio/vision batches
carry precomputed frame/patch embeddings next to (or instead of) tokens.
A batch is a flat dict of arrays; `input_specs` mirrors it with
ShapeDtypeStructs for the dry-run (no allocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig

N_PATCHES = 1024   # vision prefix length inside seq_len (stubbed frontend)


def batch_shapes(cfg: ModelConfig, shape: ShapeSpec, kind: str | None = None):
    """Returns {name: (shape, dtype)} for one *global* batch."""
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if kind == "decode":
        out = {"tokens": ((b, 1), jnp.int32)}
        if cfg.pos_emb == "mrope":
            out["positions3"] = ((3, b, 1), jnp.int32)
        return out
    if cfg.modality == "audio":
        out = {"embeds": ((b, s, cfg.d_model), dt)}
        if kind == "train":
            out["labels"] = ((b, s), jnp.int32)
            out["loss_mask"] = ((b, s), jnp.float32)
        return out
    if cfg.modality == "vision":
        p = min(N_PATCHES, s // 2)
        out = {
            "tokens": ((b, s - p), jnp.int32),
            "embeds": ((b, p, cfg.d_model), dt),
            "positions3": ((3, b, s), jnp.int32),
        }
        if kind == "train":
            out["labels"] = ((b, s), jnp.int32)
            out["loss_mask"] = ((b, s), jnp.float32)
        return out
    out = {"tokens": ((b, s), jnp.int32)}
    if kind == "train":
        out["labels"] = ((b, s), jnp.int32)
        out["loss_mask"] = ((b, s), jnp.float32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec, kind: str | None = None):
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    return {k: jax.ShapeDtypeStruct(sh, dt)
            for k, (sh, dt) in batch_shapes(cfg, shape, kind).items()}


def synthetic_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                    kind: str | None = None):
    """Materialised random batch with the same structure (smoke tests)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (sh, dt) in batch_shapes(cfg, shape, kind).items():
        if dt == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels") else max(sh[-1], 2)
            out[k] = jnp.asarray(rng.integers(0, hi, sh), jnp.int32)
        elif k == "loss_mask":
            out[k] = jnp.ones(sh, jnp.float32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, sh), dt)
    return out


def forward_kwargs(cfg: ModelConfig, batch: dict) -> dict:
    """Split a batch dict into forward() inputs (labels stay behind)."""
    kw = {}
    for k in ("tokens", "embeds", "positions3"):
        if k in batch:
            kw[k] = batch[k]
    return kw
