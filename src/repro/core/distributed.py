"""Distributed AÇAI: the paper's retrieval/caching step at pod scale.

At production scale the catalog (10^8 x d embeddings) and the fractional
cache state y live SHARDED over the `model` mesh axis; the request batch is
data-parallel.  One serve+update step per request batch:

  1. every chip scans its catalog shard with the (Pallas) distance kernel
     and takes a local top-C            -> compute-bound, no comms
  2. all-gather of per-shard top-C over `model` (tiny: C ids+dists/request)
     and a top-C re-merge               -> the only quadratic-free exchange
  3. per-request gain/subgradient on the merged candidates (Eq. 55)
  4. subgradients routed to the owning y-shards via all_gather over `data`
     + local mask (candidate traffic: B x C pairs, bytes not catalog-sized)
  5. OMA multiplicative update + DISTRIBUTED capped-simplex projection:
     per-shard top-A + per-shard tail sums are all-gathered (A x shards
     scalars), the exact global water-filling scale is solved locally and
     applied shard-wise — the O(N log N) sort of Sec. IV-F becomes
     O(N/P log A) + an O(A.P) scalar exchange.

The serve answer (ids/costs of the k cheapest augmented copies) comes out
of the same merged candidate set.  This file is lowered by the dry-run as
the paper-representative roofline cell (`acai-retrieval`).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import gain as gain_lib
from repro.core.costs import BIG_COST
from repro.core.projection import _negentropy_scale_from_sorted


def _local_topk_scan(requests, catalog, c: int, chunk: int):
    """Fused distance+top-k over catalog chunks: never materialises the
    (B, N_shard) distance matrix in HBM (the XLA analogue of the Pallas
    l2_topk kernel — §Perf optimization for the retrieval cell)."""
    n = catalog.shape[0]
    qn = jnp.sum(requests * requests, axis=1, keepdims=True)
    nchunks = max(n // chunk, 1)

    def body(carry, j):
        best_d, best_i = carry
        blk = jax.lax.dynamic_slice_in_dim(catalog, j * chunk, chunk, 0)
        cn = jnp.sum(blk * blk, axis=1)[None, :]
        d2 = jnp.maximum(qn - 2.0 * requests @ blk.T + cn, 0.0)
        ids = j * chunk + jnp.arange(chunk)[None, :]
        cat_d = jnp.concatenate([best_d, d2], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(
            ids, (requests.shape[0], chunk))], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, c)
        return (-neg, jnp.take_along_axis(cat_i, pos, axis=1)), None

    init = (jnp.full((requests.shape[0], c), jnp.inf, jnp.float32),
            jnp.zeros((requests.shape[0], c), jnp.int32))
    (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    return -best_d, best_i  # (neg-dist convention of lax.top_k callers)


def make_retrieval_step(mesh, *, n_shard: int, d: int, c: int, k: int,
                        c_f: float, h: int, eta: float, top_a: int,
                        batch_axes=("data",), model_axis: str = "model",
                        scan_chunk: int = 0):
    """Returns step(catalog_shard, y, requests) -> (y_new, answer, metrics)
    wrapped in shard_map over `mesh`.

    catalog: (N, d) sharded P(model, None);  y: (N,) sharded P(model);
    requests: (B, d) sharded P(batch_axes, None).
    scan_chunk > 0 switches the local scan to the fused chunked top-k
    (memory-roofline optimization; 0 = paper-faithful full matrix).
    """
    n_model = 1
    for ax in ([model_axis] if isinstance(model_axis, str) else model_axis):
        n_model *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]

    def step(catalog, y, requests):
        # ---- 1. local distance scan + top-C (per shard) -----------------
        if scan_chunk:
            neg, loc_ids = _local_topk_scan(requests, catalog, c, scan_chunk)
            neg = -neg
        else:
            qn = jnp.sum(requests * requests, axis=1, keepdims=True)
            cn = jnp.sum(catalog * catalog, axis=1)[None, :]
            d2 = jnp.maximum(qn - 2.0 * requests @ catalog.T + cn, 0.0)
            neg, loc_ids = jax.lax.top_k(-d2, c)             # (b, C)
        my_shard = jax.lax.axis_index(model_axis)
        glob_ids = loc_ids + my_shard * n_shard

        # ---- 2. merge shards' candidates over `model` --------------------
        all_d = jax.lax.all_gather(-neg, model_axis, axis=1,
                                   tiled=True)                # (b, P*C)
        all_ids = jax.lax.all_gather(glob_ids, model_axis, axis=1,
                                     tiled=True)
        negm, pos = jax.lax.top_k(-all_d, c)                  # global top-C
        cand_ids = jnp.take_along_axis(all_ids, pos, axis=1)
        cand_d = -negm

        # candidate y values: gather from the sharded y via gather-all
        # (y is (n_shard,) per chip; candidates span shards, so gather the
        # candidate y's with a masked local lookup + psum over model)
        local = (cand_ids >= my_shard * n_shard) & \
                (cand_ids < (my_shard + 1) * n_shard)
        safe = jnp.clip(cand_ids - my_shard * n_shard, 0, n_shard - 1)
        y_cand = jnp.where(local, y[safe], 0.0)
        y_cand = jax.lax.psum(y_cand, model_axis)             # (b, C)

        # ---- 3. serve + subgradient (Eq. 2 / Eq. 55) ---------------------
        serve = jax.vmap(lambda dd, xx: gain_lib.serve(dd, xx, k, c_f))(
            cand_d, (y_cand > 0.5).astype(cand_d.dtype))
        _, g_cand = jax.vmap(
            lambda dd, yy: gain_lib.gain_and_subgradient(dd, yy, k, c_f))(
            cand_d, y_cand)

        # ---- 4. route subgradients to owning shards ----------------------
        g_all = jax.lax.all_gather(g_cand, batch_axes, axis=0, tiled=True)
        ids_all = jax.lax.all_gather(cand_ids, batch_axes, axis=0,
                                     tiled=True)               # (B, C)
        mine = (ids_all >= my_shard * n_shard) & \
               (ids_all < (my_shard + 1) * n_shard)
        local_idx = jnp.clip(ids_all - my_shard * n_shard, 0, n_shard - 1)
        g_shard = jnp.zeros((n_shard,), y.dtype).at[
            local_idx.reshape(-1)].add(
            jnp.where(mine, g_all, 0.0).reshape(-1))

        # ---- 5. OMA + distributed projection -----------------------------
        z = y * jnp.exp(jnp.clip(eta * g_shard, -60.0, 60.0))
        ztop, _ = jax.lax.top_k(z, top_a)
        tail = jnp.sum(z) - jnp.sum(ztop)
        heads = jax.lax.all_gather(ztop, model_axis, tiled=True)  # (P*A,)
        tails = jax.lax.psum(tail, model_axis)
        heads = jnp.sort(heads)[::-1]
        s, _ = _negentropy_scale_from_sorted(heads, tails, float(h))
        y_new = jnp.clip(jnp.minimum(1.0, z * s), 1e-12, 1.0)

        metrics = {
            "gain": jax.lax.pmean(jnp.mean(serve.gain), batch_axes),
            "served_local": jax.lax.pmean(
                jnp.mean(jnp.sum(serve.from_cache, axis=1).astype(jnp.float32)),
                batch_axes),
        }
        return y_new, serve.answer_ids, metrics

    return jax.shard_map(
        step, mesh=mesh,
        in_specs=(P(model_axis, None), P(model_axis), P(batch_axes, None)),
        out_specs=(P(model_axis), P(batch_axes, None),
                   {"gain": P(), "served_local": P()}),
        check_vma=False,
    )


def reference_step(catalog, y, requests, *, c, k, c_f, h, eta, top_a):
    """Single-device oracle with identical semantics (for tests)."""
    from repro.core import projection

    d2 = jnp.maximum(
        jnp.sum(requests ** 2, 1, keepdims=True)
        - 2 * requests @ catalog.T + jnp.sum(catalog ** 2, 1)[None], 0.0)
    neg, ids = jax.lax.top_k(-d2, c)
    cand_d = -neg
    y_cand = y[ids]
    serve = jax.vmap(lambda dd, xx: gain_lib.serve(dd, xx, k, c_f))(
        cand_d, (y_cand > 0.5).astype(cand_d.dtype))
    _, g_cand = jax.vmap(
        lambda dd, yy: gain_lib.gain_and_subgradient(dd, yy, k, c_f))(
        cand_d, y_cand)
    g = jnp.zeros_like(y).at[ids.reshape(-1)].add(g_cand.reshape(-1))
    z = y * jnp.exp(jnp.clip(eta * g, -60.0, 60.0))
    y_new = projection.capped_simplex_negentropy_topk(z, h, top_a)
    return jnp.clip(y_new, 1e-12, 1.0), serve.answer_ids
