"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
pure data parallelism across pods (gradient all-reduce over DCI), `model`
stays intra-pod where ICI bandwidth lives.

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(multi_pod: bool) -> tuple:
    return ("pod", "data") if multi_pod else ("data",)


def make_host_mesh():
    """1-device mesh for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
