"""Fig. 1: normalised average gain vs requests, h=1000 (reduced: 200), k=10."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    h = 1000 if full else 200
    k = 10
    c_f = s.cf_table[50]
    out = {}

    m, dt = common.run_acai(s, h=h, k=k, c_f=c_f)
    curve = B.nag(m["gain"], k, c_f)
    out["ACAI"] = curve
    common.emit(f"fig1/{kind}/ACAI", dt * 1e6, f"{curve[-1]:.4f}")

    for name in B.POLICIES:
        nagv, mtr, dtb = common.tune_baseline(s, name, h=h, k=k, c_f=c_f)
        out[name] = B.nag(mtr["gain"], k, c_f)
        common.emit(f"fig1/{kind}/{name}", dtb * 1e6, f"{nagv:.4f}")

    second = max(v[-1] for kname, v in out.items() if kname != "ACAI")
    common.emit(f"fig1/{kind}/improvement_vs_2nd", 0.0,
                f"{(out['ACAI'][-1] - second) / max(second, 1e-9):+.2%}")
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
