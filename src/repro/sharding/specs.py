"""PartitionSpec rules for params, optimizer state, batches and caches.

TP: head/FFN/expert dims shard over `model`.  FSDP (cfg.fsdp): the other
matrix dim additionally shards over `data` (XLA all-gathers params per
layer — ZeRO-3 semantics under pjit).  EP: expert-stacked weights shard E
over `model` when E >= mesh model size, otherwise expert-internal FFN dims
shard (TP-within-expert).  DP: the batch dim shards over ('pod','data').

Every rule passes through a divisibility check: an axis that does not
divide the dimension is dropped (replicated) — this is what makes one rule
set serve all ten architectures (e.g. mamba2's 24 SSD heads do not divide
a 16-way model axis; its head-indexed vectors replicate while its big
matrices still shard).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# param-name -> (axis per dim) templates; 'F' = fsdp axis (data, if enabled)
_RULES_2D = {
    "embed": ("model", "F"),
    "lm_head": ("F", "model"),
    "wq": ("F", "model"), "wk": ("F", "model"), "wv": ("F", "model"),
    "wo": ("model", "F"),
    "wi": ("F", "model"), "wg": ("F", "model"),
    "in_proj": ("F", "model"), "out_proj": ("model", "F"),
    "wq_a": ("F", "model"), "wq_b": ("F", "model"),
    "wkv_a": ("F", "model"), "wk_b": ("F", "model"), "wv_b": ("F", "model"),
    "router": ("F", None),
    "proj": ("F", "model"),
    "conv_w": (None, "model"),
}
_RULES_1D_MODEL = {"bq", "bk", "bv", "conv_b", "a_log", "dt_bias", "d_skip",
                   "norm_w"}


def _axis_size(mesh_shape: dict, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh_shape.get(a, 1)
        return n
    return mesh_shape.get(axis, 1)


def _check(spec_axes, shape, mesh_shape):
    out = []
    for dim, axis in zip(shape, spec_axes):
        out.append(axis if axis and dim % _axis_size(mesh_shape, axis) == 0
                   else None)
    return tuple(out)


def param_pspec(path_keys, shape, cfg: ModelConfig, mesh_shape: dict) -> P:
    name = path_keys[-1]
    stacked = path_keys[0] == "body"  # leading n_units axis from the scan
    core_shape = shape[1:] if stacked else shape
    fsdp = "data" if cfg.fsdp else None

    def t(axes):
        axes = tuple(fsdp if a == "F" else a for a in axes)
        axes = _check(axes, core_shape, mesh_shape)
        return P(*((None,) + axes)) if stacked else P(*axes)

    if len(core_shape) == 3 and name in ("wi", "wg", "wo"):
        e = core_shape[0]
        if e % _axis_size(mesh_shape, "model") == 0:
            axes = ("model", fsdp, None) if name in ("wi", "wg") else \
                   ("model", None, fsdp)
        else:  # few experts: TP inside each expert instead
            axes = (None, fsdp, "model") if name in ("wi", "wg") else \
                   (None, "model", fsdp)
        axes = _check(axes, core_shape, mesh_shape)
        return P(*((None,) + axes)) if stacked else P(*axes)
    if len(core_shape) == 2 and name in _RULES_2D:
        # attention projections: if the HEAD counts do not divide the model
        # axis (e.g. qwen2-vl's 28H/kv4 on a 16-way axis), sharding the
        # flattened head*dim axis forces a reshard at every (B,S,H,D)
        # reshape — per-layer all-gathers.  Fall back to data-only sharding
        # for those matrices (§Perf 'head-alignment' iteration).
        if cfg.replicate_misaligned_heads and name in ("wq", "wk", "wv",
                                                       "wo"):
            msize = _axis_size(mesh_shape, "model")
            heads = cfg.n_kv_heads if name in ("wk", "wv") else cfg.n_heads
            if heads and msize > 1 and heads % msize != 0:
                axes = (fsdp, None) if name != "wo" else (None, fsdp)
                axes = _check(axes, core_shape, mesh_shape)
                return P(*((None,) + axes)) if stacked else P(*axes)
        return t(_RULES_2D[name])
    if len(core_shape) == 1 and name in _RULES_1D_MODEL:
        return t(("model",))
    # norms, scalars, everything else: replicated
    return P(*((None,) * len(shape)))


def _path_keys(path) -> tuple:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspecs(cfg: ModelConfig, params_shape, mesh_shape: dict):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(_path_keys(path), leaf.shape, cfg,
                                       mesh_shape),
        params_shape)


def opt_pspecs(name: str, params_shape, pspecs, cfg: ModelConfig,
               mesh_shape: dict):
    """Optimizer-state specs mirroring the param specs.

    AdamW: master/m/v share the param spec.  Adafactor: vr drops the last
    dim's axis, vc drops the second-to-last."""
    if name == "adamw":
        return {"master": pspecs, "m": pspecs, "v": pspecs, "count": P()}

    def factored(leaf, spec):
        axes = tuple(spec)
        # pad the spec to the leaf's rank (trailing dims replicated)
        axes = axes + (None,) * (len(leaf.shape) - len(axes))
        if len(leaf.shape) >= 2:
            return {"vr": P(*axes[:-1]), "vc": P(*(axes[:-2] + axes[-1:]))}
        return {"v": P(*axes)}

    # params_shape drives the structure; the pspec tree matches it leafwise
    v = jax.tree.map(factored, params_shape, pspecs)
    return {"v": v, "count": P()}


def batch_pspecs(cfg: ModelConfig, batch_specs: dict, multi_pod: bool,
                 mesh_shape: dict | None = None):
    """Batch dims shard over ('pod','data'); any dim that does not divide
    (e.g. global_batch=1 in long_500k) falls back: for caches/tokens the
    sequence dim takes the batch axes instead when it divides (handled in
    cache_pspecs); here the axis is simply dropped."""
    batch_ax = ("pod", "data") if multi_pod else ("data",)
    mesh_shape = mesh_shape or {}
    out = {}
    for k, v in batch_specs.items():
        if k == "positions3":
            axes = (None, batch_ax) + (None,) * (len(v.shape) - 2)
        else:
            axes = (batch_ax,) + (None,) * (len(v.shape) - 1)
        out[k] = P(*_check(axes, v.shape, mesh_shape))
    return out


def cache_pspecs(cfg: ModelConfig, cache_shape, mesh_shape: dict,
                 multi_pod: bool):
    """KV caches: batch over ('pod','data'), kv-head/feature dim over
    'model' when divisible."""
    batch_ax = ("pod", "data") if multi_pod else ("data",)

    def spec(path, leaf):
        keys = _path_keys(path)
        stacked = keys[0] == "body"
        shape = leaf.shape[1:] if stacked else leaf.shape
        name = keys[-1]
        bsz = _axis_size(mesh_shape, batch_ax)
        # long-context decode (global_batch=1): shard the SEQUENCE dim of
        # the KV/latent caches over the batch axes instead.
        seq_shard = shape[0] % bsz != 0 if shape else False
        if name in ("k", "v"):            # (B, S, KV, D)
            axes = ((None, batch_ax, "model", None) if seq_shard
                    else (batch_ax, None, "model", None))
        elif name in ("ckv", "krope"):    # (B, S, R)
            axes = ((None, batch_ax, None) if seq_shard
                    else (batch_ax, None, None))
        elif name == "conv":              # (B, K-1, CH)
            axes = (batch_ax, None, "model")
        elif name == "ssm":               # (B, H, P, N)
            axes = (batch_ax, "model", None, None)
        else:
            axes = (None,) * len(shape)
        axes = _check(axes, shape, mesh_shape)
        return P(*((None,) + axes)) if stacked else P(*axes)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def as_shardings(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs, is_leaf=lambda x: isinstance(x, P))
