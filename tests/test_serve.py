"""Serving engine: generation determinism, continuous batching, semantic cache."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models import init_params
from repro.serve import SemanticCachedLM, ServeEngine, generate


@pytest.fixture(scope="module")
def lm():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_generate_greedy_deterministic(lm):
    cfg, params = lm
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    a = generate(params, cfg, prompt, steps=6)
    b = generate(params, cfg, prompt, steps=6)
    np.testing.assert_array_equal(np.array(a), np.array(b))
    assert a.shape == (2, 6)


def test_generate_matches_stepwise_full_forward(lm):
    """Greedy generate == repeatedly running the full forward (no cache)."""
    from repro.models import forward
    cfg, params = lm
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab)
    gen = np.array(generate(params, cfg, prompt, steps=5))[0]
    toks = prompt
    for i in range(5):
        logits = forward(params, cfg, tokens=toks).logits
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == gen[i], (i, nxt, gen)
        toks = jnp.concatenate([toks, jnp.array([[nxt]], jnp.int32)], axis=1)


def test_continuous_batching_completes_all(lm):
    cfg, params = lm
    engine = ServeEngine(params, cfg, batch=3, s_max=32)
    rng = np.random.default_rng(0)
    for i in range(7):
        engine.submit(i, jnp.asarray(rng.integers(0, cfg.vocab, 8), jnp.int32),
                      max_tokens=4)
    while engine.step():
        pass
    assert sorted(engine.done) == list(range(7))
    assert all(len(t) >= 4 for t in engine.done.values())


def test_admit_slot_reuse_fifo(lm):
    """_admit fills free slots FIFO and reuses slots freed by finished
    requests: with batch=2 and 5 submissions, the first wave admits
    exactly 2, later waves recycle the same physical slots, every
    admission count is bounded by the free-slot count, and all requests
    still complete."""
    cfg, params = lm
    engine = ServeEngine(params, cfg, batch=2, s_max=32)
    rng = np.random.default_rng(1)
    for i in range(5):
        engine.submit(i, jnp.asarray(rng.integers(0, cfg.vocab, 6), jnp.int32),
                      max_tokens=2)
    admitted = engine._admit()
    assert admitted == 2  # only batch slots available, FIFO order
    assert [s.request_id for s in engine.slots] == [0, 1]
    assert engine._admit() == 0  # no free slot until one finishes
    while engine.step():
        pass
    # every queued request eventually ran through a recycled slot
    assert sorted(engine.done) == list(range(5))
    assert not any(s.active for s in engine.slots)
    assert engine._admit() == 0  # queue drained


def test_mamba_generate(lm):
    cfg = SMOKE_ARCHS["mamba2-130m"]
    params = init_params(jax.random.PRNGKey(3), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, cfg.vocab)
    out = generate(params, cfg, prompt, steps=4)
    assert out.shape == (1, 4)
    assert (np.array(out) >= 0).all() and (np.array(out) < cfg.vocab).all()


def test_semantic_cache_serves_repeats_locally(lm):
    cfg, params = lm
    rng = np.random.default_rng(0)
    catalog = jnp.asarray(rng.normal(size=(300, cfg.d_model)), jnp.float32)
    catalog = catalog / jnp.linalg.norm(catalog, axis=1, keepdims=True)
    calls = {"n": 0}

    def gen_fn(p):
        calls["n"] += 1
        return None

    smc = SemanticCachedLM(params, cfg, catalog,
                           [str(i) for i in range(300)], gen_fn, h=40, k=4)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, 10), jnp.int32)
    for _ in range(30):
        smc.query(prompt)  # identical request stream
    # after warmup the k best objects are cached: served locally
    assert smc.stats.served_local > 0.5 * 30 * 4
    assert smc.nag > 0.3
