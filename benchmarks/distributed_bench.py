"""Sharded AÇAI replay throughput: the scaling trajectory of the
multi-device serving path.

Runs `make_replay_sharded` on host-platform device meshes over a
shards ∈ {1, 4, 8} × B ∈ {8, 64} grid (same trace/config constants as the
`pipeline` suite, so the 1-shard rows are directly comparable to
BENCH_pipeline.json's batched exact path) and writes BENCH_distributed.json
at the repo root so the trajectory is tracked per PR.

Each shard count runs in its own subprocess with exactly that many
placeholder devices: the device count must be fixed before jax initialises
(same discipline as launch/dryrun.py), and forcing 8 devices for the
1-shard row would split the host threadpool 8 ways and poison the
comparison against the single-device pipeline numbers (measured ~3x tax).
On CPU the multi-shard rows track collective/emulation overhead, not
speedup — the scaling signal is the trend of this file on real hardware.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap

from benchmarks import common

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_distributed.json"

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count={shards} "
        + os.environ.get("XLA_FLAGS", ""))
    import json
    import time
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import oma, policy, trace
    from repro.core.costs import calibrate_fetch_cost
    from repro.core.distributed import make_replay_sharded

    n, t, d, kind, shards = {n}, {t}, {d}, {kind!r}, {shards}
    gen = trace.sift_like if kind == "sift" else trace.amazon_like
    catalog, reqs, _ = gen(n=n, d=d, t=t, seed=0)
    cat, reqs_j = jnp.array(catalog), jnp.array(reqs)
    c_f = float(calibrate_fetch_cost(cat, kth=min(50, n - 1), sample=256))
    cfg = policy.AcaiConfig(h=64, k=8, c_f=c_f, c_remote=32, c_local=16,
                            oma=oma.OMAConfig(eta=0.05 / c_f))

    rows = []
    mesh = jax.make_mesh((1, shards), ("data", "model"))
    for b in (8, 64):
        replay = make_replay_sharded(cfg, mesh, cat, b)
        state = policy.init_state(n, cfg)
        tt = (t // b) * b
        r = reqs_j[:tt]
        _, m = replay(state, r)                       # compile + warmup
        m.gain_int.block_until_ready()
        t0 = time.time()
        _, m = replay(state, r)
        m.gain_int.block_until_ready()
        dt = time.time() - t0
        nag = float(np.sum(np.asarray(m.gain_int))) / (cfg.k * c_f * tt)
        rows.append({{
            "shards": shards, "batch": b, "candidates": "exact-sharded",
            "requests_per_s": round(tt / dt, 1),
            "us_per_request": round(dt / tt * 1e6, 2),
            "nag": round(nag, 4), "requests": tt,
        }})
    print(json.dumps({{"rows": rows, "ndev": jax.device_count(),
                       "backend": jax.default_backend()}}))
""")


def main(full: bool = False, kind: str = "sift") -> None:
    n, t, d = (20000, 16384, 32) if full else (2000, 2048, 16)
    rows, ndev, backend = [], {}, None
    for shards in (1, 4, 8):
        child = _CHILD.format(n=n, t=t, d=d, kind=kind, shards=shards)
        out = subprocess.run(
            [sys.executable, "-c", child], capture_output=True, text=True,
            timeout=3600,
            env={**os.environ,
                 "PYTHONPATH": str(BENCH_JSON.parent / "src") + (
                     os.pathsep + os.environ["PYTHONPATH"]
                     if os.environ.get("PYTHONPATH") else "")})
        if out.returncode != 0:
            raise RuntimeError(f"distributed bench child (shards={shards}) "
                               f"failed:\n{out.stderr[-3000:]}")
        res = json.loads(out.stdout.strip().splitlines()[-1])
        rows += res["rows"]
        ndev[str(shards)] = res["ndev"]
        backend = res["backend"]
    for row in rows:
        common.emit(
            f"distributed/{kind}/shards{row['shards']}/B{row['batch']}",
            row["us_per_request"],
            f"NAG={row['nag']:.4f};rps={row['requests_per_s']:.0f}")
    BENCH_JSON.write_text(json.dumps(
        {"kind": kind, "full": full, "n": n, "d": d,
         "devices_per_child": ndev, "backend": backend,
         # the sharded path always runs the distributed top-A water-filling
         # projection; the BENCH_pipeline.json baseline runs the exact
         # full-sort projection (projection_topk = 0) — NAG agrees to 4
         # decimals on this workload, timing differs by < the CPU noise.
         "projection": "water-filling top-A (2h+64)", "rows": rows},
        indent=2) + "\n")
    common.emit("distributed/json", 0.0, str(BENCH_JSON.name))


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
