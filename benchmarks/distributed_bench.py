"""Sharded AÇAI replay throughput: the scaling trajectory of the
multi-device serving path.

Runs `make_replay_sharded` over a shards ∈ {1, 4, 8} × catalog-size sweep
× B ∈ {8, 64} grid (same config constants as the `pipeline` suite, so the
1-shard rows are directly comparable to BENCH_pipeline.json's batched
exact path) and writes BENCH_distributed.json at the repo root so the
trajectory is tracked per PR.  The sweep exists to locate `break_even_n`:
the smallest catalog where a 4-shard mesh beats 1 shard — the fused
per-step collective budget (DESIGN.md §15) is a fixed cost, the per-shard
scan is O(n / P), so the crossover moves with n.

Each shard count runs in its own subprocess with exactly that many
placeholder devices: the device count must be fixed before jax initialises
(same discipline as launch/dryrun.py), and forcing 8 devices for the
1-shard row would split the host threadpool 8 ways and poison the
comparison against the single-device pipeline numbers (measured ~3x tax).
The 1-shard child additionally asserts its replay is BITWISE identical to
`make_replay_batched` + exact candidates (the `bit_consistent` column) —
the bench refuses to publish numbers for a path that drifted from the
reference policy.  Every row also carries the statically counted
`collectives_per_step` (no timing noise), pinned independently by
tests/test_collectives.py.

On CPU the multi-shard rows track collective/emulation overhead, not
speedup — when no crossover exists in the sweep, `break_even_n` is null
and `break_even_note` says so honestly; the scaling signal is the trend
of this file on real hardware.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap

from benchmarks import common

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_distributed.json"

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count={shards} "
        + os.environ.get("XLA_FLAGS", ""))
    import json
    import time
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import oma, policy, trace
    from repro.core.costs import calibrate_fetch_cost
    from repro.core.distributed import (collectives_per_step,
                                        make_step_sharded)

    t, d, kind, shards, n_sweep = {t}, {d}, {kind!r}, {shards}, {n_sweep}
    gen = trace.sift_like if kind == "sift" else trace.amazon_like
    rows = []
    mesh = jax.make_mesh((1, shards), ("data", "model"))
    for n in n_sweep:
        catalog, reqs, _ = gen(n=n, d=d, t=t, seed=0)
        cat, reqs_j = jnp.array(catalog), jnp.array(reqs)
        c_f = float(calibrate_fetch_cost(cat, kth=min(50, n - 1),
                                         sample=256))
        h = 64
        cfg = policy.AcaiConfig(
            h=h, k=8, c_f=c_f, c_remote=32, c_local=16,
            oma=oma.OMAConfig(eta=0.05 / c_f, projection_topk=2 * h + 64))
        for b in (8, 64):
            step = make_step_sharded(cfg, mesh, cat, b)
            coll, _ = collectives_per_step(
                step, policy.init_state(n, cfg), jnp.zeros((b, d)))
            replay = policy.make_replay_from_step(step, b)
            state = policy.init_state(n, cfg)
            tt = (t // b) * b
            r = reqs_j[:tt]
            _, m = replay(state, r)                   # compile + warmup
            m.gain_int.block_until_ready()
            t0 = time.time()
            st_s, m = replay(state, r)
            m.gain_int.block_until_ready()
            dt = time.time() - t0
            bit = None
            if shards == 1:
                # the sharded path must BE the reference policy: bitwise
                # per-request gains and final fractional state
                ref = policy.make_replay_batched(
                    cfg, policy.exact_candidate_fn_batched(
                        cat, cfg.c_remote, cfg.c_local), b)
                st_r, m_r = ref(policy.init_state(n, cfg), r)
                assert (np.asarray(m.gain_int)
                        == np.asarray(m_r.gain_int)).all(), (n, b)
                assert (np.asarray(st_s.y) == np.asarray(st_r.y)).all(), (
                    n, b)
                bit = True
            nag = float(np.sum(np.asarray(m.gain_int))) / (cfg.k * c_f * tt)
            rows.append({{
                "shards": shards, "batch": b, "n": n,
                "candidates": "exact-sharded",
                "requests_per_s": round(tt / dt, 1),
                "us_per_request": round(dt / tt * 1e6, 2),
                "nag": round(nag, 4), "requests": tt,
                "collectives_per_step": coll, "bit_consistent": bit,
            }})
    print(json.dumps({{"rows": rows, "ndev": jax.device_count(),
                       "backend": jax.default_backend()}}))
""")


def _break_even(rows: list) -> tuple[dict, str]:
    """Smallest n (per batch size) where 4 shards out-serve 1 shard."""
    rps = {(r["shards"], r["batch"], r["n"]): r["requests_per_s"]
           for r in rows}
    even: dict = {}
    for b in sorted({r["batch"] for r in rows}):
        ns = sorted({r["n"] for r in rows})
        even[str(b)] = next(
            (n for n in ns
             if (4, b, n) in rps and rps[(4, b, n)] >= rps[(1, b, n)]),
            None)
    if all(v is None for v in even.values()):
        note = ("no crossover in this sweep: on the host-emulated CPU "
                "backend every shard adds threadpool contention but no "
                "compute; the fused budget caps the *collective* cost at "
                "3 per step, so the crossover is expected where the "
                "O(n/P) scan dominates on real multi-chip hardware")
    else:
        note = ("smallest swept n where shards=4 requests/s >= shards=1, "
                "per batch size")
    return even, note


def main(full: bool = False, kind: str = "sift") -> None:
    t, d = (8192, 32) if full else (2048, 16)
    n_sweep = (5000, 20000, 80000) if full else (512, 2048, 8192)
    rows, ndev, backend = [], {}, None
    for shards in (1, 4, 8):
        child = _CHILD.format(t=t, d=d, kind=kind, shards=shards,
                              n_sweep=n_sweep)
        out = subprocess.run(
            [sys.executable, "-c", child], capture_output=True, text=True,
            timeout=3600,
            env={**os.environ,
                 "PYTHONPATH": str(BENCH_JSON.parent / "src") + (
                     os.pathsep + os.environ["PYTHONPATH"]
                     if os.environ.get("PYTHONPATH") else "")})
        if out.returncode != 0:
            raise RuntimeError(f"distributed bench child (shards={shards}) "
                               f"failed:\n{out.stderr[-3000:]}")
        res = json.loads(out.stdout.strip().splitlines()[-1])
        rows += res["rows"]
        ndev[str(shards)] = res["ndev"]
        backend = res["backend"]
    for row in rows:
        common.emit(
            f"distributed/{kind}/n{row['n']}/shards{row['shards']}"
            f"/B{row['batch']}",
            row["us_per_request"],
            f"NAG={row['nag']:.4f};rps={row['requests_per_s']:.0f}"
            f";coll={row['collectives_per_step']}")
    break_even_n, note = _break_even(rows)
    BENCH_JSON.write_text(json.dumps(
        {"kind": kind, "full": full, "n_sweep": list(n_sweep), "d": d,
         "devices_per_child": ndev, "backend": backend,
         "break_even_n": break_even_n, "break_even_note": note,
         # the sharded path always runs the distributed top-A water-filling
         # projection; projection_topk pins the 1-shard reference to the
         # same top-A so the bit_consistent assert is exact equality.
         "projection": "water-filling top-A (2h+64)", "rows": rows},
        indent=2) + "\n")
    common.emit("distributed/json", 0.0, str(BENCH_JSON.name))


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
