"""Serve a small LM with batched requests behind the AÇAI semantic cache.

The end-to-end serving driver: a continuous-batching decode engine answers
prompts; an AÇAI similarity cache in front serves repeat/near-duplicate
queries from the edge store instead of recomputing, with the fetching cost
calibrated to the cost of a generation.

  PYTHONPATH=src python examples/serve_semantic_cache.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import SMOKE_ARCHS
from repro.models import init_params
from repro.serve import SemanticCachedLM, ServeEngine, generate


def main():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # continuous-batching engine: 24 requests through 4 slots
    engine = ServeEngine(params, cfg, batch=4, s_max=40)
    for i in range(24):
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, 12), jnp.int32)
        engine.submit(i, prompt, max_tokens=6)
    t0 = time.time()
    while engine.step():
        pass
    toks = sum(len(v) for v in engine.done.values())
    print(f"engine: {len(engine.done)} requests / {toks} tokens "
          f"in {time.time() - t0:.1f}s")

    # semantic cache over a catalog of precomputed results
    catalog = jnp.asarray(rng.normal(size=(600, cfg.d_model)), jnp.float32)
    catalog = catalog / jnp.linalg.norm(catalog, axis=1, keepdims=True)
    # c_f = distance of the 5th neighbour: a "close" server, where serving
    # far objects locally is NOT worth it -> misses trigger generations
    # until OMA concentrates the cache on the hot region.
    from repro.core.costs import calibrate_fetch_cost
    c_f = float(calibrate_fetch_cost(catalog, kth=5))
    lm = SemanticCachedLM(
        params, cfg, catalog, [f"result-{i}" for i in range(600)],
        generate_fn=lambda p: generate(params, cfg, p[None], steps=4),
        h=48, k=4, c_f=c_f)

    # zipf-repeating prompt stream: strong temporal locality => cache hits
    pool = [jnp.asarray(rng.integers(0, cfg.vocab, 12), jnp.int32)
            for _ in range(30)]
    w = (np.arange(30) + 1.0) ** -1.1
    for _ in range(80):
        lm.query(pool[rng.choice(30, p=w / w.sum())])
    s = lm.stats
    print(f"semantic cache: {s.requests} reqs, "
          f"{s.served_local}/{s.requests * 4} objects served locally, "
          f"{s.generated} fresh generations, NAG={lm.nag:.3f}")


if __name__ == "__main__":
    main()
