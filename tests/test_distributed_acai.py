"""Distributed AÇAI retrieval step == single-device reference (subprocess
with 8 placeholder devices; same discipline as launch/dryrun.py)."""

import json
import os
import subprocess
import sys
import textwrap

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.distributed import make_retrieval_step, reference_step

    rng = np.random.default_rng(0)
    N, d, B, C, k, h = 512, 16, 8, 24, 4, 32
    catalog = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    y0 = jnp.full((N,), h / N, jnp.float32)
    reqs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    step = make_retrieval_step(mesh, n_shard=N // 4, d=d, c=C, k=k,
                               c_f=1.0, h=h, eta=0.05, top_a=h + 16,
                               batch_axes=("data",))
    y1, ans, metrics = jax.jit(step)(catalog, y0, reqs)
    y_ref, ans_ref = reference_step(catalog, y0, reqs, c=C, k=k, c_f=1.0,
                                    h=h, eta=0.05, top_a=h + 16)
    err = float(jnp.abs(y1 - y_ref).max())
    # answers: compare the (sorted) candidate object sets per request
    same = all(set(np.array(a).tolist()) == set(np.array(b).tolist())
               for a, b in zip(np.array(ans), np.array(ans_ref)))
    print(json.dumps({"yerr": err, "answers_match": bool(same),
                      "gain": float(metrics["gain"]),
                      "ndev": jax.device_count()}))
""")


def test_distributed_matches_reference():
    out = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, timeout=600,
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ndev"] == 8
    assert res["yerr"] < 2e-4, res
    assert res["answers_match"], res
    # uniform init y = h/N < 0.5 => thresholded cache starts empty => zero
    # gain on the first step; it must never be negative.
    assert res["gain"] >= 0
