#!/usr/bin/env bash
# Tier-1 tests + a 2-device sharded-serving smoke step, so the distributed
# path cannot silently rot on machines without accelerators.
#
#   bash scripts/smoke.sh
#
# The two --deselect lines are the known seed-failing tests (tracked in
# CHANGES.md since v0: NSW recall 0.842 < 0.85 and MLA absorbed-decode
# rel-err 0.0256 > 2e-2); everything else must pass.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
python -m pytest -x -q \
  --deselect tests/test_index.py::test_nsw_recall \
  --deselect tests/test_mla_absorbed.py::test_absorbed_decode_matches_materialized

echo "== 2-device sharded AÇAI smoke =="
XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import oma, policy, trace
from repro.core.distributed import (build_sharded_ivf, make_replay_sharded,
                                    make_retrieval_step, reference_step)

assert jax.device_count() == 2, jax.devices()
N, d, B, C, k, h = 256, 16, 4, 16, 4, 24
rng = np.random.default_rng(0)
catalog = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
y0 = jnp.full((N,), h / N, jnp.float32)
reqs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
mesh = jax.make_mesh((1, 2), ("data", "model"))

# retrieval step (full-matrix + fused chunked scan) vs reference
for chunk in (0, 32):
    step = make_retrieval_step(mesh, n_shard=N // 2, d=d, c=C, k=k, c_f=1.0,
                               h=h, eta=0.05, top_a=h + 16,
                               scan_chunk=chunk)
    y1, ans, _ = jax.jit(step)(catalog, y0, reqs)
    y_ref, ans_ref = reference_step(catalog, y0, reqs, c=C, k=k, c_f=1.0,
                                    h=h, eta=0.05, top_a=h + 16)
    assert float(jnp.abs(y1 - y_ref).max()) < 2e-4, chunk
    assert all(set(np.array(a).tolist()) == set(np.array(b).tolist())
               for a, b in zip(np.array(ans), np.array(ans_ref))), chunk

# sharded replay end-to-end (exact + sharded-IVF candidates)
cat_t, reqs_t, _ = trace.sift_like(n=N, d=d, t=64, seed=0)
cat_t, reqs_t = jnp.array(cat_t), jnp.array(reqs_t)
cfg = policy.AcaiConfig(h=h, k=k, c_f=1.0, c_remote=16, c_local=8,
                        oma=oma.OMAConfig(eta=0.05))
s0 = policy.init_state(N, cfg)
for ivf in (None, build_sharded_ivf(cat_t, 2, nlist=8, nprobe=4)):
    st, m = jax.jit(make_replay_sharded(cfg, mesh, cat_t, 8, ivf=ivf))(
        s0, reqs_t)
    assert m.gain_int.shape == (64,)
    assert abs(float(jnp.sum(st.y)) - h) < 1e-2
    assert float(jnp.sum(np.asarray(m.gain_int))) >= 0
print("2-device sharded smoke OK")
EOF
echo "smoke OK"
