"""Random-hyperplane LSH index (multi-table, dense padded buckets).

Batched-first (DESIGN.md §6): `query` takes the whole request mini-batch,
computes every table signature with one einsum, gathers the (B, tables*cap)
candidate slab in one pass, masks cross-table duplicates to the -1 invalid
sentinel, and hands the slab to the fused gather+L2+top-k scan
(`ops.ivf_scan_auto` — the same kernel the IVF probe uses), so the
(B, P, d) gathered embeddings never materialise in HBM on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import arrays_bytes
from repro.kernels import ops


class LSHIndex:
    exact_distances = True  # candidates scored with exact L2

    def __init__(self, embeddings, tables: int = 8, bits: int = 10,
                 cap: int | None = None, seed: int = 0):
        emb = np.asarray(embeddings, np.float32)
        n, d = emb.shape
        rng = np.random.default_rng(seed)
        self.planes = rng.normal(size=(tables, bits, d)).astype(np.float32)
        self.tables, self.bits = tables, bits
        nb = 2 ** bits
        sig = (np.einsum("tbd,nd->tnb", self.planes, emb) > 0)
        codes = (sig * (1 << np.arange(bits))[None, None, :]).sum(-1)  # (t, n)
        counts = np.stack([np.bincount(codes[t], minlength=nb)
                           for t in range(tables)])
        cap = int(counts.max()) if cap is None else cap
        table = np.full((tables, nb, cap), -1, np.int32)
        cursor = np.zeros((tables, nb), np.int32)
        for t in range(tables):
            for i, b in enumerate(codes[t]):
                c = cursor[t, b]
                if c < cap:
                    table[t, b, c] = i
                    cursor[t, b] = c + 1
        self.buckets = jnp.asarray(table)
        self.planes_j = jnp.asarray(self.planes)
        self.embeddings = jnp.asarray(emb)

    @property
    def n(self) -> int:
        return self.embeddings.shape[0]

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings, self.buckets, self.planes_j)

    @partial(jax.jit, static_argnames=("self", "k"))
    def query(self, q: jax.Array, k: int):
        """(B, d) -> (dists (B, k), ids (B, k)); ids = -1 on underflow."""
        q = jnp.atleast_2d(q)
        b = q.shape[0]
        sig = jnp.einsum("tbd,nd->ntb", self.planes_j, q) > 0  # (B, t, bits)
        weights = (1 << jnp.arange(self.bits, dtype=jnp.int32))
        codes = jnp.sum(sig.astype(jnp.int32) * weights[None, None, :], -1)
        cand = self.buckets[
            jnp.arange(self.tables)[None, :], codes
        ].reshape(b, -1)                                        # (B, t*cap)
        # the same object sits in multiple tables' buckets: mask repeats to
        # the fused scan's -1 invalid sentinel (first occurrence kept)
        order = jnp.argsort(cand, axis=1)
        sid = jnp.take_along_axis(cand, order, axis=1)
        dup_sorted = jnp.concatenate(
            [jnp.zeros((b, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1
        )
        dup = jnp.zeros_like(dup_sorted)
        dup = dup.at[jnp.arange(b)[:, None], order].set(dup_sorted)
        cand = jnp.where(dup, -1, cand)
        return ops.ivf_scan_auto(q, self.embeddings, cand, k)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
