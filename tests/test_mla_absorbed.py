"""MLA absorbed-decode (latent-space attention) == baseline decode."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import SMOKE_ARCHS
from repro.models import forward, init_cache, init_params


def test_absorbed_decode_matches_materialized():
    cfg = SMOKE_ARCHS["deepseek-v3-671b"]
    cfg_abs = dataclasses.replace(cfg, mla_absorbed_decode=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 4), 0, cfg.vocab)
    cache = init_cache(cfg, B, S + 8)
    pre = forward(params, cfg, tokens=toks[:, :S], cache=cache, cache_len=0)
    c0 = c1 = pre.cache
    for step in range(3):  # several decode steps: caches stay in sync
        d0 = forward(params, cfg, tokens=toks[:, S + step:S + step + 1],
                     cache=c0, cache_len=S + step)
        d1 = forward(params, cfg_abs, tokens=toks[:, S + step:S + step + 1],
                     cache=c1, cache_len=S + step)
        a, b = np.array(d0.logits[:, 0]), np.array(d1.logits[:, 0])
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 2e-2, (step, rel)
        c0, c1 = d0.cache, d1.cache
