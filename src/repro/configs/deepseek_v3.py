"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 + MTP
(arXiv:2412.19437).

61L d_model=7168 128H, MLA (q_lora 1536, kv_lora 512, rope 64, nope 128,
v 128), routed-expert d_ff=2048, 3 leading dense layers (d_ff=18432),
vocab=129280, MTP depth 1.  Adafactor: fp32-Adam state for 671B exceeds
the aggregate HBM of 512 v5e chips (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,             # leading dense layers
    vocab=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    moe_layer_start=3,
    capacity_factor=1.25,
    mtp_depth=1,
    fsdp=True,
    optimizer="adafactor",
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    attn_type="mla", q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8,
    qk_nope_dim=16, v_head_dim=16, n_experts=4, experts_per_token=2,
    n_shared_experts=1, moe_d_ff=64, moe_layer_start=1, mtp_depth=1,
    capacity_factor=0.0,  # dropless for exact decode-consistency tests
    optimizer="adafactor",
)
