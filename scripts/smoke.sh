#!/usr/bin/env bash
# Tier-1 tests + a fast all-backends index-API conformance pass + a
# mutable-catalog churn smoke + a resilient-serving smoke + an online-
# serving smoke (two arrival kinds + the fixed-window equivalence pin) +
# an answer-cache smoke (hit path + churn invalidation + on/off parity) +
# a BENCH_*.json-vs-README schema check + every example in tiny mode +
# a 2-device sharded-serving smoke step, so neither the unified index
# registry, the churn subsystem, the serving tiers, the bench schema
# docs, the runnable entry points, nor the distributed path can
# silently rot on machines without accelerators.
#
#   bash scripts/smoke.sh
#
# Opt-in (tens of minutes on CPU): SMOKE_FULL_CHURN=1 appends the
# 1M x 128 device-mutation scale check (`--suite churn --full`,
# DESIGN.md §14) and rewrites BENCH_churn_full.json.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== all-backends conformance (tiny catalog, DESIGN.md §8) =="
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import oma, policy, trace
from repro.index import Index, IndexSpec, build_index, registered_backends
from repro.index.candidates import index_candidate_fn_batched

catalog, reqs, _ = trace.sift_like(n=256, d=16, t=32, seed=0)
cat, rq = jnp.array(catalog), jnp.array(reqs)
cfg = policy.AcaiConfig(h=16, k=4, c_f=1.0, c_remote=12, c_local=8,
                        oma=oma.OMAConfig(eta=0.05))
# the canonical tiny build-kwargs table (shared with the conformance
# test): this sweep is the standalone seconds-fast re-check of the same
# contract, for runs where pytest is filtered or skipped
from repro.index.base import TINY_BUILD_KWARGS as tiny
assert set(tiny) == set(registered_backends(sharded=False)), \
    "conformance table out of date with the registry"
for backend, kw in tiny.items():
    idx = build_index(IndexSpec(backend, kw), cat)
    assert isinstance(idx, Index) and idx.n == cat.shape[0]
    d, ids = idx.query(rq[:4], 5)
    assert d.shape == (4, 5) and ids.shape == (4, 5), backend
    fnb = index_candidate_fn_batched(idx, cat, cfg.c_remote, cfg.c_local,
                                     h=cfg.h)
    st, m = policy.make_replay_batched(cfg, fnb, 8)(
        policy.init_state(cat.shape[0], cfg), rq)
    assert m.gain_int.shape == (32,) and float(jnp.sum(m.gain_int)) >= 0
    print(f"  {backend:6s} OK  (mem {idx.memory_bytes() / 1024:.0f} KiB)")
print("all-backends conformance OK")
EOF

echo "== all-policies x all-traces experiment smoke (DESIGN.md §9) =="
python - <<'EOF'
import numpy as np
from repro.core import baselines as B
from repro.core import policy_api as PA
from repro.core import trace
from repro.core.costs import CostModel

# the canonical tiny tables (shared with tests/test_policy_api.py): the
# sweep is the standalone seconds-fast re-check of the policy + trace
# registries for runs where pytest is filtered or skipped
assert set(PA.TINY_POLICY_KWARGS) == set(PA.registered_policies()), \
    "policy conformance table out of date with the registry"
assert set(trace.TINY_TRACE_KWARGS) == set(trace.registered_traces()), \
    "trace conformance table out of date with the registry"
for tname, tkw in trace.TINY_TRACE_KWARGS.items():
    catalog, reqs, _ = trace.build_trace(tname, **tkw)
    oracle = B.ServerOracle(catalog, reqs, kmax=16)
    ts = np.arange(reqs.shape[0])
    line = []
    for pname, pkw in PA.TINY_POLICY_KWARGS.items():
        pol = PA.build_policy(PA.PolicySpec(pname, pkw), catalog,
                              CostModel(c_f=1.0), oracle=oracle, seed=0)
        res = PA.replay_trace(pol, reqs, ts, batch=8)
        assert res["gain"].shape == (64,), (tname, pname)
        assert (res["occupancy"] <= pol.h + 1e-6).all(), (tname, pname)
        line.append(f"{pname}={pol.normalized_gain(res['gain'].sum(), 64):.3f}")
    print(f"  {tname:12s} NAG: " + " ".join(line))
print("all-policies x all-traces smoke OK")
EOF

echo "== mutable-catalog churn smoke (DESIGN.md §10) =="
python - <<'EOF'
import numpy as np
import jax.numpy as jnp
from repro.core import churn, oma, policy, trace
from repro.core.trace import TINY_TRACE_KWARGS

params = dict(TINY_TRACE_KWARGS["rolling_catalog"])
n0 = churn.warm_size(params["n"], params["warm"])
cfg = policy.AcaiConfig(h=16, k=4, c_f=1.0, c_remote=12, c_local=8,
                        oma=oma.OMAConfig(eta=0.05, rounding="depround"))

# churn_rate=0 must be bit-consistent with the static batched replay
p0 = dict(params, churn_rate=0.0)
catalog0, reqs0, _ = trace.build_trace("rolling_catalog", **p0)
assert trace.rolling_catalog_events(**p0) == []
cache0 = policy.AcaiCache(jnp.asarray(catalog0[:n0]), cfg, seed=0)
res0 = churn.replay_with_churn(cache0, catalog0, reqs0, [], batch=8)
st, m = policy.make_replay_batched(
    cfg, policy.exact_candidate_fn_batched(jnp.asarray(catalog0[:n0]),
                                           cfg.c_remote, cfg.c_local), 8)(
    policy.init_state(n0, cfg, seed=0), jnp.asarray(reqs0))
assert (res0["gain"] == np.asarray(m.gain_int)).all(), "churn0 != static"

# under churn: every event applies, expired objects carry zero mass
catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
events = trace.rolling_catalog_events(**params)
from repro.index import IndexSpec
import dataclasses
cfg_ivf = dataclasses.replace(cfg, index=IndexSpec("ivf", {"nlist": 8,
                                                           "nprobe": 4}))
cache = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg_ivf, seed=0)
res = churn.replay_with_churn(cache, catalog, reqs, events, batch=8,
                              refresh_every=32)
assert res["events_applied"] == len(events) > 0
removed = np.concatenate([ev[2] for ev in events])
assert float(jnp.abs(cache.state.y[jnp.asarray(removed)]).sum()) == 0.0
print(f"churn smoke OK ({len(events)} events, "
      f"NAG={res['gain'].sum() / (cfg.k * cfg.c_f * res['requests']):.3f})")
EOF

echo "== resilient-serving smoke: outage + recovery (DESIGN.md §11) =="
python - <<'EOF'
import numpy as np
from repro.core import policy_api as PA
from repro.core import trace
from repro.core.costs import CostModel
from repro.serve.remote import FaultSpec, FaultyRemote
from repro.serve.resilience import (BreakerConfig, ResilienceConfig,
                                    ResilientPolicy, replay_resilient)

catalog, reqs, _ = trace.sift_like(n=256, d=16, t=96, seed=0)
spec = PA.PolicySpec("acai", PA.TINY_POLICY_KWARGS["acai"])
cm = CostModel(c_f=1.0)

# hard outage across the middle third, recovery after (short breaker
# cooldown so the half-open probe recloses inside the 96-request trace)
rcfg = ResilienceConfig(breaker=BreakerConfig(cooldown_requests=16))
pol = ResilientPolicy(PA.build_policy(spec, catalog, cm, seed=0),
                      remote=FaultyRemote(FaultSpec(outages=((32, 64),))),
                      resilience=rcfg)
res = replay_resilient(pol, reqs, batch=8)
c = res["counters"]
assert c["remote_failures"] >= 32, c
assert c["degraded"] + c["shed"] == c["remote_failures"], c
assert res["goodput"] > 0.9, res["goodput"]          # ladder held
deg = np.asarray(res["degraded"])
assert deg[32:40].all(), "outage window not degraded"
assert not deg[:32].any(), "pre-outage requests touched the ladder"
assert pol.session.breaker.transitions >= 1          # opened on the outage
assert np.isfinite(np.asarray(pol.inner.cache.state.y)).all()
# recovery: the tail serves healthy again once the breaker recloses
assert np.asarray(res["remote_failures"])[-8:].sum() == 0, "no recovery"

# fault-rate 0 == the static replay, bit for bit
pol0 = ResilientPolicy(PA.build_policy(spec, catalog, cm, seed=0),
                       remote=FaultyRemote(FaultSpec()),
                       resilience=ResilienceConfig())
ref = PA.build_policy(spec, catalog, cm, seed=0).replay(reqs)
got = replay_resilient(pol0, reqs, batch=8)
assert np.array_equal(got["gain"], np.asarray(ref["gain"]))
print(f"resilience smoke OK ({c['remote_failures']} failures, "
      f"{c['degraded']} degraded, {c['shed']} shed, "
      f"goodput={res['goodput']:.3f}, "
      f"{pol.session.breaker.transitions} breaker transitions)")
EOF

echo "== online-serving smoke: arrivals + batch former (DESIGN.md §12) =="
python - <<'EOF'
import numpy as np
from repro.core import policy_api as PA
from repro.core import trace
from repro.core.costs import CostModel
from repro.serve.arrivals import ArrivalSpec
from repro.serve.queue import (AdmissionConfig, BatchFormerConfig,
                               fixed_window_engine, serve_trace_online)

catalog, reqs, _ = trace.sift_like(n=256, d=16, t=96, seed=0)
spec = PA.PolicySpec("acai", PA.TINY_POLICY_KWARGS["acai"])
cm = CostModel(c_f=1.0)

# two arrival kinds through the dynamic batch former + admission control
for kind in ("poisson", "closed_loop"):
    arr = (ArrivalSpec(kind="poisson", rate_rps=2500.0, seed=3)
           if kind == "poisson"
           else ArrivalSpec(kind="closed_loop", users=6, think_ms=2.0,
                            seed=3))
    pol = PA.build_policy(spec, catalog, cm, seed=0)
    res = serve_trace_online(
        pol, reqs, arr,
        former=BatchFormerConfig(max_batch=8, max_wait_ms=4.0),
        admission=AdmissionConfig(queue_cap=32), slo_ms=25.0)
    assert res["served"] + res["shed_total"] == 96, kind
    assert (res["done_ms"] >= res["arrival_ms"]).all(), kind
    print(f"  {kind:12s} p50={res['p50_ms']:.1f}ms p99={res['p99_ms']:.1f}ms "
          f"goodput={res['goodput_slo']:.3f} batch={res['mean_batch']:.2f}")

# the equivalence pin: fixed-window engine == make_replay_batched,
# bitwise, on gain AND final policy state
pol_on = PA.build_policy(spec, catalog, cm, seed=0)
pol_off = PA.build_policy(spec, catalog, cm, seed=0)
res = fixed_window_engine(pol_on, 8).run(
    reqs, ArrivalSpec(kind="poisson", rate_rps=2500.0, seed=3))
ref = pol_off.replay(reqs)
assert np.array_equal(res["gain"], np.asarray(ref["gain"])), "gain drift"
assert np.array_equal(np.asarray(pol_on.cache.state.y),
                      np.asarray(pol_off.cache.state.y)), "y drift"
assert np.array_equal(np.asarray(pol_on.cache.state.x),
                      np.asarray(pol_off.cache.state.x)), "x drift"
print("online-serving smoke OK (fixed-window pin holds)")
EOF

echo "== answer-cache smoke: hits + invalidation + parity (DESIGN.md §13) =="
python - <<'EOF'
import numpy as np
from repro.core import policy_api as PA
from repro.core import trace
from repro.core.costs import CostModel
from repro.index import IndexSpec
from repro.serve import AnswerCacheSpec

catalog, reqs, _ = trace.sift_like(n=256, d=16, t=96, zipf_a=1.1,
                                   jitter=0.0, seed=3)
spec = PA.PolicySpec("acai", dict(PA.TINY_POLICY_KWARGS["acai"], batch=8))
cm = CostModel(c_f=1.0)

# cache on vs capacity=0 pass-through: bitwise parity, scans skipped
arms = {}
for cap in (64, 0):
    pol = PA.build_policy(spec, catalog, cm, seed=0,
                          index_spec=IndexSpec("flat"),
                          answer_cache=AnswerCacheSpec(capacity=cap))
    arms[cap] = (pol, pol.replay(reqs))
(pol_on, r_on), (pol_off, r_off) = arms[64], arms[0]
assert np.array_equal(r_on["gain"], r_off["gain"]), "gain drift"
assert np.array_equal(np.asarray(pol_on.cache.state.y),
                      np.asarray(pol_off.cache.state.y)), "y drift"
st = pol_on.answer_cache.stats()
assert st["hits"] > 0, st

# the hit path: replaying a batch the store has fully memoized must
# skip the scan (a skip needs ALL rows to hit — the batch contract)
served = [np.asarray(pol.serve_update_batch(reqs[:8])[1])
          for pol, _ in arms.values()]
assert np.array_equal(*served), "hit-path answer drift"
st = pol_on.answer_cache.stats()
assert st["scans_skipped"] >= 1, st

# churn invalidation: removing a memoized id drops exactly its entries,
# and the doomed id is never served afterwards — parity still bitwise
doomed = next(iter(pol_on.answer_cache.cache._inv))
for pol, _ in arms.values():
    pol.remove_objects([doomed])
served = [np.asarray(pol.serve_update_batch(reqs[:8])[1])
          for pol, _ in arms.values()]
assert np.array_equal(*served), "post-churn answer drift"
assert doomed not in served[0], "served a removed id"
st = pol_on.answer_cache.stats()
assert st["inv_remove"] > 0, st
print(f"answer-cache smoke OK (hit_rate={st['hit_rate']:.3f}, "
      f"{st['scans_skipped']} scans skipped, "
      f"{st['inv_remove']} precise invalidations, parity bitwise)")
EOF

echo "== BENCH_*.json schema vs README =="
python scripts/check_bench_schema.py

echo "== examples (tiny mode) =="
for ex in examples/*.py; do
    echo "-- $ex --tiny"
    python "$ex" --tiny > /dev/null
done
echo "examples OK"

echo "== 2-device sharded AÇAI smoke =="
XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import oma, policy, trace
from repro.core.distributed import (make_replay_sharded,
                                    make_retrieval_step, reference_step)
from repro.index import IndexSpec, build_index

assert jax.device_count() == 2, jax.devices()
N, d, B, C, k, h = 256, 16, 4, 16, 4, 24
rng = np.random.default_rng(0)
catalog = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
y0 = jnp.full((N,), h / N, jnp.float32)
reqs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
mesh = jax.make_mesh((1, 2), ("data", "model"))

# retrieval step (full-matrix + fused chunked scan) vs reference
for chunk in (0, 32):
    step = make_retrieval_step(mesh, n_shard=N // 2, d=d, c=C, k=k, c_f=1.0,
                               h=h, eta=0.05, top_a=h + 16,
                               scan_chunk=chunk)
    y1, ans, _ = jax.jit(step)(catalog, y0, reqs)
    y_ref, ans_ref = reference_step(catalog, y0, reqs, c=C, k=k, c_f=1.0,
                                    h=h, eta=0.05, top_a=h + 16)
    assert float(jnp.abs(y1 - y_ref).max()) < 2e-4, chunk
    assert all(set(np.array(a).tolist()) == set(np.array(b).tolist())
               for a, b in zip(np.array(ans), np.array(ans_ref))), chunk

# sharded replay end-to-end (exact + registry-built sharded-IVF candidates)
cat_t, reqs_t, _ = trace.sift_like(n=N, d=d, t=64, seed=0)
cat_t, reqs_t = jnp.array(cat_t), jnp.array(reqs_t)
cfg = policy.AcaiConfig(h=h, k=k, c_f=1.0, c_remote=16, c_local=8,
                        oma=oma.OMAConfig(eta=0.05))
s0 = policy.init_state(N, cfg)
ivf = build_index(IndexSpec("ivf_sharded", {"nlist": 8, "nprobe": 4}),
                  cat_t, mesh=mesh)
for kw in ({}, {"ivf": ivf}):
    st, m = jax.jit(make_replay_sharded(cfg, mesh, cat_t, 8, **kw))(
        s0, reqs_t)
    assert m.gain_int.shape == (64,)
    assert abs(float(jnp.sum(st.y)) - h) < 1e-2
    assert float(jnp.sum(np.asarray(m.gain_int))) >= 0
print("2-device sharded smoke OK")
EOF

echo "== 2-device sharded churn smoke =="
XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
python - <<'EOF'
import numpy as np
import jax, jax.numpy as jnp
from repro.core import churn, oma, policy, trace

assert jax.device_count() == 2, jax.devices()
params = dict(trace.TINY_TRACE_KWARGS["rolling_catalog"])
catalog, reqs, _ = trace.build_trace("rolling_catalog", **params)
events = trace.rolling_catalog_events(**params)
n0 = churn.warm_size(params["n"], params["warm"])
cfg = policy.AcaiConfig(h=16, k=4, c_f=1.0, c_remote=16, c_local=8,
                        oma=oma.OMAConfig(eta=0.01, projection_topk=48))

# mutation + epoch compaction on a real 2-shard mesh (DESIGN.md §15)
pol2 = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0,
                        mesh=jax.make_mesh((1, 2), ("data", "model")))
res2 = churn.replay_with_churn(pol2, catalog, reqs, events, batch=8,
                               compact_every=24)
assert res2["events_applied"] == len(events) > 0
assert res2["compactions"] > 0
assert pol2.live_count == n0
assert pol2.catalog.shape[0] % 2 == 0          # slab stays mesh-aligned

# 1-device-mesh parity: the sharded mutable path IS the mutable path
pol1 = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0,
                        mesh=jax.make_mesh((1, 1), ("data", "model")))
res1 = churn.replay_with_churn(pol1, catalog, reqs, events, batch=8,
                               compact_every=24)
plain = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0)
resp = churn.replay_with_churn(plain, catalog, reqs, events, batch=8,
                               compact_every=24)
for k in ("gain", "served_local", "occupancy"):
    assert (res1[k] == resp[k]).all(), k
assert (np.asarray(pol1.state.y) == np.asarray(plain.state.y)).all()
nag = pol2.normalized_gain(float(res2["gain"].sum()), res2["requests"])
print(f"2-device sharded churn smoke OK (NAG={nag:.4f}, "
      f"compactions={res2['compactions']})")
EOF

if [ -n "${SMOKE_FULL_CHURN:-}" ]; then
    echo "== full-scale churn bench (1M x 128, opt-in) =="
    python -m benchmarks.run --suite churn --full
fi
echo "smoke OK"
