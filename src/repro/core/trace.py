"""Synthetic datasets + request traces matching the paper's Sec. V-A setup.

SIFT1M and the Amazon crawl are not available offline; we generate
statistically matched stand-ins:

* `sift_like`   — N points ~ U[0,1]^d (SIFT descriptors are dense,
  roughly isotropic after whitening).  Requests follow the Independent
  Reference Model with lambda_i ∝ d_i^{-beta}, d_i = distance of object i
  from the catalog barycenter — the paper's exact construction — with beta
  calibrated so the ranked-popularity tail matches Zipf(0.9).
* `amazon_like` — Gaussian-mixture clustered embeddings (product
  categories) + a non-stationary request process: a slow random walk over
  cluster preferences (temporal drift of review traffic).
* `flash_crowd` — the SIFT-like stationary base process interrupted by
  popularity shocks: during each shock window a small random object set
  captures most of the traffic (breaking-news / viral-item bursts), then
  the base popularity resumes.  Stresses how fast a policy re-learns
  after an abrupt, transient shift.
* `adversarial` — worst-case drift for LRU-style recency heuristics: the
  catalog is sliced into well-separated regions and the request process
  jumps between *maximally distant* regions in phases, so any policy
  chasing recent requests keeps paying full misses.  This is the regime
  where OMA's no-regret guarantee (Theorem IV.1) — and nothing weaker —
  still holds.
* `rolling_catalog` — the mutable-catalog workload (DESIGN.md §10):
  the catalog itself churns.  Only a warm window is live at t = 0;
  a deterministic insert+expire stream (`rolling_catalog_events`, rate =
  `churn_rate` events per request) rolls the window forward over the
  object universe, and every request targets the *currently live* set.
  At `churn_rate = 0` this degenerates to `sift_like` over the warm
  window — the static-replay consistency anchor the churn bench pins.

Every generator returns (catalog (N,d), request embeddings (T,d),
request ids (T,)).  Requests are *for catalog points* (the k=1 exact
target exists), matching the benchmark datasets where queries are
held-out points of the same distribution — we optionally jitter the
request embedding.  For `rolling_catalog` the returned catalog is the
whole object universe (live + not-yet-inserted + expired rows); the event
schedule that says *when* each row goes live/dead is a pure function of
the same params, re-derivable via `rolling_catalog_events`.

`TraceSpec` + `build_trace` mirror the index layer's `IndexSpec` +
`build_index` (DESIGN.md §8/§9): a serializable (scenario name + kwargs)
description that the experiment harness, CLI surfaces and provenance
records all share.  Generators register via `register_trace`, so adding a
scenario is a single registration.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Tuple

import numpy as np


def _zipf_calibrate_beta(dist_rank: np.ndarray, zipf_a: float = 0.9) -> float:
    """Pick beta so lambda ∝ d^{-beta} has a Zipf(a)-like ranked tail.

    Matching the log-log slope of the ranked popularity curve: if ranked
    distances grow ~ rank^gamma then lambda_(rank) ~ rank^(-beta*gamma); we
    want beta*gamma = a.

    Robust to tiny catalogs: the body window [n//100+1, n//2) degenerates
    below n ~ 200 (short or empty slice -> polyfit warnings / crashes), so
    the fit widens to every rank when the window is under 8 points, and a
    non-finite or non-positive slope falls back to gamma = 1 (beta =
    zipf_a) instead of exploding."""
    n = dist_rank.shape[0]
    if n < 2:
        return float(zipf_a)
    ranks = np.arange(1, n + 1)
    lo, hi = n // 100 + 1, n // 2  # fit the body, ignore head/tail noise
    if hi - lo < 8:  # tiny catalog: no body/tail distinction to exploit
        lo, hi = 0, n
    sel = slice(lo, hi)
    with np.errstate(all="ignore"):
        gamma = np.polyfit(np.log(ranks[sel]),
                           np.log(dist_rank[sel] + 1e-12), 1)[0]
    if not np.isfinite(gamma) or gamma <= 0:
        gamma = 1.0
    return float(zipf_a / max(gamma, 1e-3))


def _barycentric_popularity(catalog: np.ndarray, zipf_a: float) -> np.ndarray:
    """The paper's IRM construction: lambda_i ∝ d_i^{-beta}, d_i = distance
    from the catalog barycenter, beta Zipf-calibrated."""
    bary = catalog.mean(axis=0, keepdims=True)
    dist = np.linalg.norm(catalog - bary, axis=1)
    beta = _zipf_calibrate_beta(np.sort(dist), zipf_a)
    with np.errstate(all="ignore"):
        lam = (dist + 1e-9) ** (-beta)
        lam = lam / lam.sum()
    if not np.isfinite(lam).all():
        # degenerate catalogs can calibrate a huge beta and overflow the
        # power; renormalise in log space (same distribution, no overflow)
        log_lam = -beta * np.log(dist + 1e-9)
        lam = np.exp(log_lam - log_lam.max())
        lam = lam / lam.sum()
    return lam


def sift_like(
    n: int = 20000,
    d: int = 32,
    t: int = 30000,
    zipf_a: float = 0.9,
    jitter: float = 0.0,
    seed: int = 0,
):
    rng = np.random.default_rng(seed)
    catalog = rng.random((n, d), dtype=np.float32)
    lam = _barycentric_popularity(catalog, zipf_a)
    ids = rng.choice(n, size=t, p=lam)
    reqs = catalog[ids]
    if jitter > 0:
        reqs = reqs + rng.normal(0, jitter, reqs.shape).astype(np.float32)
    return catalog, reqs.astype(np.float32), ids


def amazon_like(
    n: int = 20000,
    d: int = 32,
    t: int = 30000,
    clusters: int = 50,
    drift: float = 0.05,
    seed: int = 1,
):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.0, (clusters, d)).astype(np.float32)
    assign = rng.integers(0, clusters, n)
    catalog = centers[assign] + rng.normal(0, 0.25, (n, d)).astype(np.float32)

    # non-stationary cluster preference random walk
    logits = rng.normal(0, 1.0, clusters)
    ids = np.empty(t, dtype=np.int64)
    members = [np.nonzero(assign == c)[0] for c in range(clusters)]
    members = [m if len(m) else np.array([0]) for m in members]
    # per-cluster popularity (Zipf within cluster)
    for step in range(t):
        logits += rng.normal(0, drift, clusters)
        p = np.exp(logits - logits.max())
        p /= p.sum()
        c = rng.choice(clusters, p=p)
        m = members[c]
        w = (np.arange(len(m)) + 1.0) ** -0.9
        ids[step] = m[rng.choice(len(m), p=w / w.sum())]
    return catalog, catalog[ids].copy(), ids


def flash_crowd(
    n: int = 20000,
    d: int = 32,
    t: int = 30000,
    zipf_a: float = 0.9,
    shocks: int = 4,
    shock_len: float = 0.08,
    shock_objects: int = 20,
    shock_share: float = 0.8,
    seed: int = 7,
):
    """Stationary SIFT-like base + `shocks` popularity shocks.

    Shock windows (each `shock_len` of the trace, evenly spaced) reroute
    `shock_share` of the traffic to a fresh random set of `shock_objects`
    objects with internal Zipf(1) popularity; outside the windows the base
    IRM process is untouched, so the paper's statistical-regularity
    assumption holds piecewise but not globally."""
    rng = np.random.default_rng(seed)
    catalog = rng.random((n, d), dtype=np.float32)
    lam = _barycentric_popularity(catalog, zipf_a)
    ids = rng.choice(n, size=t, p=lam)

    width = max(int(t * shock_len), 1)
    shock_objects = min(shock_objects, n)
    starts = np.linspace(0, max(t - width, 0), shocks + 2)[1:-1].astype(int)
    w = (np.arange(shock_objects) + 1.0) ** -1.0
    w /= w.sum()
    for s in starts:
        crowd = rng.choice(n, size=shock_objects, replace=False)
        hot = rng.random(width) < shock_share
        ids[s:s + width][hot] = crowd[
            rng.choice(shock_objects, size=int(hot.sum()), p=w)]
    return catalog, catalog[ids].copy(), ids


def adversarial(
    n: int = 20000,
    d: int = 32,
    t: int = 30000,
    phases: int = 8,
    phase_zipf: float = 0.6,
    separation: float = 3.0,
    seed: int = 11,
):
    """Worst-case drift: phase p concentrates every request on the region
    farthest from phase p-1's region.

    The catalog is `phases` unit cubes of equal population, placed along a
    random direction with centers `separation` apart — well beyond the
    within-cube spread, so each region's kNN answers live entirely inside
    it.  The phase order alternates between the two extremes (0, P-1, 1,
    P-2, ...): consecutive phases are near-maximally separated in
    embedding space and a recency-driven cache is always wrong about
    where the next burst lands.  Within a phase, requests are
    Zipf(`phase_zipf`) over the region (some regularity for OMA to
    exploit *inside* the phase)."""
    rng = np.random.default_rng(seed)
    catalog = rng.random((n, d), dtype=np.float32)
    u = rng.normal(size=d).astype(np.float32)
    u /= np.linalg.norm(u) + 1e-12
    slabs = np.array_split(rng.permutation(n), phases)
    for c, slab in enumerate(slabs):
        catalog[slab] += (c * separation) * u

    # alternate extremes: 0, P-1, 1, P-2, ... — consecutive phases live at
    # opposite ends of the principal direction
    seq = []
    lo_i, hi_i = 0, phases - 1
    while lo_i <= hi_i:
        seq.append(lo_i)
        if hi_i != lo_i:
            seq.append(hi_i)
        lo_i, hi_i = lo_i + 1, hi_i - 1

    ids = np.empty(t, dtype=np.int64)
    bounds = np.linspace(0, t, len(seq) + 1).astype(int)
    for p, (a, b) in zip(seq, zip(bounds[:-1], bounds[1:])):
        slab = slabs[p]
        w = (np.arange(len(slab)) + 1.0) ** (-phase_zipf)
        w /= w.sum()
        ids[a:b] = slab[rng.choice(len(slab), size=b - a, p=w)]
    return catalog, catalog[ids].copy(), ids


def rolling_catalog_events(
    n: int = 20000,
    t: int = 30000,
    churn_rate: float = 0.02,
    warm: float = 0.5,
    **_unused,
):
    """Deterministic insert/expire schedule for `rolling_catalog`.

    A pure function of (n, t, churn_rate, warm) — no RNG — so the trace
    generator, the churn replay driver, the bench suite and the tests all
    derive the *same* schedule from the same TraceSpec params (the extra
    **kwargs swallow the generator-only params like d/zipf_a/seed, so the
    full spec param dict can be splatted in).

    The live window starts as rows [0, n0), n0 = round(warm * n).  E =
    min(round(churn_rate * t), n - n0) events are spread evenly over the
    trace; event i fires *before* request step ((i + 1) * t) // (E + 1),
    inserts row n0 + i and expires row i — a rolling window of constant
    population n0.  Returns a list of (step, insert_ids (np.int32),
    remove_ids (np.int32)) with strictly increasing steps (events landing
    on the same step are merged).
    """
    n0 = max(int(round(warm * n)), 1)
    e = min(int(round(churn_rate * t)), n - n0)
    merged: "dict[int, tuple[list, list]]" = {}
    for i in range(e):
        step = ((i + 1) * t) // (e + 1)
        ins, rem = merged.setdefault(step, ([], []))
        ins.append(n0 + i)
        rem.append(i)
    return [(step, np.asarray(ins, np.int32), np.asarray(rem, np.int32))
            for step, (ins, rem) in sorted(merged.items())]


def rolling_catalog(
    n: int = 20000,
    d: int = 32,
    t: int = 30000,
    churn_rate: float = 0.02,
    warm: float = 0.5,
    zipf_a: float = 0.9,
    seed: int = 17,
):
    """Rolling-window catalog churn: requests always target the live set.

    The object universe is SIFT-like (n points ~ U[0,1]^d) with the
    paper's barycentric IRM popularity over the *whole* universe; at any
    step only the rows the `rolling_catalog_events` schedule has made live
    can be requested (popularity renormalised over the live window per
    inter-event epoch).  Content "freshness" churn with stationary
    geometry: the embedding distribution never drifts, only membership —
    isolating the index/cache-invalidation cost from distribution shift
    (amazon_like/flash_crowd cover the latter).
    """
    rng = np.random.default_rng(seed)
    catalog = rng.random((n, d), dtype=np.float32)
    lam = _barycentric_popularity(catalog, zipf_a)
    events = rolling_catalog_events(n, t, churn_rate=churn_rate, warm=warm)
    n0 = max(int(round(warm * n)), 1)
    live = np.zeros(n, bool)
    live[:n0] = True
    ids = np.empty(t, dtype=np.int64)
    prev = 0
    for step, ins, rem in events + [(t, np.empty(0, np.int32),
                                     np.empty(0, np.int32))]:
        if step > prev:
            p = np.where(live, lam, 0.0)
            p = p / p.sum()
            ids[prev:step] = rng.choice(n, size=step - prev, p=p)
        live[ins] = True
        live[rem] = False
        prev = max(prev, step)
    return catalog, catalog[ids].copy(), ids


def ranked_popularity(ids: np.ndarray, n: int) -> np.ndarray:
    counts = np.bincount(ids, minlength=n).astype(np.float64)
    return np.sort(counts)[::-1]


# ---------------------------------------------------------------------------
# TraceSpec registry (the workload twin of repro.index.base.IndexSpec)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Serializable workload selection: scenario name + generator kwargs.

    The workload twin of `IndexSpec`/`PolicySpec` (DESIGN.md §8/§9): one
    value naming a scenario and everything needed to regenerate it, so a
    benchmark row or provenance record fully determines its trace.

    `name` must be a registered scenario (`registered_traces()`; today
    ``sift_like | amazon_like | flash_crowd | adversarial |
    rolling_catalog``).  `params` are passed verbatim to the registered
    generator, so valid keys are exactly its keyword arguments — e.g.
    ``TraceSpec("flash_crowd", {"n": 4000, "shocks": 6})``.  Common
    params: ``n`` (catalog size), ``d`` (embedding dim), ``t`` (trace
    length), ``seed``, plus per-scenario knobs (``drift``, ``shocks``,
    ``phases``, ``churn_rate`` ...).

    Round-trips through a flat dict (`to_dict` / `from_dict`) with the
    name under the ``"name"`` key, so a spec can live in benchmark grids,
    CLI flags and provenance records; `with_params` derives size-reduced
    or swept variants.

    Example::

        spec = TraceSpec("rolling_catalog", {"churn_rate": 0.05})
        catalog, reqs, ids = build_trace(spec, n=2000, t=4096)
    """

    name: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))
        if "name" in self.params:
            raise ValueError("'name' is the spec field, not a param")

    def __hash__(self):
        return hash((self.name, tuple(sorted(self.params.items()))))

    def to_dict(self) -> Dict[str, Any]:
        """Flat dict form: {'name': scenario, **params}."""
        return {"name": self.name, **self.params}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TraceSpec":
        d = dict(d)
        try:
            name = d.pop("name")
        except KeyError:
            raise ValueError(f"trace spec dict needs a 'name' key: {d}")
        if name not in _TRACES:
            raise ValueError(_unknown_trace_msg(name))
        return cls(name, d)

    def with_params(self, **updates) -> "TraceSpec":
        return TraceSpec(self.name, {**self.params, **updates})


_TRACES: Dict[str, Callable] = {}


def register_trace(name: str):
    """Decorator registering `fn(**params) -> (catalog, reqs, ids)`."""

    def deco(fn: Callable) -> Callable:
        if name in _TRACES:
            raise ValueError(f"trace scenario {name!r} already registered")
        _TRACES[name] = fn
        return fn

    return deco


def registered_traces() -> Tuple[str, ...]:
    return tuple(sorted(_TRACES))


def _unknown_trace_msg(name: str) -> str:
    return (f"unknown trace scenario {name!r}; registered: "
            f"{', '.join(registered_traces())}")


def build_trace(spec, **overrides):
    """Generate the (catalog, requests, ids) a spec describes.

    Args:
      spec: a `TraceSpec`, a registered scenario name, or the flat dict
        form (``{"name": "sift_like", "n": 4000}``).
      **overrides: generator kwargs merged *over* the spec params — the
        experiment harness uses this for size reductions (n=..., t=...)
        without rewriting the spec.

    Returns:
      (catalog (N, d) float32, requests (T, d) float32, ids (T,)) — the
      object embeddings, the request stream, and each request's target
      catalog row.  Generators are deterministic in their ``seed`` param,
      so equal specs yield bitwise-equal traces.

    Raises:
      ValueError for unregistered scenario names (listing the registry).
    """
    if isinstance(spec, str):
        spec = TraceSpec(spec)
    elif isinstance(spec, Mapping):
        spec = TraceSpec.from_dict(spec)
    try:
        fn = _TRACES[spec.name]
    except KeyError:
        raise ValueError(_unknown_trace_msg(spec.name))
    return fn(**{**spec.params, **overrides})


register_trace("sift_like")(sift_like)
register_trace("amazon_like")(amazon_like)
register_trace("flash_crowd")(flash_crowd)
register_trace("adversarial")(adversarial)
register_trace("rolling_catalog")(rolling_catalog)


# Smallest sensible generator kwargs per scenario (fractions of a second
# each).  The single source of truth for the policy-conformance test
# (tests/test_policy_api.py) and the scripts/smoke.sh experiment sweep —
# a new scenario registers here once and both pick it up.
TINY_TRACE_KWARGS = {
    "sift_like": {"n": 256, "d": 16, "t": 64},
    "amazon_like": {"n": 256, "d": 16, "t": 64, "clusters": 8},
    "flash_crowd": {"n": 256, "d": 16, "t": 64, "shocks": 2,
                    "shock_objects": 8},
    "adversarial": {"n": 256, "d": 16, "t": 64, "phases": 4},
    "rolling_catalog": {"n": 256, "d": 16, "t": 64, "churn_rate": 0.1,
                        "warm": 0.5},
}
