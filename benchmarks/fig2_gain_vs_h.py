"""Fig. 2: gain vs cache size h in {50,100,200,500,1000,2000}, k=10."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    k = 10
    c_f = s.cf_table[50]
    hs = (50, 100, 200, 500, 1000, 2000) if full else (50, 100, 200, 400)
    out = {}
    for h in hs:
        m, dt = common.run_acai(s, h=h, k=k, c_f=c_f)
        acai = B.nag(m["gain"], k, c_f)[-1]
        common.emit(f"fig2/{kind}/h{h}/ACAI", dt * 1e6, f"{acai:.4f}")
        best = -1.0
        for name in ("SIM-LRU", "CLS-LRU", "QCACHE"):
            nagv, _, dtb = common.tune_baseline(s, name, h=h, k=k, c_f=c_f)
            common.emit(f"fig2/{kind}/h{h}/{name}", dtb * 1e6, f"{nagv:.4f}")
            best = max(best, nagv)
        out[h] = (acai, best)
        common.emit(f"fig2/{kind}/h{h}/improvement", 0.0,
                    f"{(acai - best) / max(best, 1e-9):+.2%}")
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
