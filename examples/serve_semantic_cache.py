"""Serve a small LM with batched requests behind the AÇAI semantic cache.

The end-to-end serving driver: a continuous-batching decode engine answers
prompts; a similarity cache in front serves repeat/near-duplicate queries
from the edge store instead of recomputing, with the fetching cost
calibrated to the cost of a generation.  Policy and index selection go
through the unified spec knobs (DESIGN.md §8/§9): `policy_spec` picks the
cache policy, `index_spec` the remote-catalog ANN backend.

  PYTHONPATH=src python examples/serve_semantic_cache.py
  PYTHONPATH=src python examples/serve_semantic_cache.py --tiny
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import SMOKE_ARCHS
from repro.core import PolicySpec
from repro.index import IndexSpec
from repro.models import init_params
from repro.serve import SemanticCachedLM, ServeEngine, generate


def main(tiny: bool = False):
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_requests, n_docs = (8, 200) if tiny else (24, 600)

    # continuous-batching engine
    engine = ServeEngine(params, cfg, batch=4, s_max=40)
    for i in range(n_requests):
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, 12), jnp.int32)
        engine.submit(i, prompt, max_tokens=6)
    t0 = time.time()
    while engine.step():
        pass
    toks = sum(len(v) for v in engine.done.values())
    print(f"engine: {len(engine.done)} requests / {toks} tokens "
          f"in {time.time() - t0:.1f}s")

    # semantic cache over a catalog of precomputed results
    catalog = jnp.asarray(rng.normal(size=(n_docs, cfg.d_model)), jnp.float32)
    catalog = catalog / jnp.linalg.norm(catalog, axis=1, keepdims=True)
    # c_f = distance of the 5th neighbour: a "close" server, where serving
    # far objects locally is NOT worth it -> misses trigger generations
    # until OMA concentrates the cache on the hot region.
    from repro.core.costs import calibrate_fetch_cost
    c_f = float(calibrate_fetch_cost(catalog, kth=5))
    lm = SemanticCachedLM(
        params, cfg, catalog, [f"result-{i}" for i in range(n_docs)],
        generate_fn=lambda p: generate(params, cfg, p[None], steps=4),
        c_f=c_f,
        # the two config knobs: swap "acai" for any registered baseline
        # (sim_lru, qcache, ...) or the IVF spec for flat/lsh/nsw/ivfpq
        policy_spec=PolicySpec("acai", {"h": 48, "k": 4}),
        index_spec=IndexSpec("ivf", {"nlist": max(n_docs // 40, 4),
                                     "nprobe": 6}))

    # zipf-repeating prompt stream: strong temporal locality => cache hits
    pool = [jnp.asarray(rng.integers(0, cfg.vocab, 12), jnp.int32)
            for _ in range(30)]
    w = (np.arange(30) + 1.0) ** -1.1
    n_queries = 16 if tiny else 80
    for _ in range(n_queries):
        lm.query(pool[rng.choice(30, p=w / w.sum())])

    # the catalog is mutable (DESIGN.md §10): admit freshly generated
    # results online and expire the oldest documents, no rebuild
    fresh = jnp.asarray(rng.normal(size=(4, cfg.d_model)), jnp.float32)
    fresh = fresh / jnp.linalg.norm(fresh, axis=1, keepdims=True)
    new_ids = lm.add_documents(fresh, [f"fresh-{i}" for i in range(4)])
    lm.remove_documents(list(range(4)))
    for _ in range(n_queries // 4):
        lm.query(pool[rng.choice(30, p=w / w.sum())])

    s = lm.stats
    print(f"semantic cache (policy={lm.policy_spec.name}, "
          f"docs +{len(new_ids)}/-4 online): {s.requests} reqs, "
          f"{s.served_local}/{s.requests * lm.k} objects served locally, "
          f"{s.generated} fresh generations, NAG={lm.nag:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-fast sizes (CI smoke)")
    main(ap.parse_args().tiny)
