"""Fig. 6: negative-entropy vs Euclidean mirror maps (h=100, k=10)."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines as B


def main(full: bool = False, kind: str = "sift") -> dict:
    s = common.get_setup(kind, **common.sizes(full))
    h, k = 100, 10
    c_f = s.cf_table[50]
    out = {}
    for mirror, etas in (
        ("negentropy", (0.01 / c_f, 0.05 / c_f, 0.2 / c_f)),
        ("euclidean", (0.1 / (c_f * h), 0.5 / (c_f * h), 2.0 / (c_f * h))),
    ):
        best, best_curve = -1.0, None
        for eta in etas:
            m, dt = common.run_acai(s, h=h, k=k, c_f=c_f, eta=eta, mirror=mirror)
            v = B.nag(m["gain"], k, c_f)[-1]
            common.emit(f"fig6/{kind}/{mirror}/eta{eta:.2e}", dt * 1e6, f"{v:.4f}")
            if v > best:
                best, best_curve = v, B.nag(m["gain"], k, c_f)
        out[mirror] = (best, best_curve)
        common.emit(f"fig6/{kind}/{mirror}/best", 0.0, f"{best:.4f}")
    # time-to-90%-of-final: the paper's "same gain in a shorter time" claim
    for mirror, (best, curve) in out.items():
        import numpy as np
        tgt = 0.9 * curve[-1]
        t90 = int(np.argmax(curve >= tgt))
        common.emit(f"fig6/{kind}/{mirror}/t90", 0.0, str(t90))
    return out


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
