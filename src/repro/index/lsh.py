"""Random-hyperplane LSH index (multi-table, dense padded buckets).

Batched-first (DESIGN.md §6): `query` takes the whole request mini-batch,
computes every table signature with one einsum, gathers the (B, tables*cap)
candidate slab in one pass, masks cross-table duplicates to the -1 invalid
sentinel, and hands the slab to the fused gather+L2+top-k scan
(`ops.ivf_scan_auto` — the same kernel the IVF probe uses), so the
(B, P, d) gathered embeddings never materialise in HBM on TPU.

Mutable catalog (DESIGN.md §10): the hyperplanes are immutable, so `add`
is exact — new rows hash into the same buckets a fresh build would use
(per-bucket capacity doubling when one fills); `remove` tombstones (stale
bucket entries masked at query time); `refresh` just rebuilds the bucket
tables over the live rows, reclaiming tombstone slots — recall is
mask-exact between refreshes, unlike the trained backends.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import (MutableRows, _flat_set, arrays_bytes,
                              check_finite_queries, pad_ids, run_device,
                              track_jit)
from repro.kernels import ops


@track_jit("lsh_query")
@partial(jax.jit, static_argnames=("k", "masked"))
def _lsh_query(q, emb, planes, buckets, valid, k: int, masked: bool):
    """(B, d) -> (dists (B, k), ids (B, k)); ids = -1 on underflow."""
    q = jnp.atleast_2d(q)
    b = q.shape[0]
    tables, bits, _ = planes.shape
    sig = jnp.einsum("tbd,nd->ntb", planes, q) > 0          # (B, t, bits)
    weights = (1 << jnp.arange(bits, dtype=jnp.int32))
    codes = jnp.sum(sig.astype(jnp.int32) * weights[None, None, :], -1)
    cand = buckets[jnp.arange(tables)[None, :], codes].reshape(b, -1)
    # the same object sits in multiple tables' buckets: mask repeats to
    # the fused scan's -1 invalid sentinel (first occurrence kept)
    order = jnp.argsort(cand, axis=1)
    sid = jnp.take_along_axis(cand, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1
    )
    dup = jnp.zeros_like(dup_sorted)
    dup = dup.at[jnp.arange(b)[:, None], order].set(dup_sorted)
    cand = jnp.where(dup, -1, cand)
    return ops.ivf_scan_auto(q, emb, cand, k, valid if masked else None)


class LSHIndex(MutableRows):
    exact_distances = True  # candidates scored with exact L2

    def __init__(self, embeddings, tables: int = 8, bits: int = 10,
                 cap: int | None = None, seed: int = 0):
        self._init_rows(embeddings)
        rng = np.random.default_rng(seed)
        d = self.embeddings.shape[1]
        self.planes = rng.normal(size=(tables, bits, d)).astype(np.float32)
        self.planes_j = jnp.asarray(self.planes)
        self.tables, self.bits = tables, bits
        self._fixed_cap = cap
        self._build_structures()

    def _codes_np(self, emb_np: np.ndarray) -> np.ndarray:
        """(n, d) -> (tables, n) bucket codes (numpy, build/insert path)."""
        sig = (np.einsum("tbd,nd->tnb", self.planes, emb_np) > 0)
        return (sig * (1 << np.arange(self.bits))[None, None, :]).sum(-1)

    def _compute_structures(self):
        """Rebuild the bucket tables over the live rows (drops tombstone
        slots; the hash itself never drifts).  Pure — serving keeps the
        stale tables until `_install_structures`."""
        live = self.live_rows()
        emb_np = np.asarray(self.embeddings)[live]
        nb = 2 ** self.bits
        codes = self._codes_np(emb_np)                       # (t, n_live)
        counts = np.stack([np.bincount(codes[t], minlength=nb)
                           for t in range(self.tables)])
        cap = (int(counts.max()) if self._fixed_cap is None
               else self._fixed_cap)
        cap = max(cap, 1)
        table = np.full((self.tables, nb, cap), -1, np.int32)
        cursor = np.zeros((self.tables, nb), np.int32)
        for t in range(self.tables):
            for i, bb in zip(live, codes[t]):
                c = cursor[t, bb]
                if c < cap:
                    table[t, bb, c] = i
                    cursor[t, bb] = c + 1
        return (jnp.asarray(table), cursor)

    def _install_structures(self, structures) -> None:
        self.buckets, self._cursor = structures

    # -- mutation -----------------------------------------------------------

    def add(self, vectors) -> np.ndarray:
        """Hash-and-append: exact LSH insertion (the planes are immutable,
        so insert-time buckets match a fresh build's).

        Device-resident fast path: the incoming batch hashes on the host
        (a (b, d) einsum), destination slots are host cursor bookkeeping,
        and all tables' entries land in the (t, nb, cap) bucket tensor via
        one donated flat scatter — no numpy master, no full re-upload.
        Overflowing a fixed user cap keeps FAISS-LSH truncation semantics:
        the overflow lane gets an out-of-range flat index and is dropped."""
        vec_np = np.asarray(vectors, np.float32)
        ids = self._append_rows(vec_np)
        codes = self._codes_np(vec_np)                       # (t, B)
        nb = 2 ** self.bits
        cap = self.buckets.shape[2]
        # a fixed user cap keeps truncation semantics; otherwise grow a
        # full bucket by doubling the shared column capacity (rare)
        if self._fixed_cap is None:
            need = int(self._cursor.max()) + len(ids)        # loose bound
            if need > cap:
                new_cap = max(2 * cap, need)
                self.buckets = jnp.pad(
                    self.buckets, ((0, 0), (0, 0), (0, new_cap - cap)),
                    constant_values=-1)
                cap = new_cap
        oob = self.tables * nb * cap
        assert oob < np.iinfo(np.int32).max, "bucket tensor exceeds int32"
        flat = np.full(self.tables * len(ids), oob, np.int64)
        vals = np.empty(flat.shape[0], np.int32)
        lane = 0
        for t in range(self.tables):
            for i, bb in zip(ids, codes[t]):
                c = self._cursor[t, bb]
                if c < cap:
                    flat[lane] = (t * nb + int(bb)) * cap + c
                    self._cursor[t, bb] = c + 1
                vals[lane] = i
                lane += 1
        self.buckets = run_device(
            _flat_set, self.buckets,
            pad_ids(flat.astype(np.int32), oob), pad_ids(vals, -1))
        return ids

    # -- queries ------------------------------------------------------------

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings, self.buckets, self.planes_j,
                            self.valid)

    def query(self, q: jax.Array, k: int):
        check_finite_queries(q, "LSHIndex.query")
        return _lsh_query(q, self.embeddings, self.planes_j, self.buckets,
                          self.valid, k, masked=self._live != self._n_slots)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
