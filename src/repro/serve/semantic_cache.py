"""Semantic cache: a similarity-caching policy as the retrieval tier in
front of LM inference.

The deployment the paper motivates (refs [3]-[6], [20], [49]): an edge
server receives prompts, embeds them, and runs a similarity search over a
catalog of previously computed results.  The policy decides per-object
whether to serve from the local store (cost = dissimilarity only) or
compute / fetch remotely (cost = dissimilarity + c_f, where c_f is
calibrated to the inference cost), and updates the local store.

The policy is one config knob (`policy_spec`, DESIGN.md §9): AÇAI by
default — the batched OMA pipeline, optionally over an approximate index
(`index_spec`) or a device mesh — or any registered baseline
(`sim_lru`, `qcache`, ...), which serves through an *online*
`ServerOracle` (exact kNN computed per mini-batch via the fused chunked
scan).  Every policy speaks the same `CachePolicy` step contract, so the
serving tier is policy-agnostic.

`embed_prompt` derives the request embedding from the LM's own token
embedding table (mean pooled + normalised) — no extra encoder needed.

The catalog is mutable (DESIGN.md §10): `add_documents` / `remove_documents`
admit freshly computed results and expire stale ones online, for any
registered policy — the rolling-catalog regime real edge deployments live
in (`launch/serve.py --churn-rate` drives it end to end).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import policy_api as policy_api
from repro.core.costs import CostModel
from repro.models.config import ModelConfig


def embed_prompt(params, tokens: jax.Array) -> jax.Array:
    """(S,) int32 -> (d,) normalised mean-pooled embedding."""
    e = params["embed"][tokens].astype(jnp.float32)
    v = jnp.mean(e, axis=0)
    return v / jnp.maximum(jnp.linalg.norm(v), 1e-6)


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    served_local: int = 0
    generated: int = 0
    total_gain: float = 0.0


class SemanticCachedLM:
    """A similarity cache wrapping a generate() callable."""

    def __init__(self, params, cfg: ModelConfig, catalog_embs: jax.Array,
                 catalog_payloads: list, generate_fn: Callable,
                 h: int = 64, k: int = 4, c_f: Optional[float] = None,
                 eta: Optional[float] = None, seed: int = 0, mesh=None,
                 index_spec=None, policy_spec=None, remote=None,
                 resilience=None, answer_cache=None):
        from repro.core.costs import calibrate_fetch_cost

        self.params, self.cfg = params, cfg
        self.payloads = list(catalog_payloads)
        self.generate_fn = generate_fn
        c_f = c_f if c_f is not None else float(
            calibrate_fetch_cost(catalog_embs, kth=min(50, len(catalog_payloads) - 1)))
        # index_spec: remote-catalog index selection (repro.index.base
        # IndexSpec; also accepts the flat-dict / backend-name forms, with
        # "exact" resolving to None) — one knob from the CLI down to the
        # candidate generator; None = exact candidates.
        from repro.index.base import resolve_spec

        index_spec = resolve_spec(index_spec)
        # policy_spec: cache-policy selection (repro.core.policy_api
        # PolicySpec; flat-dict / name forms accepted) — None = AÇAI.
        # The h/k/eta constructor args are defaults; spec params win.
        spec = policy_api.resolve_policy_spec(policy_spec)
        if spec is None:
            spec = policy_api.PolicySpec("acai")
        base = {"h": h, "k": k}
        if spec.name == "acai":
            # candidate widths follow the *effective* k (a spec k override
            # wins over the constructor default)
            k_eff = int(spec.params.get("k", k))
            base.update(c_remote=max(4 * k_eff, 16), c_local=max(k_eff, 8))
            if eta is not None:
                base["eta"] = eta
        elif eta is not None:
            raise ValueError(f"eta only applies to the 'acai' policy, not "
                             f"{spec.name!r}")
        spec = policy_api.PolicySpec(spec.name, {**base, **spec.params})
        if spec.name != "acai" and (index_spec is not None or mesh is not None
                                    or answer_cache is not None):
            raise ValueError(
                f"policy {spec.name!r} serves from the exact server oracle; "
                f"index_spec/mesh/answer_cache only apply to 'acai'")
        # mesh: shard the catalog scan + OMA over the mesh's `model` axis
        # (repro.core.distributed.make_step_sharded) — the multi-device
        # serving path; None = the single-device batched pipeline.
        # answer_cache: the exact answer-memo tier in front of the index
        # (DESIGN.md §13) — AnswerCacheSpec / dict / capacity int / None.
        self.policy = policy_api.build_policy(
            spec, catalog_embs, CostModel(c_f=c_f), index_spec=index_spec,
            mesh=mesh, seed=seed, answer_cache=answer_cache)
        # resilient serving (DESIGN.md §11): with a remote backend and/or
        # resilience config, every request first runs its remote
        # interaction (retry / hedge / deadline / breaker) and failures
        # fall down the degradation ladder — transparently, for any policy
        if remote is not None or resilience is not None:
            from repro.serve.resilience import ResilientPolicy

            self.policy = ResilientPolicy(self.policy, remote, resilience)
        # back-compat: the underlying AcaiCache (None for baselines)
        self.cache = getattr(getattr(self.policy, "inner", self.policy),
                             "cache", None)
        self.stats = ServeStats()
        self._embed_batch = jax.jit(jax.vmap(embed_prompt, in_axes=(None, 0)))

    @property
    def k(self) -> int:
        return self.policy.k

    @property
    def policy_spec(self):
        return self.policy.spec

    @property
    def answer_cache(self):
        """The answer tier's `CachedIndex` wrapper (None when off) —
        `.stats()` carries hit/invalidation/unload counts."""
        return getattr(getattr(self.policy, "inner", self.policy),
                       "answer_cache", None)

    def query(self, prompt_tokens: jax.Array):
        """Returns metrics: the k most similar cached results, each tagged
        local/remote; remote ones trigger generation."""
        r = embed_prompt(self.params, prompt_tokens)
        m = self.policy.serve_update(r)
        self.stats.requests += 1
        self.stats.served_local += int(m.served_local)
        self.stats.total_gain += float(m.gain_int)
        if int(m.served_local) < self.k:
            # at least one object must be produced/fetched remotely
            self.stats.generated += 1
            _ = self.generate_fn(prompt_tokens)
        return m

    def query_batch(self, prompts: list):
        """Batched entry point: embeds a whole request batch, runs one
        policy mini-batch step (for AÇAI a single OMA + rounding update,
        DESIGN.md §6) and triggers generation for each request not fully
        served locally.  Returns StepMetrics with a (B,) leading axis."""
        if len({p.shape[0] for p in prompts}) == 1:
            # equal-length prompts: one vmapped embed dispatch
            rs = self._embed_batch(self.params, jnp.stack(prompts))
        else:
            rs = jnp.stack([embed_prompt(self.params, p) for p in prompts])
        m = self.policy.serve_update_batch(rs)
        served = [int(s) for s in m.served_local]
        self.stats.requests += len(prompts)
        self.stats.served_local += sum(served)
        self.stats.total_gain += float(jnp.sum(jnp.asarray(m.gain_int)))
        for p, s in zip(prompts, served):
            if s < self.k:
                self.stats.generated += 1
                _ = self.generate_fn(p)
        return m

    # -- online catalog mutation (DESIGN.md §10) ----------------------------

    def add_documents(self, embeddings, payloads) -> list:
        """Admit freshly computed results online: the policy's catalog
        (and its remote index, when one is configured) learns the
        embeddings without a rebuild, and the payload table grows with
        them.  Returns the new documents' ids (stable handles for
        `remove_documents`)."""
        embeddings = jnp.atleast_2d(jnp.asarray(embeddings, jnp.float32))
        payloads = list(payloads)
        if len(payloads) != embeddings.shape[0]:
            raise ValueError(
                f"add_documents: {embeddings.shape[0]} embeddings but "
                f"{len(payloads)} payloads")
        ids = [int(i) for i in self.policy.add_objects(embeddings)]
        # ids are monotonic (never recycled): pad the payload table up to
        # the new high-water mark, then place the new payloads
        self.payloads.extend([None] * (max(ids) + 1 - len(self.payloads)))
        for i, p in zip(ids, payloads):
            self.payloads[i] = p
        return ids

    def remove_documents(self, ids) -> None:
        """Expire documents online: tombstoned in the policy (they can
        never be served again, and any cached copy is dropped at once);
        their payload slots are cleared but never reused — until an
        epoch compaction (`compact`) renumbers the table."""
        self.policy.remove_objects(ids)
        for i in ids:
            self.payloads[int(i)] = None

    def compact(self) -> None:
        """Epoch compaction (DESIGN.md §14): the policy drops tombstoned
        slab rows and renumbers the survivors; the payload table follows
        the same remap, so document handles returned before the
        compaction are invalidated (the id space restarts dense)."""
        remap = self.policy.compact()
        new = [None] * int((remap >= 0).sum())
        for old_id, new_id in enumerate(remap):
            if new_id >= 0 and old_id < len(self.payloads):
                new[int(new_id)] = self.payloads[old_id]
        self.payloads = new

    @property
    def nag(self) -> float:
        return self.policy.normalized_gain(self.stats.total_gain,
                                           self.stats.requests)
