"""Jitted public wrappers for the Pallas kernels.

Handle padding to block multiples, dtype promotion, backend dispatch
(interpret=True automatically on non-TPU backends so the same call sites
run in CI/CPU and on real hardware), and the partial-top-k merge.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ivf_scan as ivf_scan_kernel
from repro.kernels import l2 as l2_kernel
from repro.kernels import l2_topk as l2_topk_kernel
from repro.kernels import pq_adc as pq_adc_kernel
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(a: jax.Array, mult: int, value=0.0) -> jax.Array:
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=value)


@partial(jax.jit, static_argnames=("interpret",))
def pairwise_l2(q: jax.Array, x: jax.Array, *, interpret: bool | None = None):
    """Pairwise squared-L2 distances via the Pallas kernel.

    Args:
      q: (Q, D) float query embeddings (promoted to float32 inside).
      x: (N, D) float catalog embeddings.
      interpret: force Pallas interpret mode; default = auto (compiled on
        TPU, interpret elsewhere — DESIGN.md §3 backend dispatch).

    Returns:
      (Q, N) float32 squared distances, clamped at 0 (DESIGN.md §2).  Rows
      are internally padded to the (BQ, BN) = (128, 128) tile grid of
      DESIGN.md §4 and sliced back, so any Q, N are accepted.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = q.shape[0], x.shape[0]
    qp = _pad_rows(q, l2_kernel.BQ)
    xp = _pad_rows(x, l2_kernel.BN)
    out = l2_kernel.pairwise_l2_pallas(qp, xp, interpret=interp)
    return out[:qq, :n]


@partial(jax.jit, static_argnames=("interpret",))
def pq_adc(lut: jax.Array, codes: jax.Array, *, interpret: bool | None = None):
    """Product-quantization asymmetric-distance scan (ADC).

    Args:
      lut: (Q, M, C) per-query, per-subspace distance tables (float32):
        lut[q, m, c] = ||r_q^{(m)} - centroid_c^{(m)}||².
      codes: (N, M) integer PQ codes in [0, C).
      interpret: see `pairwise_l2`.

    Returns:
      (Q, N) float32 approximate distances
      dist[q, n] = Σ_m lut[q, m, codes[n, m]], computed on the MXU via the
      on-the-fly one-hot contraction of DESIGN.md §3.  Tile grid
      (BQ, BN) = (128, 128); inputs are padded/sliced automatically.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = lut.shape[0], codes.shape[0]
    lp = _pad_rows(lut, pq_adc_kernel.BQ)
    cp = _pad_rows(codes, pq_adc_kernel.BN)
    out = pq_adc_kernel.pq_adc_pallas(lp, cp, interpret=interp)
    return out[:qq, :n]


@partial(jax.jit, static_argnames=("k", "interpret"))
def topk_l2(q: jax.Array, x: jax.Array, k: int, *, valid: jax.Array | None = None,
            interpret: bool | None = None):
    """Fused blocked distance + top-k: the (Q, N) matrix never hits HBM.

    Args:
      q: (Q, D) query embeddings.
      x: (N, D) catalog embeddings.
      k: number of nearest neighbours per query (static).
      valid: optional (N,) bool tombstone mask (mutable catalog,
        DESIGN.md §10).  Masked rows never surface; queries with fewer
        than k live rows underflow as dist = +inf, id = -1.  With
        valid=None the output is bitwise the pre-mutation scan.
      interpret: see `pairwise_l2`.

    Returns:
      (dists (Q, k), ids (Q, k)): the k smallest squared distances per
      query, ascending, with int32 catalog row ids.  Each (BQ, BN) tile
      emits its k best by iterative masked-min extraction and the wrapper
      merges the (Q, nblocks·k) partials with one `lax.top_k`
      (DESIGN.md §3) — HBM traffic is Q·N·k/BN floats instead of Q·N.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    qq, n = q.shape[0], x.shape[0]
    qp = _pad_rows(q, l2_topk_kernel.BQ)
    xp = _pad_rows(x, l2_topk_kernel.BN)
    mask = None
    if valid is not None:
        # additive per-row penalty: 0 live, +inf tombstoned (padded tail
        # rows are already masked by n_valid inside the kernel)
        mask = jnp.where(_pad_rows(valid[None, :].T, l2_topk_kernel.BN,
                                   value=False).T, 0.0, jnp.inf)
    pd, pi = l2_topk_kernel.l2_topk_pallas(qp, xp, k, n_valid=n, mask=mask,
                                           interpret=interp)
    neg, pos = jax.lax.top_k(-pd, k)
    ids = jnp.take_along_axis(pi, pos, axis=1)
    if valid is not None:
        ids = jnp.where(jnp.isfinite(neg), ids, -1)
    return (-neg)[:qq], ids[:qq]


@partial(jax.jit, static_argnames=("k", "chunk"))
def topk_l2_chunked(q: jax.Array, x: jax.Array, k: int, chunk: int,
                    valid: jax.Array | None = None):
    """Chunked fused distance + top-k in pure XLA: the memory-roofline
    oracle of the Pallas `topk_l2` kernel for non-TPU backends.

    Args:
      q: (Q, D) query embeddings.
      x: (N, D) catalog embeddings (N need not divide `chunk`; the tail
        chunk is padded and masked to +inf).
      k: neighbours per query (static).
      chunk: catalog rows per scan step (static) — peak extra memory is
        O(Q · (chunk + k)) instead of O(Q · N).
      valid: optional (N,) bool tombstone mask (mutable catalog,
        DESIGN.md §10): masked rows scan as +inf, chunk by chunk, so a
        removed object can never enter the running top-k.  Queries with
        fewer than k live rows surface dist = +inf, id = -1 slots.

    Returns:
      (dists (Q, k), ids (Q, k)) exactly as `topk_l2`: ascending squared
      distances, int32 row ids.  Used by the distributed retrieval step
      (`repro.core.distributed`) and the baselines' `ServerOracle` so a
      catalog (shard) is scanned without ever materialising the (B, N)
      distance matrix.
    """
    n = x.shape[0]
    b = q.shape[0]
    xp = _pad_rows(x, chunk)
    vp = None if valid is None else _pad_rows(valid[:, None], chunk,
                                              value=False)[:, 0]
    nchunks = xp.shape[0] // chunk
    qn = jnp.sum(q * q, axis=1, keepdims=True)

    def body(carry, j):
        best_d, best_i = carry
        blk = jax.lax.dynamic_slice_in_dim(xp, j * chunk, chunk, 0)
        cn = jnp.sum(blk * blk, axis=1)[None, :]
        d2 = jnp.maximum(qn - 2.0 * q @ blk.T + cn, 0.0)
        ids = j * chunk + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        d2 = jnp.where(ids < n, d2, jnp.inf)                 # padded tail
        if vp is not None:                                   # tombstones
            vblk = jax.lax.dynamic_slice_in_dim(vp, j * chunk, chunk, 0)
            d2 = jnp.where(vblk[None, :], d2, jnp.inf)
        cat_d = jnp.concatenate([best_d, d2], axis=1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(ids, (b, chunk))], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, pos, axis=1)), None

    init = (jnp.full((b, k), jnp.inf, jnp.float32),
            jnp.zeros((b, k), jnp.int32))
    (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    if valid is not None:
        best_i = jnp.where(jnp.isfinite(best_d), best_i, -1)
    return best_d, best_i


@partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_scan_topk(q: jax.Array, x: jax.Array, cand: jax.Array, k: int, *,
                  valid: jax.Array | None = None,
                  interpret: bool | None = None):
    """Fused gather + L2 + top-k over per-query candidate id lists.

    q (B, d), x (N, d) catalog, cand (B, P) int32 with -1 = invalid slot
    (inverted-list padding, dedup sentinels).  Returns (dists (B, k),
    ids (B, k)); underflowing slots come back as dist = +inf, id = -1.

    `valid` (N,) bool is the mutable-catalog tombstone mask (DESIGN.md
    §10): candidate ids pointing at tombstoned rows are folded into the
    kernel's existing -1 invalid-slot convention *before* the scan, so a
    removed object can never surface from a stale inverted list or
    bucket — no kernel change, one extra gather + where.

    The fused kernel's per-block extraction handles k up to its tile width
    (BP = 128); larger k falls back to the XLA reference, which has no
    such limit.
    """
    if valid is not None:
        cand = jnp.where(
            (cand >= 0) & valid[jnp.clip(cand, 0, x.shape[0] - 1)], cand, -1)
    if k > ivf_scan_kernel.BP:
        return ref.ivf_scan_ref(q, x, cand, k)
    interp = (not _on_tpu()) if interpret is None else interpret
    b, p = cand.shape
    qp = _pad_rows(q, ivf_scan_kernel.BQ)
    cp = _pad_rows(cand, ivf_scan_kernel.BQ, value=-1)
    padp = (-p) % ivf_scan_kernel.BP
    if padp:
        cp = jnp.pad(cp, ((0, 0), (0, padp)), constant_values=-1)
    pd, pi = ivf_scan_kernel.ivf_scan_pallas(qp, x, cp, k, interpret=interp)
    neg, pos = jax.lax.top_k(-pd, k)
    ppos = jnp.take_along_axis(pi, pos, axis=1)          # positions in P axis
    ids = jnp.take_along_axis(cp, ppos, axis=1)
    ids = jnp.where(jnp.isfinite(neg), ids, -1)
    return (-neg)[:b], ids[:b]


# jnp fallbacks, exported for benchmarking kernel vs XLA-fused baseline.
pairwise_l2_xla = jax.jit(ref.pairwise_l2_ref)
pq_adc_xla = jax.jit(ref.pq_adc_ref)
topk_l2_xla = jax.jit(ref.l2_topk_ref, static_argnames=("k",))
ivf_scan_xla = jax.jit(ref.ivf_scan_ref, static_argnames=("k",))


def topk_l2_auto(q: jax.Array, x: jax.Array, k: int,
                 valid: jax.Array | None = None):
    """Hot-path dispatch: compiled Pallas kernel on TPU, fused XLA reference
    elsewhere (interpret-mode Pallas is a correctness harness, not a perf
    path — see kernel_bench).  `valid` is the optional tombstone mask
    (every dispatch target honors it, DESIGN.md §10)."""
    if _on_tpu():
        return topk_l2(q, x, k, valid=valid)
    return topk_l2_xla(q, x, k, valid)


def ivf_scan_auto(q: jax.Array, x: jax.Array, cand: jax.Array, k: int,
                  valid: jax.Array | None = None):
    """Hot-path dispatch for the fused IVF scan (same policy as
    topk_l2_auto).  `valid` folds tombstoned rows into the -1 invalid-slot
    convention before the scan."""
    if _on_tpu():
        return ivf_scan_topk(q, x, cand, k, valid=valid)
    return ivf_scan_xla(q, x, cand, k, valid)


def topk_l2_fused(q: jax.Array, x: jax.Array, k: int, *, chunk: int,
                  valid: jax.Array | None = None):
    """Memory-roofline dispatch: fused Pallas `topk_l2` on TPU, the chunked
    XLA oracle elsewhere — on either backend the (Q, N) distance matrix is
    never materialised.  This is the scan the distributed retrieval step
    runs per catalog shard when `scan_chunk > 0`, and (with `valid`) the
    mutable-catalog `ServerOracle` scan."""
    if _on_tpu():
        return topk_l2(q, x, k, valid=valid)
    return topk_l2_chunked(q, x, k, chunk, valid)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                   "written_upto", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    written_upto=None, interpret: bool | None = None):
    """Pallas flash attention.

    Args:
      q: (B, S, H, D) queries.
      k, v: (B, T, KV, D) keys/values (KV heads broadcast over H for GQA).
      causal: apply the causal mask (decode/prefill).
      window: sliding-window size; 0 = full attention.
      q_offset: absolute position of q[0] (static) for causal masking of
        decode steps against a longer cache.
      written_upto: ring-buffer watermark — keys at positions >= this are
        masked out (static-shape decode, DESIGN.md §5).
      interpret: see `pairwise_l2`.

    Returns:
      (B, S, H, Dv) attention output; S is internally padded to the BQ
      tile and sliced back.
    """
    from repro.kernels import flash_attention as fa

    interp = (not _on_tpu()) if interpret is None else interpret
    s = q.shape[1]
    bq = min(fa.BQ, s)
    bk = min(fa.BK, k.shape[1])
    pad = (-s) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset,
                                    written_upto=written_upto,
                                    bq=bq, bk=bk, interpret=interp)
    return out[:, :s]


def register_tracked_jits() -> None:
    """Register the module-level jits with the mutation path's compile
    tracker (repro.index.base.track_jit), so the churn no-retrace guard
    also covers the masked scans that serve over swapped slab buffers.
    Called from repro.index's package init — a lazy hook rather than a
    top-level import to keep kernels free of an index-package cycle."""
    from repro.index.base import track_jit

    for name, fn in (("ops_pairwise_l2", pairwise_l2),
                     ("ops_pq_adc", pq_adc),
                     ("ops_topk_l2", topk_l2),
                     ("ops_topk_l2_chunked", topk_l2_chunked),
                     ("ops_ivf_scan_topk", ivf_scan_topk),
                     ("ops_pairwise_l2_xla", pairwise_l2_xla),
                     ("ops_pq_adc_xla", pq_adc_xla),
                     ("ops_topk_l2_xla", topk_l2_xla),
                     ("ops_ivf_scan_xla", ivf_scan_xla)):
        track_jit(name, fn)
