"""Cost model of the paper (Sec. II / IV-A).

Objects and requests are embeddings in R^d.  The *dissimilarity cost*
c_d(r, o) is a distance in that space (squared Euclidean by default — the
metric used for both SIFT1M and the Amazon trace in Sec. V-C).  Fetching an
object from the remote server adds the *fetching cost* c_f.  All costs are
additive and mutually comparable (paper's main cost assumption).

The *augmented catalog* (Sec. IV-D) gives every object i two copies:
  - the local copy  i      with cost c(r, i)   = c_d(r, i)
  - the remote copy i + N  with cost c(r, i+N) = c_d(r, i) + c_f
with the coupling constraint x_{i+N} = 1 - x_i.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# Finite stand-in for +inf so that cost differences never produce NaNs.
BIG_COST = jnp.float32(1e9)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Dissimilarity + fetching cost specification."""

    c_f: float
    metric: str = "sqeuclidean"  # 'sqeuclidean' | 'euclidean' | 'ip'

    def dissimilarity(self, queries: jax.Array, points: jax.Array) -> jax.Array:
        return pairwise_dissimilarity(queries, points, self.metric)


def pairwise_dissimilarity(
    queries: jax.Array, points: jax.Array, metric: str = "sqeuclidean"
) -> jax.Array:
    """(Q, d) x (N, d) -> (Q, N) dissimilarity matrix.

    Computed as ||q||^2 - 2 q.x + ||x||^2 so the contraction runs on the MXU.
    A Pallas-kernelised version lives in repro.kernels.ops.pairwise_l2.
    """
    queries = jnp.atleast_2d(queries)
    dots = queries @ points.T
    if metric == "ip":
        return -dots
    qn = jnp.sum(queries * queries, axis=-1, keepdims=True)
    pn = jnp.sum(points * points, axis=-1)[None, :]
    sq = jnp.maximum(qn - 2.0 * dots + pn, 0.0)
    if metric == "euclidean":
        return jnp.sqrt(sq)
    if metric == "sqeuclidean":
        return sq
    raise ValueError(f"unknown metric {metric!r}")


@partial(jax.jit, static_argnames=("kth", "sample"))
def calibrate_fetch_cost(
    catalog: jax.Array, *, kth: int = 50, sample: int = 512, seed: int = 0
) -> jax.Array:
    """c_f := average distance of the `kth` closest neighbour in the catalog.

    Exactly the paper's Sec. V-C construction ("we set c_f equal to the
    average distance of the 50-th closest neighbor in the catalog N"),
    estimated over a random sample of catalog points.
    """
    n = catalog.shape[0]
    key = jax.random.PRNGKey(seed)
    idx = jax.random.choice(key, n, shape=(min(sample, n),), replace=False)
    d = pairwise_dissimilarity(catalog[idx], catalog)
    # kth+1 because the point itself is at distance 0.
    neg_top, _ = jax.lax.top_k(-d, kth + 1)
    return jnp.mean(-neg_top[:, kth])
