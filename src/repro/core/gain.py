"""Caching gain, service cost and subgradients (paper Sec. IV-D, App. B/C).

All functions operate on a fixed-size *candidate set* of C objects — the
union of kNN(r, local catalog) and kNN(r, remote catalog) returned by the two
approximate indexes (Sec. IV-B/C).  Objects outside the candidate set cannot
appear in the answer and have zero subgradient, so restricting the augmented
catalog U = N ∪ {N+1..2N} to the candidates is exact as long as the candidate
set contains the K^r cheapest augmented entries — guaranteed when C ≥ k
catalog candidates are supplied (their remote copies alone drive sigma to k).

Layout: every candidate i contributes two augmented entries,
    entry i       (local copy,  cost d_i,        weight y_i)
    entry i + C   (remote copy, cost d_i + c_f,  weight 1 - y_i)
mirroring the paper's x_{i+N} = 1 - x_i coupling.  Invalid candidates
(padding / duplicates across the two indexes) are passed with d_i = BIG_COST
and y_i = 0 so they sort to the tail, keep sigma-accounting consistent, and
never contribute before K^r.

Everything is pure jnp + lax and jit/vmap-friendly.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.costs import BIG_COST


class AugmentedOrder(NamedTuple):
    """Sorted augmented-entry view of a candidate set for one request."""

    costs: jax.Array      # (2C,) sorted ascending: c(r, pi_i)
    weights: jax.Array    # (2C,) y_{pi_i} (fractional) or x (integral)
    is_remote: jax.Array  # (2C,) bool
    cand_of_entry: jax.Array  # (2C,) candidate slot of each sorted entry
    lpos: jax.Array       # (C,) sorted position of candidate's local copy
    rpos: jax.Array       # (C,) sorted position of candidate's remote copy


def _augment_and_sort(d: jax.Array, y: jax.Array, c_f) -> AugmentedOrder:
    c = d.shape[-1]
    costs = jnp.concatenate([d, d + c_f])
    weights = jnp.concatenate([y, 1.0 - y])
    is_remote = jnp.concatenate(
        [jnp.zeros((c,), bool), jnp.ones((c,), bool)]
    )
    order = jnp.argsort(costs, stable=True)
    inv = jnp.argsort(order, stable=True)  # position of original entry j
    return AugmentedOrder(
        costs=costs[order],
        weights=weights[order],
        is_remote=is_remote[order],
        cand_of_entry=jnp.where(order < c, order, order - c),
        lpos=inv[:c],
        rpos=inv[c:],
    )


def gain_value(d: jax.Array, y: jax.Array, k: int, c_f) -> jax.Array:
    """Caching gain G(r, y) of Eq. (7) on a candidate set.

    d: (C,) dissimilarities (BIG_COST on invalid slots)
    y: (C,) fractional (or 0/1 integral) cache state of the candidates
    """
    a = _augment_and_sort(d, y, c_f)
    s = jnp.cumsum(a.weights)                  # S_i = sum_{j<=i} w_{pi_j}
    sig = jnp.cumsum(a.is_remote.astype(d.dtype))  # sigma_i
    alpha = a.costs[1:] - a.costs[:-1]         # alpha_i = c_{i+1} - c_i
    # terms for i = 1 .. K^r - 1  <=>  sigma_i < k (S_i >= sigma_i always).
    active = sig[:-1] < k
    inner = jnp.minimum(k - sig[:-1], s[:-1] - sig[:-1])
    return jnp.sum(jnp.where(active, alpha * inner, 0.0))


def gain_and_subgradient(
    d: jax.Array, y: jax.Array, k: int, c_f
) -> tuple[jax.Array, jax.Array]:
    """G(r, y) and a subgradient g ∈ ∂_y G(r, y)  (Eq. 55, App. C).

    The component for candidate l telescopes to
        g_l = c(r, pi_{b_l + 1}) - d_l        if lpos_l <= b_l else 0,
        b_l = min(rpos_l - 1, T),   T = max{i : S_i < k},
    i.e. the paper's (c(r, pi_{i*+1}) - c(r, l)) * 1{l* <= i*} with the
    per-component clamp 'remote copy of l not in the prefix'.
    Returns (gain, g) with g of shape (C,) aligned to the candidate slots.
    """
    a = _augment_and_sort(d, y, c_f)
    s = jnp.cumsum(a.weights)
    sig = jnp.cumsum(a.is_remote.astype(d.dtype))
    alpha = a.costs[1:] - a.costs[:-1]
    active = sig[:-1] < k
    inner = jnp.minimum(k - sig[:-1], s[:-1] - sig[:-1])
    gain = jnp.sum(jnp.where(active, alpha * inner, 0.0))

    two_c = a.costs.shape[0]
    # T: last sorted position with S < k (S nondecreasing for y in [0,1]).
    t = jnp.sum(s < k) - 1  # -1 if none
    b = jnp.minimum(a.rpos - 1, t)
    upper = jnp.take(a.costs, jnp.clip(b + 1, 0, two_c - 1))
    g = jnp.where(a.lpos <= b, upper - d, 0.0)
    g = jnp.maximum(g, 0.0)  # alpha_i >= 0 => g >= 0; guards float dust
    return gain, g


class ServeResult(NamedTuple):
    answer_ids: jax.Array    # (k,) candidate-slot indices of the answer
    from_cache: jax.Array    # (k,) bool — served locally?
    answer_costs: jax.Array  # (k,) per-object cost c(r, .)
    cost: jax.Array          # scalar C(r, x), Eq. (5)
    gain: jax.Array          # scalar G(r, x), Eq. (6)


def serve(d: jax.Array, x: jax.Array, k: int, c_f) -> ServeResult:
    """Compose the answer per Eq. (2): first k available augmented entries.

    x: (C,) integral cache indicator on the candidate slots.
    An entry is available iff weight = 1 (local copies need x_i = 1; remote
    copies are always available since x_{i+N} = 1 - x_i only gates the paper
    bookkeeping — for integral x a remote copy has weight 1 iff x_i = 0, and
    when x_i = 1 the *local* copy (strictly cheaper) precedes it, so taking
    weight-1 entries in cost order is exactly the arg-min of Eq. (2).
    """
    a = _augment_and_sort(d, x, c_f)
    w = a.weights > 0.5
    rank = jnp.cumsum(w.astype(jnp.int32))
    chosen = w & (rank <= k)
    cost = jnp.sum(jnp.where(chosen, a.costs, 0.0))

    # Gather the k chosen entries in order.
    pos = jnp.nonzero(chosen, size=k, fill_value=a.costs.shape[0] - 1)[0]
    answer_ids = a.cand_of_entry[pos]
    from_cache = ~a.is_remote[pos]
    answer_costs = a.costs[pos]

    # Empty-cache cost: k closest catalog objects, all fetched remotely.
    # The empty cache is always a feasible answer, so G(r, x) >= 0; the
    # maximum() guards the float dust of the two different summation orders.
    neg_top, _ = jax.lax.top_k(-d, k)
    empty_cost = jnp.sum(-neg_top) + k * c_f
    return ServeResult(answer_ids, from_cache, answer_costs, cost,
                       jnp.maximum(empty_cost - cost, 0.0))


def empty_cache_cost(d: jax.Array, k: int, c_f) -> jax.Array:
    """C(r, (0..0,1..1)): all k answers fetched from the server."""
    neg_top, _ = jax.lax.top_k(-d, k)
    return jnp.sum(-neg_top) + k * c_f


# vmapped conveniences over a batch of requests -----------------------------

gain_value_batch = jax.vmap(gain_value, in_axes=(0, 0, None, None))
gain_and_subgradient_batch = jax.vmap(
    gain_and_subgradient, in_axes=(0, 0, None, None)
)
serve_batch = jax.vmap(serve, in_axes=(0, 0, None, None))


@partial(jax.jit, static_argnames=("k",))
def lower_bound_l(d: jax.Array, y: jax.Array, k: int, c_f) -> jax.Array:
    """The multilinear lower bound L(r, y) of Eq. (15) (App. A).

    L(r,y) = sum_i alpha_i (k - sigma_i) (1 - prod_{j in I_i} (1 - y_pi_j/(k-sigma_i)))
    where I_i keeps local copies whose remote twin is not in the prefix.
    Used by tests to check Lemma 1:  L(y) <= G(y) <= (1-1/e)^{-1} L(y).
    """
    a = _augment_and_sort(d, y, c_f)
    two_c = a.costs.shape[0]
    sig = jnp.cumsum(a.is_remote.astype(d.dtype))
    alpha = a.costs[1:] - a.costs[:-1]
    active = sig[:-1] < k

    # I_i membership for prefix i: local entries at position p <= i whose
    # remote twin position rpos > i.  Build per-(i, entry) mask — O(C^2) but
    # this is a test helper, not the hot path.
    pos = jnp.arange(two_c)
    is_local_entry = ~a.is_remote
    rpos_of_entry = a.rpos[a.cand_of_entry]  # remote-twin position per entry
    yv = jnp.where(is_local_entry, a.weights, 0.0)

    def term(i):
        in_prefix = pos <= i
        member = in_prefix & is_local_entry & (rpos_of_entry > i)
        c = jnp.maximum(k - sig[i], 1.0)
        prod = jnp.prod(jnp.where(member, 1.0 - yv / c, 1.0))
        return (k - sig[i]) * (1.0 - prod)

    terms = jax.vmap(term)(jnp.arange(two_c - 1))
    return jnp.sum(jnp.where(active, alpha * terms, 0.0))
