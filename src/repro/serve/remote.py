"""Remote backend: the serving tier's model of the catalog server.

AÇAI's whole premise is an edge cache deciding per request between a
local answer (cost = dissimilarity) and a remote fetch (cost =
dissimilarity + c_f) — but the paper models the remote server as always
reachable at a fixed deterministic cost.  This module makes the remote
tier explicit so the serving stack (repro.serve.resilience, DESIGN.md
§11) can reason about its failure:

* `RemoteBackend` — the protocol: `outcome(t, attempt)` describes what
  happens to attempt `attempt` of request `t` (success / transient error
  / corrupt payload / outage, plus a virtual latency), and
  `fetch(rs, k, t, attempt)` performs the actual answer transfer (exact
  kNN ids + distances) when one is needed — baselines fetch server
  answers; AÇAI's indexes are local metadata, so its remote calls only
  move object payloads.
* `OracleRemote` — the healthy server: every outcome succeeds at a fixed
  latency; `fetch` answers through an optional `ServerOracle` (or any
  `(rs, k) -> (ids, d2)` callable).
* `FaultyRemote` — a deterministic fault *simulator* around any inner
  backend.  The schedule is a pure function of `(spec.seed, t, attempt)`
  via `np.random.SeedSequence`, so outcomes are order-independent,
  replayable, and identical across runs/machines; at a null `FaultSpec`
  (`fault-rate 0`) every outcome is `ok` and the resilient serving path
  is bitwise identical to the fault-free pipeline (pinned by
  tests/test_resilience.py).

Nothing here sleeps: latencies are *virtual* milliseconds consumed by
the resilience layer's simulated clock, so fault sweeps run at full
speed and p99s are exactly reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Protocol, \
    Sequence, Tuple, runtime_checkable

import numpy as np


class Outcome(NamedTuple):
    """What one attempt of one request experiences at the remote tier."""

    kind: str           # 'ok' | 'error' | 'corrupt' | 'outage'
    latency_ms: float   # virtual time until the outcome surfaces

    @property
    def ok(self) -> bool:
        return self.kind == "ok"


#: outcome kinds that count as remote failures ('corrupt' is a *delivered*
#: payload that fails validation — detected, then treated as a failure so
#: it can never poison OMA state)
FAILURE_KINDS = ("error", "corrupt", "outage")


@runtime_checkable
class RemoteBackend(Protocol):
    """The serving tier's view of the remote catalog server."""

    def outcome(self, t: int, attempt: int = 0) -> Outcome:
        """Fate of attempt `attempt` of request `t` (deterministic)."""
        ...

    def fetch(self, rs: np.ndarray, k: int, t: int = 0,
              attempt: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Transfer the server answer for queries `rs` (B, d): exact kNN
        `(ids (B, k) int32, d2 (B, k) float32)`.  A faulty backend may
        return NaN-poisoned payloads on 'corrupt' outcomes — callers must
        validate with `payload_ok` before consuming."""
        ...


def payload_ok(*arrays) -> bool:
    """Validate fetched payloads: every array finite (no NaN/Inf).  The
    detection half of the corruption failure mode — a payload failing
    this check is treated exactly like a transport error (retry /
    degrade), never handed to the policy state."""
    for a in arrays:
        if a is None:
            return False
        a = np.asarray(a)
        if np.issubdtype(a.dtype, np.floating) and not np.isfinite(a).all():
            return False
    return True


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded, deterministic fault schedule for `FaultyRemote`.

    All probabilities / latencies apply per (request, attempt) pair; the
    draw is keyed by `SeedSequence((seed, t, attempt))`, so a retry of
    the same request sees an independent (but reproducible) fate.

    * `latency_ms` / `latency_sigma` — lognormal service latency around
      `latency_ms` (sigma in log-space; 0 = constant).
    * `spike_every` / `spike_width` / `spike_ms` — periodic latency
      spikes: requests with `t % spike_every < spike_width` pay an extra
      `spike_ms` (a garbage-collection / compaction pause train).
    * `error_rate` — transient transport errors (fail fast at
      `error_latency_ms`).
    * `corrupt_rate` — delivered-but-corrupt payloads (full latency paid,
      NaN-poisoned arrays from `fetch`).
    * `outages` — hard outage windows as (start, end) request-index
      pairs: every attempt inside fails fast with kind 'outage'.
    """

    error_rate: float = 0.0
    corrupt_rate: float = 0.0
    latency_ms: float = 5.0
    latency_sigma: float = 0.0
    error_latency_ms: float = 1.0
    spike_every: int = 0
    spike_width: int = 0
    spike_ms: float = 0.0
    outages: Tuple[Tuple[int, int], ...] = ()
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.error_rate <= 1.0:
            raise ValueError(f"error_rate must be in [0, 1]: {self.error_rate}")
        if not 0.0 <= self.corrupt_rate <= 1.0:
            raise ValueError(
                f"corrupt_rate must be in [0, 1]: {self.corrupt_rate}")
        object.__setattr__(
            self, "outages",
            tuple((int(a), int(b)) for a, b in self.outages))
        for a, b in self.outages:
            if a < 0 or b <= a:
                raise ValueError(f"outage window must be 0 <= start < end: "
                                 f"({a}, {b})")

    @property
    def is_null(self) -> bool:
        """True when the schedule can never produce a failure (fault-rate
        0) — the bitwise-parity regime."""
        return (self.error_rate == 0.0 and self.corrupt_rate == 0.0
                and not self.outages)

    def in_outage(self, t: int) -> bool:
        return any(a <= t < b for a, b in self.outages)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["outages"] = [list(w) for w in self.outages]
        return d

    @classmethod
    def from_dict(cls, d) -> "FaultSpec":
        d = dict(d)
        d["outages"] = tuple(tuple(w) for w in d.get("outages", ()))
        return cls(**d)


def parse_outage_windows(specs: Sequence[str]) -> Tuple[Tuple[int, int], ...]:
    """Parse CLI outage windows ('START:END', repeatable) into the
    FaultSpec tuple form, with a clear error on malformed input."""
    out = []
    for s in specs:
        try:
            a, b = s.split(":")
            a, b = int(a), int(b)
        except ValueError:
            raise ValueError(
                f"outage window must be START:END request indices: {s!r}")
        if a < 0 or b <= a:
            raise ValueError(f"outage window needs 0 <= start < end: {s!r}")
        out.append((a, b))
    return tuple(out)


class OracleRemote:
    """The healthy remote server: every attempt succeeds at a fixed
    virtual latency; answers come from `answer_fn` — a `ServerOracle`
    (its fused `_scan`) or any `(rs, k) -> (ids, d2)` callable — when the
    caller actually needs payloads (baselines; AÇAI only needs the
    success/failure signal, its indexes being local)."""

    def __init__(self, answer_fn: Optional[Callable] = None,
                 latency_ms: float = 5.0):
        if answer_fn is not None and not callable(answer_fn):
            # a ServerOracle: route fetches through its fused scan
            oracle = answer_fn

            def answer_fn(rs, k):  # noqa: F811 — adapter closure
                ids, d2 = oracle._scan(np.atleast_2d(
                    np.asarray(rs, np.float32)))
                return ids[:, :k], d2[:, :k]

        self._answer_fn = answer_fn
        self.latency_ms = float(latency_ms)

    def outcome(self, t: int, attempt: int = 0) -> Outcome:
        return Outcome("ok", self.latency_ms)

    def fetch(self, rs: np.ndarray, k: int, t: int = 0, attempt: int = 0):
        if self._answer_fn is None:
            raise ValueError(
                "OracleRemote has no answer_fn: this backend only models "
                "fetch success/failure (AÇAI's indexes are local); pass a "
                "ServerOracle or (rs, k) -> (ids, d2) callable for payloads")
        return self._answer_fn(np.atleast_2d(np.asarray(rs, np.float32)), k)


class FaultyRemote:
    """Deterministic fault injector around an inner `RemoteBackend`.

    `outcome(t, attempt)` draws the attempt's fate from the seeded
    schedule; `fetch` delegates to the inner backend and NaN-poisons the
    distance payload when the schedule says 'corrupt' — exercising the
    full detect-and-degrade path instead of just flagging the request."""

    def __init__(self, spec: FaultSpec = FaultSpec(),
                 inner: Optional[RemoteBackend] = None):
        self.spec = spec
        self.inner = inner if inner is not None else OracleRemote(
            latency_ms=spec.latency_ms)

    def _rng(self, t: int, attempt: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence((self.spec.seed, int(t), int(attempt))))

    def outcome(self, t: int, attempt: int = 0) -> Outcome:
        s = self.spec
        if s.in_outage(t):
            return Outcome("outage", s.error_latency_ms)
        rng = self._rng(t, attempt)
        # one draw per failure mode, in fixed order, so adding a mode
        # never reshuffles the existing schedule
        u_err, u_corrupt, z = rng.random(), rng.random(), rng.normal()
        lat = s.latency_ms * (float(np.exp(s.latency_sigma * z))
                              if s.latency_sigma > 0 else 1.0)
        if s.spike_every > 0 and (t % s.spike_every) < s.spike_width:
            lat += s.spike_ms
        if u_err < s.error_rate:
            return Outcome("error", s.error_latency_ms)
        if u_corrupt < s.corrupt_rate:
            return Outcome("corrupt", lat)  # full latency paid, bad payload
        return Outcome("ok", lat)

    def fetch(self, rs: np.ndarray, k: int, t: int = 0, attempt: int = 0):
        o = self.outcome(t, attempt)
        if o.kind in ("error", "outage"):
            raise ConnectionError(
                f"remote {o.kind} on request {t} attempt {attempt}")
        ids, d2 = self.inner.fetch(rs, k, t, attempt)
        if o.kind == "corrupt":
            d2 = np.asarray(d2, np.float32).copy()
            d2[..., 0] = np.nan  # poisoned payload: callers must validate
        return ids, d2
