"""Per-arch smoke tests: reduced configs, one forward + one train step on
CPU, asserting output shapes and finiteness (assignment deliverable f)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_ARCHS, SMOKE_SHAPES
from repro.configs.shapes import ShapeSpec
from repro.models import forward, init_cache, init_params
from repro.train import OptConfig, init_train_state, make_train_step
from repro.train.batching import synthetic_batch, forward_kwargs

ARCH_NAMES = sorted(SMOKE_ARCHS)


def _shape(cfg, kind):
    b, s = 2, 32
    return ShapeSpec(kind, s, b, kind)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = SMOKE_ARCHS[arch]
    batch = synthetic_batch(cfg, _shape(cfg, "train"), seed=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    out = forward(params, cfg, **forward_kwargs(cfg, batch))
    assert out.logits.shape[0] == 2 and out.logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(out.logits).all())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = SMOKE_ARCHS[arch]
    batch = synthetic_batch(cfg, _shape(cfg, "train"), seed=1)
    params, opt_state = init_train_state(jax.random.PRNGKey(1), cfg)
    step_fn = jax.jit(make_train_step(cfg, OptConfig(name=cfg.optimizer, lr=1e-3)))
    new_params, new_opt, metrics = step_fn(params, opt_state, batch, 0)
    assert bool(jnp.isfinite(metrics.loss))
    assert bool(jnp.isfinite(metrics.grad_norm))
    # params actually moved
    delta = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mixtral-8x22b"])
def test_loss_decreases(arch):
    cfg = SMOKE_ARCHS[arch]
    batch = synthetic_batch(cfg, _shape(cfg, "train"), seed=2)
    params, opt_state = init_train_state(jax.random.PRNGKey(2), cfg)
    step_fn = jax.jit(make_train_step(cfg, OptConfig(name=cfg.optimizer, lr=3e-3)))
    losses = []
    for i in range(8):
        params, opt_state, m = step_fn(params, opt_state, batch, i)
        losses.append(float(m.loss))
    assert losses[-1] < losses[0], losses


def test_grad_accum_matches_single_batch():
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    batch = synthetic_batch(cfg, ShapeSpec("train", 16, 4, "train"), seed=3)
    params, opt_state = init_train_state(jax.random.PRNGKey(3), cfg)
    opt = OptConfig(name=cfg.optimizer, lr=1e-3)
    p1, _, m1 = jax.jit(make_train_step(cfg, opt, accum=1))(params, opt_state, batch, 0)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt, accum=2))(params, opt_state, batch, 0)
    assert float(m1.loss) == pytest.approx(float(m2.loss), rel=2e-2)
    worst = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert worst < 0.05  # bf16 accumulation noise


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_consistency(arch):
    """prefill(S) + decode(1) logits == full forward at position S."""
    cfg = SMOKE_ARCHS[arch]
    if not cfg.has_decode:
        pytest.skip("encoder-only arch has no decode step")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    if cfg.modality == "vision":
        p3 = lambda n, b=0: (b + jnp.broadcast_to(jnp.arange(n), (3, B, n))).astype(jnp.int32)
        full = forward(params, cfg, tokens=toks, positions3=p3(S + 1))
        cache = init_cache(cfg, B, S + 8)
        pre = forward(params, cfg, tokens=toks[:, :S], cache=cache,
                      cache_len=0, positions3=p3(S))
        dec = forward(params, cfg, tokens=toks[:, S:], cache=pre.cache,
                      cache_len=S, positions3=S + jnp.zeros((3, B, 1), jnp.int32))
    else:
        full = forward(params, cfg, tokens=toks)
        cache = init_cache(cfg, B, S + 8)
        pre = forward(params, cfg, tokens=toks[:, :S], cache=cache, cache_len=0)
        dec = forward(params, cfg, tokens=toks[:, S:], cache=pre.cache,
                      cache_len=S)
    a = np.array(full.logits[:, -1])
    b = np.array(dec.logits[:, 0])
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert err < 2e-2, err
