"""Subgradient (Eq. 55) correctness: finite differences + subgradient inequality."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gain as G
from repro.core import ref
from conftest import make_instance


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("k", [1, 4])
def test_matches_finite_differences(seed, k):
    rng = np.random.default_rng(seed)
    d, y, x, _, c_f = make_instance(rng, n=25, k=k)
    _, g = G.gain_and_subgradient(jnp.array(d), jnp.array(y), k, c_f)
    fd = ref.subgrad_fd(d, y, k, c_f)
    np.testing.assert_allclose(np.array(g), fd, atol=2e-2)


@pytest.mark.parametrize("seed", range(10))
def test_subgradient_inequality(seed):
    """Concavity: G(z) <= G(y) + g(y).(z - y) for all z — the defining
    property of a supergradient of a concave function."""
    rng = np.random.default_rng(100 + seed)
    d, y, x, k, c_f = make_instance(rng, n=25)
    dj, yj = jnp.array(d), jnp.array(y)
    gy, g = G.gain_and_subgradient(dj, yj, k, c_f)
    for _ in range(20):
        z = rng.random(25).astype(np.float32)
        gz = float(G.gain_value(dj, jnp.array(z), k, c_f))
        bound = float(gy) + float(jnp.dot(g, jnp.array(z) - yj))
        assert gz <= bound + 1e-3


def test_subgradient_nonnegative_and_bounded():
    """0 <= g_l <= L = c_d^k + c_f (Lemma 7)."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        d, y, x, k, c_f = make_instance(rng, n=30)
        _, g = G.gain_and_subgradient(jnp.array(d), jnp.array(y), k, c_f)
        g = np.array(g)
        assert (g >= -1e-6).all()
        c_dk = np.sort(d)[k - 1]  # dissimilarity of the k-th closest
        assert (g <= c_dk + c_f + 1e-4).all()
