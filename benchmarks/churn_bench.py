"""Mutable-catalog churn sweep (DESIGN.md §10/§14) — BENCH_churn.json
(default scale) and BENCH_churn_full.json (--full, 1M x 128).

Two questions, one suite:

* What does catalog churn cost?  `rolling_catalog` traces at increasing
  `churn_rate` (insert+expire events per request), replayed through AÇAI
  with exact candidates, AÇAI over an IVF index (stale-quantizer binning
  between refreshes), and the strongest classical baseline (SIM-LRU, via
  the online oracle).  Per row: NAG, hit ratio, p50 serving-step latency,
  `recall10_vs_live_exact` (the policy's post-churn top-10 against a
  fresh exact scan over the rows live at the schedule's end — the
  stale-structure gap, 1.0 by construction for exact/oracle cells),
  and the *separated* mutation/refresh wall time — churn overhead must
  never hide inside the serving latency.
* When does refreshing pay?  A `refresh_every` sweep at fixed churn for
  the IVF-backed cache: frequent rebuilds restore index recall (NAG up)
  but cost rebuild wall time (refresh_s up) — the amortization curve.

The churn_rate = 0 AÇAI-exact row doubles as the static-consistency
anchor: with no events the cache never leaves its static jitted path and
the replay is bit-consistent with `make_replay_batched`
(tests/test_mutable_index.py pins it; the bench asserts the cheap half).
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap
import time

import numpy as np

from benchmarks import common
from repro.core import churn, policy, trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel, calibrate_fetch_cost
from repro.core.trace import TraceSpec
from repro.index import IndexSpec

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = _ROOT / "BENCH_churn.json"
BENCH_FULL_JSON = _ROOT / "BENCH_churn_full.json"

CHURN_RATES = (0.0, 0.02, 0.1, 0.5)
FULL_CHURN_RATES = (0.0, 0.1)    # full scale: the static anchor + one churn point
REFRESH_SWEEP = (0, 1024, 256)   # requests between refreshes (0 = never)
REFRESH_CHURN = 0.1
COMPACT_EVERY = 512              # epoch-compaction cadence (requests)
WARM = 0.5
BATCH = 8
SHARDED_SHARDS = 2               # mesh width of the sharded churn cell

# Sharded churn cell (DESIGN.md §15): the same fixed-churn rolling trace
# replayed through an AcaiCache on a (1, SHARDS) mesh — mutation routed to
# owner shards, serving through the sharded exact masked scan, epoch
# compaction keeping the slab mesh-aligned.  Runs in a subprocess because
# the virtual device count must be fixed before jax initialises (and the
# parent's single-device cells must not inherit a split threadpool).  The
# config mirrors the in-process acai-exact cell (same h/k/eta defaults),
# so its NAG is directly comparable to the shards=1 rows.
_SHARDED_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count={shards} "
        + os.environ.get("XLA_FLAGS", ""))
    import json
    import time
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import churn, oma, policy, trace
    from repro.core.costs import calibrate_fetch_cost

    n, d, t, rate, warm = {n}, {d}, {t}, {rate}, {warm}
    shards, h, k, batch, compact_every = (
        {shards}, {h}, {k}, {batch}, {compact_every})
    catalog, reqs, _ = trace.build_trace(
        "rolling_catalog", n=n, d=d, t=t, churn_rate=rate, warm=warm,
        seed=17)
    events = trace.rolling_catalog_events(n=n, t=t, churn_rate=rate,
                                          warm=warm)
    n0 = churn.warm_size(n, warm)
    c_f = float(calibrate_fetch_cost(jnp.asarray(catalog[:n0]),
                                     kth=min(50, n0 - 1), sample=256))
    cfg = policy.AcaiConfig(h=h, k=k, c_f=c_f,
                            oma=oma.OMAConfig(eta=0.05 / c_f))
    mesh = jax.make_mesh((1, shards), ("data", "model"))
    pol = policy.AcaiCache(jnp.asarray(catalog[:n0]), cfg, seed=0,
                           mesh=mesh)
    t0 = time.time()
    res = churn.replay_with_churn(pol, catalog, reqs, events, batch=batch,
                                  compact_every=compact_every)
    wall = time.time() - t0
    tt = res["requests"]
    print(json.dumps({{
        "label": "acai-exact", "index": "exact", "shards": shards,
        "refresh_every": 0, "compact_every": compact_every,
        "events": res["events_applied"],
        "nag": round(pol.normalized_gain(float(res["gain"].sum()), tt), 4),
        "hit_ratio": round(float(res["hit"].mean()), 4),
        "recall10_vs_live_exact": 1.0,
        "p50_step_us": round(res["p50_step_s"] * 1e6, 1),
        "mutation_ms": round(res["mutation_s"] * 1e3, 1),
        "mutation_host_ms": round(res["mutation_host_s"] * 1e3, 1),
        "mutation_device_ms": round(res["mutation_device_s"] * 1e3, 1),
        "refresh_ms": 0.0, "refresh_stall_ms": 0.0,
        "compact_ms": round(res["compact_s"] * 1e3, 1),
        "compactions": res["compactions"],
        "us_per_request": round(wall / tt * 1e6, 2),
        "requests": tt, "ndev": jax.device_count(),
    }}))
""")


def _run_sharded_cell(n, d, t, rate, h, k, *, shards, compact_every):
    root = pathlib.Path(__file__).resolve().parent.parent
    child = _SHARDED_CHILD.format(n=n, d=d, t=t, rate=rate, warm=WARM,
                                  shards=shards, h=h, k=k, batch=BATCH,
                                  compact_every=compact_every)
    out = subprocess.run(
        [sys.executable, "-c", child], capture_output=True, text=True,
        timeout=3600,
        env={**os.environ,
             "PYTHONPATH": str(root / "src") + (
                 os.pathsep + os.environ["PYTHONPATH"]
                 if os.environ.get("PYTHONPATH") else "")})
    if out.returncode != 0:
        raise RuntimeError(f"sharded churn child (shards={shards}) "
                           f"failed:\n{out.stderr[-3000:]}")
    row = json.loads(out.stdout.strip().splitlines()[-1])
    if row.pop("ndev") != shards:
        raise RuntimeError("sharded churn child ran without its mesh")
    return row


def _policies(c_f: float, h: int, k: int, full: bool = False):
    """(label, PolicySpec, index_spec) cells of the sweep.

    At full scale (1M rows) SIM-LRU is dropped: its online ServerOracle
    runs an exact host-side scan per mini-batch, which is intractable at
    1M x 128, and the baseline comparison already lives in BENCH_churn.json
    at bench scale.  The IVF cell scales its list count with the catalog
    (sqrt-ish rule) and trims k-means iterations so a refresh is minutes,
    not hours, on CPU."""
    ivf = (IndexSpec("ivf", {"nlist": 256, "nprobe": 16, "train_iters": 4})
           if full else IndexSpec("ivf", {"nlist": 48, "nprobe": 10}))
    cells = [
        ("acai-exact", PA.PolicySpec("acai", {"h": h, "k": k}), None),
        ("acai-ivf", PA.PolicySpec("acai", {"h": h, "k": k}), ivf),
    ]
    if not full:
        cells.append(
            ("sim_lru", PA.PolicySpec("sim_lru",
                                      {"h": h, "k": k, "k_prime": 2 * k,
                                       "c_theta": 1.5 * c_f}), None))
    return tuple(cells)


RECALL_SAMPLE = 64
RECALL_R = 10


def _recall10_vs_live_exact(pol, queries) -> float:
    """Post-churn retrieval quality: the policy's top-10 against a fresh
    exact top-10 over the rows live *right now* (the end state of the
    event schedule).  Policies that retrieve exactly by construction —
    exact AÇAI candidates, oracle-served baselines — score 1.0 without a
    scan; index-backed cells measure the real stale-structure gap."""
    idx = getattr(getattr(pol, "cache", None), "index", None)
    if idx is None:
        return 1.0
    got = np.asarray(idx.query(np.asarray(queries, np.float32),
                               RECALL_R)[1])
    q = np.asarray(queries, np.float64)
    emb = np.asarray(idx.embeddings)
    live = np.asarray(idx.valid, bool)
    qn = (q ** 2).sum(1)[:, None]
    # exact top-R over the live slab, merged chunk by chunk so the
    # (sample, capacity) distance matrix never materialises at full
    # scale (1M rows x 64 queries in float64 would be half a gigabyte)
    best_d = np.full((q.shape[0], RECALL_R), np.inf)
    best_i = np.full((q.shape[0], RECALL_R), -1, np.int64)
    chunk = 262144
    for s in range(0, emb.shape[0], chunk):
        e = emb[s:s + chunk].astype(np.float64)
        d2 = qn - 2.0 * q @ e.T + (e ** 2).sum(1)[None, :]
        d2[:, ~live[s:s + chunk]] = np.inf
        r = min(RECALL_R, d2.shape[1])
        part = np.argpartition(d2, r - 1, axis=1)[:, :r]
        cand_d = np.concatenate(
            [best_d, np.take_along_axis(d2, part, 1)], axis=1)
        cand_i = np.concatenate([best_i, part + s], axis=1)
        sel = np.argsort(cand_d, axis=1, kind="stable")[:, :RECALL_R]
        best_d = np.take_along_axis(cand_d, sel, 1)
        best_i = np.take_along_axis(cand_i, sel, 1)
    overlap = [np.intersect1d(g, e[e >= 0]).size
               for g, e in zip(got, best_i)]
    return float(np.mean(overlap)) / RECALL_R


def _run_cell(label, spec, index_spec, catalog, reqs, events, cm, *,
              refresh_every=0, compact_every=0, seed=0, warm_jits=True):
    # every cell starts on the warm prefix (the live window at t = 0), so
    # rows are comparable across churn rates — at rate 0 the window just
    # never moves
    n0 = churn.warm_size(catalog.shape[0], WARM)
    if warm_jits and len(events):
        # mutation jits are bucketed by (capacity level, pow2 write
        # width) and cached process-wide, so an identical throwaway
        # replay compiles every entry the timed one will hit and the
        # timed mutation_ms columns measure steady-state cost, not
        # first-call compilation (the no-retrace test pins that the
        # second pass adds zero compiles).  Skipped at --full, where a
        # doubled 1M replay would dwarf the one-time compile cost it
        # amortizes away.
        throwaway = PA.build_policy(spec, catalog[:n0], cm,
                                    index_spec=index_spec, seed=seed)
        churn.replay_with_churn(throwaway, catalog, reqs, events,
                                batch=BATCH, refresh_every=refresh_every,
                                compact_every=compact_every)
    pol = PA.build_policy(spec, catalog[:n0], cm, index_spec=index_spec,
                          seed=seed)
    t0 = time.time()
    res = churn.replay_with_churn(pol, catalog, reqs, events, batch=BATCH,
                                  refresh_every=refresh_every,
                                  compact_every=compact_every)
    wall = time.time() - t0
    tt = res["requests"]
    return {
        "policy": spec.to_dict(), "label": label,
        "index": index_spec.to_dict() if index_spec else "exact",
        "shards": 1,
        "refresh_every": refresh_every,
        "compact_every": compact_every,
        "events": res["events_applied"],
        "nag": round(float(res["gain"].sum()) / (pol.k * pol.c_f * tt), 4),
        "hit_ratio": round(float(res["hit"].mean()), 4),
        "recall10_vs_live_exact": round(
            _recall10_vs_live_exact(pol, reqs[tt - RECALL_SAMPLE:tt]), 4),
        "p50_step_us": round(res["p50_step_s"] * 1e6, 1),
        "mutation_ms": round(res["mutation_s"] * 1e3, 1),
        "mutation_host_ms": round(res["mutation_host_s"] * 1e3, 1),
        "mutation_device_ms": round(res["mutation_device_s"] * 1e3, 1),
        "refresh_ms": round(res["refresh_s"] * 1e3, 1),
        "refresh_stall_ms": round(res["refresh_stall_s"] * 1e3, 2),
        "compact_ms": round(res["compact_s"] * 1e3, 1),
        "compactions": res["compactions"],
        "us_per_request": round(wall / tt * 1e6, 2),
        "requests": tt,
    }


def main(full: bool = False, kind: str = None) -> None:
    # `kind` exists for run.py's suite signature only: the churn sweep is
    # pinned to rolling_catalog geometry (membership churn IS the
    # variable under study), so a trace filter is rejected rather than
    # silently ignored
    if kind not in (None, "sift", "rolling_catalog"):
        raise ValueError(
            "the churn suite runs rolling_catalog only (its churn_rate is "
            "the swept knob); --trace does not apply here")
    # --full is the 1M x 128 device-mutation scale check (DESIGN.md §14):
    # does the donated-update path hold its per-event cost when the slab
    # is three orders of magnitude bigger, and does refresh amortization
    # flip once the rebuild stall leaves the serving path?  It writes a
    # separate artifact (BENCH_churn_full.json) so the default-scale
    # sweep's history stays comparable across PRs.
    n, t, d = (1_000_000, 2048, 128) if full else (2000, 2048, 16)
    h, k = (400, 10) if full else (64, 8)
    rates = FULL_CHURN_RATES if full else CHURN_RATES
    json_path = BENCH_FULL_JSON if full else BENCH_JSON
    n0 = churn.warm_size(n, WARM)
    rows = []

    import jax
    import jax.numpy as jnp

    for rate in rates:
        tspec = TraceSpec("rolling_catalog",
                          {"n": n, "d": d, "t": t, "churn_rate": rate,
                           "warm": WARM, "seed": 17})
        catalog, reqs, _ = trace.build_trace(tspec)
        events = trace.rolling_catalog_events(**tspec.params)
        c_f = float(calibrate_fetch_cost(jnp.asarray(catalog[:n0]),
                                         kth=min(50, n0 - 1), sample=256))
        cm = CostModel(c_f=c_f)
        for label, spec, ispec in _policies(c_f, h, k, full=full):
            row = _run_cell(label, spec, ispec, catalog, reqs, events, cm,
                            warm_jits=not full)
            row.update(churn_rate=rate, trace=tspec.to_dict())
            rows.append(row)
            common.emit(
                f"churn/rate{rate:g}/{label}", row["p50_step_us"],
                f"NAG={row['nag']:.4f};hit={row['hit_ratio']:.3f};"
                f"mut_ms={row['mutation_ms']:.0f};"
                f"r10={row['recall10_vs_live_exact']:.3f}")
        if rate == 0.0 and not full:
            # cheap half of the static-consistency anchor (the full
            # bitwise pin lives in tests/test_mutable_index.py): with no
            # events the exact AÇAI replay must match the batched static
            # replay's NAG to float tolerance.  Skipped at --full: it
            # would double the 1M exact replay for a property the test
            # suite already pins at bench scale.
            from repro.core import oma

            cfg = policy.AcaiConfig(h=h, k=k, c_f=c_f,
                                    oma=oma.OMAConfig(eta=0.05 / c_f))
            replay = policy.make_replay_batched(
                cfg, policy.exact_candidate_fn_batched(
                    jnp.asarray(catalog[:n0]), cfg.c_remote, cfg.c_local),
                BATCH)
            _, m = replay(policy.init_state(n0, cfg, seed=0),
                          jnp.asarray(reqs[:(t // BATCH) * BATCH]))
            nag_static = float(np.sum(np.asarray(m.gain_int))) / (
                k * c_f * ((t // BATCH) * BATCH))
            anchor = next(r for r in rows
                          if r["label"] == "acai-exact"
                          and r["churn_rate"] == 0.0)
            assert abs(round(nag_static, 4) - anchor["nag"]) < 1e-9, (
                nag_static, anchor["nag"])
            common.emit("churn/static-anchor", 0.0,
                        f"NAG={nag_static:.4f} (== rate0 acai-exact row)")

    # refresh-amortization curve: fixed churn, IVF-backed AÇAI
    tspec = TraceSpec("rolling_catalog",
                      {"n": n, "d": d, "t": t, "churn_rate": REFRESH_CHURN,
                       "warm": WARM, "seed": 17})
    catalog, reqs, _ = trace.build_trace(tspec)
    events = trace.rolling_catalog_events(**tspec.params)
    c_f = float(calibrate_fetch_cost(jnp.asarray(catalog[:n0]),
                                     kth=min(50, n0 - 1), sample=256))
    cm = CostModel(c_f=c_f)
    pcells = _policies(c_f, h, k, full=full)
    _, spec, ispec = pcells[1]                        # acai-ivf
    for every in REFRESH_SWEEP:
        row = _run_cell("acai-ivf", spec, ispec, catalog, reqs, events, cm,
                        refresh_every=every, warm_jits=not full)
        row.update(churn_rate=REFRESH_CHURN, trace=tspec.to_dict())
        rows.append(row)
        common.emit(
            f"churn/refresh{every}/acai-ivf", row["p50_step_us"],
            f"NAG={row['nag']:.4f};refresh_ms={row['refresh_ms']:.0f};"
            f"stall_ms={row['refresh_stall_ms']:.1f}")

    # epoch-compaction cells (same fixed-churn trace): tombstoned slab
    # rows are reclaimed on a cadence.  For exact candidates compaction
    # is behavior-neutral (tests pin gain/NAG parity with the
    # compaction-free row above); the columns of interest are compact_ms
    # and the smaller slab's serving latency.
    for label, spec, ispec in pcells:
        if label == "sim_lru":
            continue
        row = _run_cell(label, spec, ispec, catalog, reqs, events, cm,
                        compact_every=COMPACT_EVERY, warm_jits=not full)
        row.update(churn_rate=REFRESH_CHURN, trace=tspec.to_dict())
        rows.append(row)
        common.emit(
            f"churn/compact{COMPACT_EVERY}/{label}", row["p50_step_us"],
            f"NAG={row['nag']:.4f};compact_ms={row['compact_ms']:.0f};"
            f"compactions={row['compactions']}")

    # sharded churn cell: the same fixed-churn + compaction workload on a
    # (1, 2) mesh (subprocess, see _SHARDED_CHILD).  Skipped at --full:
    # host-emulated shards at 1M x 128 measure threadpool contention, not
    # the mesh mutation path (which the 2k-scale cell already exercises).
    if not full:
        row = _run_sharded_cell(n, d, t, REFRESH_CHURN, h, k,
                                shards=SHARDED_SHARDS,
                                compact_every=COMPACT_EVERY)
        row.update(churn_rate=REFRESH_CHURN, trace=tspec.to_dict(),
                   policy={"name": "acai", "params": {"h": h, "k": k}})
        rows.append(row)
        common.emit(
            f"churn/sharded{SHARDED_SHARDS}/acai-exact",
            row["p50_step_us"],
            f"NAG={row['nag']:.4f};compactions={row['compactions']};"
            f"mut_ms={row['mutation_ms']:.0f}")

    json_path.write_text(json.dumps(
        {"full": full, "n": n, "d": d, "t": t, "warm": WARM, "h": h, "k": k,
         "batch": BATCH, "backend": jax.default_backend(), "rows": rows},
        indent=2) + "\n")
    common.emit("churn/json", 0.0, str(json_path.name))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="1M x 128 scale check -> BENCH_churn_full.json "
                         "(tens of minutes on CPU)")
    main(ap.parse_args().full)
