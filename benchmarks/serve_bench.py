"""Serving-engine throughput microbenchmark (CPU, smoke-size model):
continuous batching tokens/s, semantic-cache hit economics, and the
batched AÇAI request pipeline (B ∈ {1, 8, 64}, exact vs IVF candidates —
written to BENCH_pipeline.json so the perf trajectory is tracked across
PRs)."""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.configs import SMOKE_ARCHS
from repro.models import init_params
from repro.serve import SemanticCachedLM, ServeEngine, generate

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_pipeline.json"


def main(full: bool = False, kind: str = "sift") -> None:
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req, budget = (64, 16) if full else (16, 6)

    engine = ServeEngine(params, cfg, batch=4, s_max=48)
    for i in range(n_req):
        engine.submit(i, jnp.asarray(rng.integers(0, cfg.vocab, 12),
                                     jnp.int32), budget)
    t0 = time.time()
    steps = 0
    while engine.step():
        steps += 1
    dt = time.time() - t0
    toks = sum(len(v) for v in engine.done.values())
    common.emit("serve/engine/tokens_per_s", dt / max(toks, 1) * 1e6,
                f"{toks / dt:.1f}")
    common.emit("serve/engine/steps", 0.0, str(steps))

    catalog = jnp.asarray(rng.normal(size=(400, cfg.d_model)), jnp.float32)
    catalog = catalog / jnp.linalg.norm(catalog, axis=1, keepdims=True)
    lm = SemanticCachedLM(
        params, cfg, catalog, [str(i) for i in range(400)],
        generate_fn=lambda p: generate(params, cfg, p[None], steps=2),
        h=40, k=4)
    pool = [jnp.asarray(rng.integers(0, cfg.vocab, 12), jnp.int32)
            for _ in range(20)]
    w = (np.arange(20) + 1.0) ** -1.1
    t0 = time.time()
    for _ in range(n_req * 2):
        lm.query(pool[rng.choice(20, p=w / w.sum())])
    dt = (time.time() - t0) / (n_req * 2)
    s = lm.stats
    common.emit("serve/semantic_cache/NAG", dt * 1e6, f"{lm.nag:.3f}")
    common.emit("serve/semantic_cache/local_share", 0.0,
                f"{s.served_local / (s.requests * 4):.2f}")

    # batched semantic-cache path: same request mix, B=8 mini-batches.
    # Fresh cache so NAG@B8 measures only the batched path (no dilution by
    # the sequential phase above).
    lm_b = SemanticCachedLM(
        params, cfg, catalog, [str(i) for i in range(400)],
        generate_fn=lambda p: generate(params, cfg, p[None], steps=2),
        h=40, k=4)
    t0 = time.time()
    for _ in range(n_req // 2):
        lm_b.query_batch([pool[i] for i in rng.choice(20, size=8, p=w / w.sum())])
    dt_b = (time.time() - t0) / (n_req // 2 * 8)
    common.emit("serve/semantic_cache/NAG@B8", dt_b * 1e6, f"{lm_b.nag:.3f}")


def pipeline_main(full: bool = False, kind: str = "sift") -> None:
    """Batched AÇAI replay throughput: requests/sec and µs/request for
    B ∈ {1, 8, 64}, exact vs IVF candidate generation, NAG alongside so
    speed is never reported without quality.  Results land in
    BENCH_pipeline.json at the repo root."""
    from repro.core import oma, policy, trace
    from repro.core.costs import calibrate_fetch_cost
    from repro.index import IVFFlatIndex
    from repro.index.candidates import index_candidate_fn_batched

    n, t, d = (20000, 16384, 32) if full else (2000, 2048, 16)
    gen = trace.sift_like if kind == "sift" else trace.amazon_like
    catalog, reqs, _ = gen(n=n, d=d, t=t, seed=0)
    cat, reqs_j = jnp.array(catalog), jnp.array(reqs)
    c_f = float(calibrate_fetch_cost(cat, kth=min(50, n - 1), sample=256))
    cfg = policy.AcaiConfig(h=64, k=8, c_f=c_f, c_remote=32, c_local=16,
                            oma=oma.OMAConfig(eta=0.05 / c_f))

    index = IVFFlatIndex(cat, nlist=48, nprobe=10)
    fns = {
        "exact": policy.exact_candidate_fn_batched(cat, cfg.c_remote, cfg.c_local),
        "ivf": index_candidate_fn_batched(index, cat, cfg.c_remote, cfg.c_local,
                                          h=cfg.h),
    }
    rows = []
    for cand_name, fnb in fns.items():
        for b in (1, 8, 64):
            replay = policy.make_replay_batched(cfg, fnb, b)
            state = policy.init_state(n, cfg)
            tt = (t // b) * b
            r = reqs_j[:tt]
            _, m = replay(state, r)                       # compile + warmup
            m.gain_int.block_until_ready()
            t0 = time.time()
            _, m = replay(state, r)
            m.gain_int.block_until_ready()
            dt = time.time() - t0
            nag = float(np.sum(np.asarray(m.gain_int))) / (cfg.k * c_f * tt)
            rows.append({
                "batch": b, "candidates": cand_name,
                "requests_per_s": round(tt / dt, 1),
                "us_per_request": round(dt / tt * 1e6, 2),
                "nag": round(nag, 4), "requests": tt,
            })
            common.emit(f"pipeline/{kind}/{cand_name}/B{b}", dt / tt * 1e6,
                        f"NAG={nag:.4f};rps={tt / dt:.0f}")
    BENCH_JSON.write_text(json.dumps(
        {"kind": kind, "full": full, "n": n, "d": d,
         "backend": jax.default_backend(), "rows": rows}, indent=2) + "\n")
    common.emit("pipeline/json", 0.0, str(BENCH_JSON.name))


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
    pipeline_main(args.full, args.trace)
