"""Answer-cache tier sweep (DESIGN.md §13) — BENCH_answer_cache.json.

Hit-rate / latency / NAG across three trace scenarios — Zipf repeats
(`sift_like`, jitter 0 so repeated requests are exact catalog rows),
`flash_crowd` shocks, and `rolling_catalog` churn with live
insert/expire events — with the answer cache on
(`AnswerCacheSpec(capacity=CAP)`) vs off (`capacity=0`, the documented
pass-through arm: identical serving code, memoization bypassed).

Built-in checks, every run (the tier's contract, not a tuning claim):

* **NAG-neutrality / bitwise parity** — per scenario × index backend,
  the cache-on arm must match the cache-off arm bitwise: per-request
  gain, final policy state (y, x), and the served ids coming out of the
  index tier (recorded at `CachedIndex.query`).  The answer cache is a
  latency tier, never a quality knob.
* **Hot-fraction speedup** — on the Zipf trace the repeated-query hot
  path must be ≥5× faster at p50 than the fused scan, asserted both on
  measured wall time (memoized lookup vs full scan, same batch) and on
  the engine's deterministic virtual clock (p50_miss_ms / p50_hit_ms).
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common
from repro.core import churn, trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel, calibrate_fetch_cost
from repro.index.base import IndexSpec
from repro.serve.answer_cache import AnswerCacheSpec
from repro.serve.arrivals import ArrivalSpec
from repro.serve.queue import BatchFormerConfig, OnlineServingEngine, \
    ServiceModel

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_answer_cache.json"

BATCH = 8
ANSWER_CAP = 4096      # on-arm entry budget (≥ unique queries at bench size)
ZIPF_A = 1.1           # head-heavy repeats: the regime the tier targets
CHURN_RATE = 0.05
CHURN_WARM = 0.5
ARRIVAL_SEED = 11
WALL_REPS = 30         # hot-path wall microbench repetitions
MIN_SPEEDUP = 5.0      # acceptance floor, asserted every run


def _indexes(full: bool):
    """Index backends per scenario: flat (exact scan, precise
    invalidation) everywhere, IVF (probed scan, precise radius/inverted
    invalidation) on the scenarios where quantizer structure matters."""
    ivf = IndexSpec("ivf", {"nlist": 128 if full else 48,
                            "nprobe": 16 if full else 10})
    return {
        "zipf": (IndexSpec("flat"), ivf),
        "flash_crowd": (IndexSpec("flat"),),
        "rolling_catalog": (IndexSpec("flat"), ivf),
    }


def _build(catalog, cm, h, k, index_spec, cap, seed=0):
    spec = PA.PolicySpec("acai", {"h": h, "k": k, "batch": BATCH})
    return PA.build_policy(spec, catalog, cm, index_spec=index_spec,
                           seed=seed,
                           answer_cache=AnswerCacheSpec(capacity=cap))


def _record_served_ids(pol, sink: list):
    """Tap `CachedIndex.query` so every batch's served ids land in
    `sink` — the parity assert compares these across the on/off arms
    (the mutable candidate fn resolves `index.query` per call, so an
    instance attribute shadowing the method is enough)."""
    idx = pol.cache.index
    orig = idx.query

    def wrapped(rs, kk):
        d, ids = orig(rs, kk)
        sink.append(np.asarray(ids))
        return d, ids

    idx.query = wrapped


def _scenario_traces(full: bool, n, d, t):
    cat_z, req_z, _ = trace.sift_like(n=n, d=d, t=t, zipf_a=ZIPF_A,
                                      jitter=0.0, seed=17)
    cat_f, req_f, _ = trace.flash_crowd(n=n, d=d, t=t, seed=7)
    cat_r, req_r, _ = trace.rolling_catalog(n=n, d=d, t=t,
                                            churn_rate=CHURN_RATE,
                                            warm=CHURN_WARM, seed=17)
    events = trace.rolling_catalog_events(n=n, t=t, churn_rate=CHURN_RATE,
                                          warm=CHURN_WARM)
    return {
        "zipf": (cat_z, req_z, ()),
        "flash_crowd": (cat_f, req_f, ()),
        "rolling_catalog": (cat_r, req_r, events),
    }


def _run_cell(scenario, index_spec, cap, catalog, reqs, events, cm, h, k):
    """One (scenario × index × cache-arm) replay.  Returns (row, gain
    array, final state, served-ids array) — the latter three feed the
    parity asserts."""
    n_warm = churn.warm_size(catalog.shape[0], CHURN_WARM)
    live_cat = catalog[:n_warm] if events else catalog
    pol = _build(live_cat, cm, h, k, index_spec, cap)
    served: list = []
    _record_served_ids(pol, served)
    t0 = time.time()
    if events:
        res = churn.replay_with_churn(pol, catalog, reqs, events,
                                      batch=BATCH)
    else:
        res = pol.replay(reqs)
    wall = time.time() - t0
    gain = np.asarray(res["gain"], np.float64)
    t_served = int(res["requests"])
    st = pol.answer_cache.stats()
    row = {
        "scenario": scenario,
        "index": index_spec.to_dict(),
        "cache": "on" if cap else "off",
        "capacity": cap,
        "nag": round(pol.normalized_gain(float(gain.sum()), t_served), 4),
        "hit_ratio": round(float(np.asarray(res["hit"]).mean()), 4),
        "answer_hit_rate": round(st["hit_rate"], 4),
        "entries": st["entries"],
        "invalidations": st["invalidations"],
        "inv_remove": st["inv_remove"],
        "inv_add": st["inv_add"],
        "inv_refresh": st["inv_refresh"],
        "scans": st["scans"],
        "scans_skipped": st["scans_skipped"],
        "events": len(events),
        "p50_step_us": round(res["p50_step_s"] * 1e6, 1),
        "us_per_request": round(wall / max(t_served, 1) * 1e6, 2),
        "requests": t_served,
    }
    ids = (np.concatenate([s.reshape(-1) for s in served])
           if served else np.zeros(0, np.int32))
    return row, gain, pol.cache.state, ids


def _assert_parity(scenario, index_spec, on, off):
    """NAG-neutrality, bitwise: gain, state (y, x), served ids."""
    (row_on, g_on, st_on, ids_on) = on
    (row_off, g_off, st_off, ids_off) = off
    where = f"{scenario}/{index_spec.backend}"
    assert np.array_equal(g_on, g_off), (
        f"answer cache changed per-request gain on {where}")
    assert row_on["nag"] == row_off["nag"], (
        f"answer cache changed NAG on {where}: "
        f"{row_on['nag']} != {row_off['nag']}")
    for f in ("y", "x"):
        assert np.array_equal(np.asarray(getattr(st_on, f)),
                              np.asarray(getattr(st_off, f))), (
            f"answer cache changed policy state .{f} on {where}")
    assert np.array_equal(ids_on, ids_off), (
        f"answer cache changed served ids on {where}")


def _hot_wall_microbench(catalog, reqs, cm, h, k):
    """Measured-wall hot-path claim: p50 of a memoized all-hit batch vs
    the same batch through the pass-through (full fused scan) arm."""
    import jax

    pol_on = _build(catalog, cm, h, k, IndexSpec("flat"), ANSWER_CAP)
    pol_off = _build(catalog, cm, h, k, IndexSpec("flat"), 0)
    c_remote = pol_on.cache.cfg.c_remote
    # the hot fraction: the trace's most repeated request rows
    uniq, counts = np.unique(reqs, axis=0, return_counts=True)
    hot = uniq[np.argsort(-counts)[:BATCH]]
    if hot.shape[0] < BATCH:  # pad by repeating the hottest row
        hot = np.concatenate(
            [hot, np.repeat(hot[:1], BATCH - hot.shape[0], axis=0)])
    walls = {"on": [], "off": []}
    for arm, pol in (("on", pol_on), ("off", pol_off)):
        pol.cache.index.query(hot, c_remote)  # warm: store + compile
        for _ in range(WALL_REPS):
            t0 = time.time()
            jax.block_until_ready(pol.cache.index.query(hot, c_remote))
            walls[arm].append(time.time() - t0)
    p50_on = float(np.percentile(walls["on"], 50))
    p50_off = float(np.percentile(walls["off"], 50))
    assert pol_on.answer_cache.stats()["scans_skipped"] >= WALL_REPS, (
        "hot microbench batches were not served from the store")
    speedup = p50_off / max(p50_on, 1e-9)
    assert speedup >= MIN_SPEEDUP, (
        f"hot-path wall speedup {speedup:.1f}x below the {MIN_SPEEDUP}x "
        f"acceptance floor (hit p50 {p50_on * 1e6:.1f}us vs scan p50 "
        f"{p50_off * 1e6:.1f}us)")
    return {"hot_p50_us_on": round(p50_on * 1e6, 2),
            "hot_p50_us_off": round(p50_off * 1e6, 2),
            "speedup_wall": round(speedup, 2),
            "reps": WALL_REPS}


def _engine_cell(catalog, reqs, cm, h, k, cap):
    """Online-engine arm (DESIGN.md §12 + §13 fast path): virtual-clock
    latency decomposition with answer-cache hits completing at arrival."""
    service = ServiceModel()
    pol = _build(catalog, cm, h, k, IndexSpec("flat"), cap)
    eng = OnlineServingEngine(
        pol, former=BatchFormerConfig(max_batch=BATCH, max_wait_ms=5.0),
        service=service)
    arrival = ArrivalSpec(kind="poisson",
                          rate_rps=0.8 * service.capacity_rps(BATCH),
                          seed=ARRIVAL_SEED)
    res = eng.run(reqs, arrival)
    return {
        "cache": "on" if cap else "off",
        "answer_hit_rate": round(res["answer_hit_rate"], 4),
        "p50_user_ms": round(res["p50_user_ms"], 3),
        "p99_user_ms": round(res["p99_user_ms"], 3),
        "p50_hit_ms": round(res["p50_hit_ms"], 3),
        "p50_miss_ms": round(res["p50_miss_ms"], 3),
        "p50_ms": round(res["p50_ms"], 3),
    }, np.asarray(res["gain"], np.float64)


def main(full: bool = False, kind: str = None) -> None:
    if kind not in (None, "sift"):
        raise ValueError(
            "the answer_cache suite sweeps its own three scenarios "
            "(zipf / flash_crowd / rolling_catalog); --trace does not "
            "apply here")
    n, t, d = (20000, 8192, 32) if full else (2000, 2048, 16)
    h, k = (400, 10) if full else (64, 8)

    import jax
    import jax.numpy as jnp

    scen = _scenario_traces(full, n, d, t)
    cat_z = scen["zipf"][0]
    c_f = float(calibrate_fetch_cost(jnp.asarray(cat_z),
                                     kth=min(50, n - 1), sample=256))
    cm = CostModel(c_f=c_f)

    rows = []
    for scenario, (catalog, reqs, events) in scen.items():
        for index_spec in _indexes(full)[scenario]:
            arms = {}
            for cap in (ANSWER_CAP, 0):
                cell = _run_cell(scenario, index_spec, cap, catalog, reqs,
                                 events, cm, h, k)
                arms[cap] = cell
                rows.append(cell[0])
                common.emit(
                    f"answer_cache/{scenario}/{index_spec.backend}/"
                    f"{'on' if cap else 'off'}",
                    cell[0]["p50_step_us"],
                    f"nag={cell[0]['nag']:.3f};"
                    f"hit={cell[0]['answer_hit_rate']:.3f};"
                    f"inval={cell[0]['invalidations']}")
            _assert_parity(scenario, index_spec, arms[ANSWER_CAP], arms[0])
    common.emit("answer_cache/parity-pin", 0.0,
                "cache-on == cache-off (gain, NAG, y, x, served ids), "
                "every scenario x index")

    hot = _hot_wall_microbench(cat_z, scen["zipf"][1], cm, h, k)
    common.emit("answer_cache/hot-path", hot["hot_p50_us_on"],
                f"scan={hot['hot_p50_us_off']}us;"
                f"speedup={hot['speedup_wall']}x")

    engine = {}
    gains = {}
    for cap in (ANSWER_CAP, 0):
        cell, g = _engine_cell(cat_z, scen["zipf"][1], cm, h, k, cap)
        engine[cell["cache"]] = cell
        gains[cell["cache"]] = g
    assert np.array_equal(gains["on"], gains["off"]), (
        "engine fast path changed per-request gain (the learn-path batch "
        "partition must be identical across arms)")
    virt = engine["on"]
    speedup_virtual = virt["p50_miss_ms"] / max(virt["p50_hit_ms"], 1e-9)
    assert speedup_virtual >= MIN_SPEEDUP, (
        f"virtual hot-path speedup {speedup_virtual:.1f}x below "
        f"{MIN_SPEEDUP}x (hit p50 {virt['p50_hit_ms']}ms vs miss p50 "
        f"{virt['p50_miss_ms']}ms)")
    engine["speedup_virtual"] = round(speedup_virtual, 2)
    common.emit("answer_cache/engine", virt["p50_user_ms"],
                f"hit={virt['answer_hit_rate']:.3f};"
                f"p50_hit={virt['p50_hit_ms']}ms;"
                f"p50_miss={virt['p50_miss_ms']}ms;"
                f"speedup={engine['speedup_virtual']}x")

    BENCH_JSON.write_text(json.dumps(
        {"full": full, "n": n, "d": d, "t": t, "h": h, "k": k,
         "batch": BATCH, "c_f": round(c_f, 6),
         "answer_capacity": ANSWER_CAP, "zipf_a": ZIPF_A,
         "churn_rate": CHURN_RATE, "churn_warm": CHURN_WARM,
         "arrival_seed": ARRIVAL_SEED,
         "backend": jax.default_backend(),
         "parity_pin": True, "min_speedup": MIN_SPEEDUP,
         "hot_latency": hot, "engine": engine,
         "rows": rows}, indent=2) + "\n")
    common.emit("answer_cache/json", 0.0, str(BENCH_JSON.name))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(args.full)
