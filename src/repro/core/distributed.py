"""Distributed AÇAI: the paper's retrieval/caching step at pod scale.

At production scale the catalog (10^8 x d embeddings) and the fractional
cache state y live SHARDED over the `model` mesh axis; the request batch is
data-parallel.  One serve+update step per request batch:

  1. every chip scans its catalog shard with the fused distance+top-k
     kernel (Pallas `topk_l2` on TPU, the chunked XLA oracle elsewhere, or
     the sharded-IVF probe) and takes a local top-C
                                          -> compute-bound, no comms
  2. all-gather of per-shard top-C over `model` (tiny: C ids+dists/request)
     and a top-C re-merge               -> the only quadratic-free exchange
  3. per-request gain/subgradient on the merged candidates (Eq. 55)
  4. subgradients routed to the owning y-shards via all_gather over `data`
     + local mask (candidate traffic: B x C pairs, bytes not catalog-sized)
  5. OMA multiplicative update + DISTRIBUTED capped-simplex projection:
     per-shard top-A + per-shard tail sums are all-gathered (A x shards
     scalars), the exact global water-filling scale is solved locally and
     applied shard-wise — the O(N log N) sort of Sec. IV-F becomes
     O(N/P log A) + an O(A.P) scalar exchange.

The serve answer (global ids of the k cheapest augmented copies) comes out
of the same merged candidate set.  `make_retrieval_step` is the
paper-representative roofline cell (`acai-retrieval`) lowered by the
dry-run; `make_replay_sharded` is the serving-stack twin of
`repro.core.policy.make_replay_batched` — same mini-batch OMA semantics,
state carried as (y, x, t, key), bit-consistent with the batched replay on
a 1-device mesh (see DESIGN.md §7).

All shard_map usage goes through `repro.compat` so the module lowers on
every supported jax version.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import gain as gain_lib
from repro.core import mirror as mirror_maps
from repro.core import oma as oma_lib
from repro.core import policy as policy_lib
from repro.core.costs import BIG_COST, pairwise_dissimilarity
from repro.core.projection import _negentropy_scale_from_sorted
from repro.kernels import ops


def _axis_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for ax in ([axes] if isinstance(axes, str) else axes):
        total *= sizes[ax]
    return total


# ---------------------------------------------------------------------------
# Sharded IVF: per-shard coarse quantizer + inverted lists (local row ids)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedIVF:
    """Per-shard IVF structures, stacked along the (sharded) row axis.

    centroids: (P * nlist, d)  — shard p owns rows [p*nlist, (p+1)*nlist)
    invlists:  (P * nlist, cap) int32 — ids are LOCAL row offsets into the
               owning catalog shard, -1 padded
    """

    centroids: jax.Array
    invlists: jax.Array
    nlist: int
    nprobe: int

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


def build_sharded_ivf(catalog, n_shards: int, *, nlist: int = 32,
                      nprobe: int = 8, train_iters: int = 12,
                      seed: int = 0) -> ShardedIVF:
    """Train one IVF coarse quantizer per catalog shard.

    Each shard gets its own k-means over its rows — exactly what a chip
    would do at scale (the invlist table shards row-wise, DESIGN.md §4) —
    so the sharded retrieval step probes only shard-local lists.
    """
    from repro.index.ivf import build_invlists
    from repro.index.kmeans import kmeans

    catalog = jnp.asarray(catalog, jnp.float32)
    n = catalog.shape[0]
    assert n % n_shards == 0, (n, n_shards)
    n_shard = n // n_shards
    cents, tables = [], []
    for p in range(n_shards):
        shard = catalog[p * n_shard:(p + 1) * n_shard]
        key = jax.random.PRNGKey(seed + p)
        c, assign = kmeans(key, shard, nlist, train_iters)
        cents.append(np.asarray(c))
        tables.append(build_invlists(np.asarray(assign), nlist))
    cap = max(t.shape[1] for t in tables)
    tables = [np.pad(t, ((0, 0), (0, cap - t.shape[1])), constant_values=-1)
              for t in tables]
    return ShardedIVF(
        centroids=jnp.asarray(np.concatenate(cents, 0), jnp.float32),
        invlists=jnp.asarray(np.concatenate(tables, 0), jnp.int32),
        nlist=nlist, nprobe=nprobe)


def _check_ivf_matches_mesh(ivf: "ShardedIVF | None", n_model: int) -> None:
    """A ShardedIVF built for P shards only makes sense on a P-way model
    axis: the P(model, None) in_spec would otherwise silently hand each
    mesh shard centroid/invlist rows belonging to a different catalog
    sub-shard (local row ids reinterpreted against the wrong shard — wrong
    candidates, no shape error)."""
    if ivf is None:
        return
    built_for = ivf.centroids.shape[0] // ivf.nlist
    if built_for != n_model:
        raise ValueError(
            f"ShardedIVF was built for {built_for} shards "
            f"(centroids {ivf.centroids.shape}, nlist {ivf.nlist}) but the "
            f"mesh's model axis has {n_model} devices — rebuild with "
            f"build_sharded_ivf(catalog, {n_model}, ...)")


def _local_scan(requests, catalog, c: int, scan_chunk: int, ivf_shard):
    """Per-shard local top-c scan: (dists (b, c), local ids (b, c)).

    Three variants (DESIGN.md §7): paper-faithful full matrix
    (scan_chunk = 0, ivf = None), the fused kernel path (`ops.topk_l2_fused`
    — Pallas on TPU, chunked XLA oracle elsewhere), and the sharded-IVF
    probe that scans only this shard's probed inverted lists.  Underflowing
    slots (IVF only) come back as dist = +inf, id = -1.
    """
    if ivf_shard is not None:
        centroids, invlists, nprobe = ivf_shard
        dc = pairwise_dissimilarity(requests, centroids)
        _, probe = jax.lax.top_k(-dc, nprobe)                # (b, nprobe)
        cand = invlists[probe].reshape(requests.shape[0], -1)
        return ops.ivf_scan_auto(requests, catalog, cand, c)
    if scan_chunk:
        return ops.topk_l2_fused(requests, catalog, c, chunk=scan_chunk)
    d2 = pairwise_dissimilarity(requests, catalog)
    neg, ids = jax.lax.top_k(-d2, c)
    return -neg, ids


def _merge_topc(d_loc, ids_loc, miss, count: int, off, n: int, model_axis):
    """All-gather each shard's local top candidates over `model` and
    re-top-k to the global top-`count` (step 2 of the module docstring).

    `miss` marks invalid local slots (IVF underflow): they become
    (dist = +inf, id = n) and sort to the tail.  Returns (dists (b, count)
    with +inf on unfilled slots, global ids (b, count))."""
    gids = jnp.where(miss, n, ids_loc + off)
    dd = jnp.where(miss, jnp.inf, d_loc)
    all_d = jax.lax.all_gather(dd, model_axis, axis=1, tiled=True)
    all_i = jax.lax.all_gather(gids, model_axis, axis=1, tiled=True)
    negm, pos = jax.lax.top_k(-all_d, count)
    return -negm, jnp.take_along_axis(all_i, pos, axis=1)


def _route_subgradients(g_cand, ids, valid, off, n_shard: int, batch_axes,
                        denom: float = 1.0):
    """All-gather per-request candidate subgradients over the batch axes
    and scatter-add the slots this shard owns into its (n_shard,) slice
    (step 4 of the module docstring).  `valid` (optional) additionally
    masks invalid candidate slots; `denom` is the mini-batch averaging
    divisor."""
    g_all = jax.lax.all_gather(g_cand, batch_axes, axis=0, tiled=True)
    ids_all = jax.lax.all_gather(ids, batch_axes, axis=0, tiled=True)
    mine = (ids_all >= off) & (ids_all < off + n_shard)
    if valid is not None:
        mine &= jax.lax.all_gather(valid, batch_axes, axis=0, tiled=True)
    lidx = jnp.clip(ids_all - off, 0, n_shard - 1)
    val = jnp.where(mine, g_all, 0.0).reshape(-1)
    if denom != 1.0:
        val = val / denom
    return jnp.zeros((n_shard,), g_cand.dtype).at[lidx.reshape(-1)].add(val)


def _gather_sharded(vec_shard, gids, my_shard, n_shard, model_axis):
    """Look up sharded (N,) state at global ids: masked local gather +
    psum over `model`.  Out-of-range ids (>= N, the invalid sentinel)
    return 0."""
    local = (gids >= my_shard * n_shard) & (gids < (my_shard + 1) * n_shard)
    safe = jnp.clip(gids - my_shard * n_shard, 0, n_shard - 1)
    return jax.lax.psum(jnp.where(local, vec_shard[safe], 0.0), model_axis)


def _distributed_projection(z, h, top_a: int, n_model: int, model_axis):
    """Distributed negentropy Bregman projection (Sec. IV-F water-filling).

    Per shard: top-A heads + exact tail sum (scatter-zero, no total-minus-
    top cancellation).  Exchange: the (P·A,) heads all-gather + one scalar
    psum.  The global scale s is then solved redundantly on every shard
    from the same sorted head array — bitwise identical across shards — and
    applied locally.  At P = 1 this IS `capped_simplex_negentropy_topk`.
    """
    z = jnp.maximum(z, 0.0)
    ztop, idx = jax.lax.top_k(z, top_a)
    tail = jnp.sum(z.at[idx].set(0.0))
    heads = jax.lax.all_gather(ztop, model_axis, tiled=True)   # (P*A,)
    tails = jax.lax.psum(tail, model_axis)
    if n_model > 1:
        heads = jnp.sort(heads)[::-1]
    s, _ = _negentropy_scale_from_sorted(heads, tails, h)
    return jnp.minimum(1.0, z * s)


# ---------------------------------------------------------------------------
# The roofline cell: stateless retrieval + OMA step on thresholded y
# ---------------------------------------------------------------------------

def make_retrieval_step(mesh, *, n_shard: int, d: int, c: int, k: int,
                        c_f: float, h: int, eta: float, top_a: int,
                        batch_axes=("data",), model_axis: str = "model",
                        scan_chunk: int = 0, ivf: ShardedIVF | None = None):
    """Returns step(catalog_shard, y, requests) -> (y_new, answer, metrics)
    wrapped in shard_map over `mesh`.

    catalog: (N, d) sharded P(model, None);  y: (N,) sharded P(model);
    requests: (B, d) sharded P(batch_axes, None).
    scan_chunk > 0 routes the local scan through the fused kernels
    (`ops.topk_l2_fused`: Pallas l2_topk on TPU, chunked XLA oracle
    elsewhere — memory-roofline optimization; 0 = paper-faithful full
    matrix).  `ivf` switches each shard to probing only its own inverted
    lists (`ops.ivf_scan_topk` / oracle) — the approximate-index serving
    configuration of Sec. IV-B at pod scale.

    The answer is the (B, k) global object ids of the k cheapest augmented
    copies per request (Eq. 2 on the merged candidates); -1 marks answer
    slots a starved IVF probe could not fill with a real candidate.
    """
    n_model = _axis_size(mesh, model_axis)
    n = n_shard * n_model
    _check_ivf_matches_mesh(ivf, n_model)

    def step(catalog, y, requests, *ivf_args):
        # ---- 1. local distance scan + top-C (per shard) -----------------
        ivf_shard = (ivf_args[0], ivf_args[1], ivf.nprobe) if ivf else None
        loc_d, loc_ids = _local_scan(requests, catalog, c, scan_chunk,
                                     ivf_shard)
        my_shard = jax.lax.axis_index(model_axis)

        # ---- 2. merge shards' candidates over `model` --------------------
        cand_d, cand_ids = _merge_topc(loc_d, loc_ids, loc_ids < 0, c,
                                       my_shard * n_shard, n, model_axis)
        cand_d = jnp.where(jnp.isfinite(cand_d), cand_d, BIG_COST)

        # candidate y values: masked local lookup + psum over model (ids
        # >= N, the underflow sentinel, read as y = 0)
        y_cand = _gather_sharded(y, cand_ids, my_shard, n_shard, model_axis)

        # ---- 3. serve + subgradient (Eq. 2 / Eq. 55) ---------------------
        serve = jax.vmap(lambda dd, xx: gain_lib.serve(dd, xx, k, c_f))(
            cand_d, (y_cand > 0.5).astype(cand_d.dtype))
        _, g_cand = jax.vmap(
            lambda dd, yy: gain_lib.gain_and_subgradient(dd, yy, k, c_f))(
            cand_d, y_cand)
        answers = jnp.take_along_axis(cand_ids, serve.answer_ids, axis=1)
        # IVF underflow can leave < k real candidates; those answer slots
        # carry the out-of-range sentinel — surface them as id = -1 (the
        # kernels' underflow convention) rather than a clamping-prone n.
        answers = jnp.where(answers < n, answers, -1)

        # ---- 4. route subgradients to owning shards ----------------------
        g_shard = _route_subgradients(g_cand, cand_ids, None,
                                      my_shard * n_shard, n_shard,
                                      batch_axes)

        # ---- 5. OMA + distributed projection -----------------------------
        z = mirror_maps.dual_ascent_step(y, g_shard, eta,
                                         mirror_maps.NEGENTROPY)
        y_new = jnp.clip(
            _distributed_projection(z, float(h), top_a, n_model, model_axis),
            1e-12, 1.0)

        metrics = {
            "gain": jax.lax.pmean(jnp.mean(serve.gain), batch_axes),
            "served_local": jax.lax.pmean(
                jnp.mean(jnp.sum(serve.from_cache, axis=1).astype(jnp.float32)),
                batch_axes),
        }
        return y_new, answers, metrics

    in_specs = [P(model_axis, None), P(model_axis), P(batch_axes, None)]
    if ivf is not None:
        in_specs += [P(model_axis, None), P(model_axis, None)]
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(model_axis), P(batch_axes, None),
                   {"gain": P(), "served_local": P()}),
        check_vma=False,
    )
    if ivf is None:
        return mapped
    return lambda catalog, y, requests: mapped(
        catalog, y, requests, ivf.centroids, ivf.invlists)


def reference_step(catalog, y, requests, *, c, k, c_f, h, eta, top_a):
    """Single-device oracle with identical semantics (for tests)."""
    from repro.core import projection

    d2 = pairwise_dissimilarity(requests, catalog)
    neg, ids = jax.lax.top_k(-d2, c)
    cand_d = -neg
    y_cand = y[ids]
    serve = jax.vmap(lambda dd, xx: gain_lib.serve(dd, xx, k, c_f))(
        cand_d, (y_cand > 0.5).astype(cand_d.dtype))
    _, g_cand = jax.vmap(
        lambda dd, yy: gain_lib.gain_and_subgradient(dd, yy, k, c_f))(
        cand_d, y_cand)
    g = jnp.zeros_like(y).at[ids.reshape(-1)].add(g_cand.reshape(-1))
    z = y * jnp.exp(jnp.clip(eta * g, -60.0, 60.0))
    y_new = projection.capped_simplex_negentropy_topk(z, h, top_a)
    answers = jnp.take_along_axis(ids, serve.answer_ids, axis=1)
    return jnp.clip(y_new, 1e-12, 1.0), answers


# ---------------------------------------------------------------------------
# The serving twin: sharded make_step_batched / make_replay_batched
# ---------------------------------------------------------------------------

def make_step_sharded(
    cfg: policy_lib.AcaiConfig, mesh, catalog: jax.Array, batch: int, *,
    eta_scale: float | None = None, model_axis: str = "model",
    batch_axes=("data",), scan_chunk: int = 0,
    ivf: ShardedIVF | None = None, top_a: int | None = None,
) -> Callable:
    """Sharded mini-batch step: (CacheState, requests (B, d)) ->
    (CacheState', StepMetrics (B,)) — the multi-device twin of
    `policy.make_step_batched` + `exact_candidate_fn_batched`.

    The candidate scan (per-shard fused top-k + top-C merge), the
    cached-row scan, serve/gain/subgradient, and the OMA + water-filling
    projection all run under shard_map over `mesh` (catalog/y/x sharded
    P(model), requests P(batch_axes)); rounding and metric assembly reuse
    the policy-layer code on the (small) merged state outside the map.

    Bit-consistency contract (pinned by tests/test_distributed_acai.py):
    on a 1-device mesh with `scan_chunk = 0`, `ivf = None` and
    `cfg.oma.projection_topk == top_a`, every carried state and metric is
    bitwise identical to `make_step_batched` with the exact candidate
    generator.  `top_a` defaults to `cfg.oma.projection_topk` (or 2h + 64)
    per shard — headroom for the distributed projection, Sec. IV-F.

    Requires the negentropy mirror map (the distributed water-filling
    solves the negentropy scale; euclidean would need a different exchange).
    """
    if cfg.oma.mirror != mirror_maps.NEGENTROPY:
        raise NotImplementedError(
            "make_step_sharded requires the negentropy mirror map")
    n, d = catalog.shape
    n_model = _axis_size(mesh, model_axis)
    n_batch = _axis_size(mesh, batch_axes)
    if n % n_model:
        raise ValueError(
            f"catalog rows ({n}) must divide by the mesh's {model_axis} "
            f"axis ({n_model})")
    if batch % n_batch:
        raise ValueError(
            f"batch size {batch} must divide by the mesh's batch axes "
            f"{batch_axes} (total size {n_batch}); note serve_update "
            f"(B = 1) only exists on meshes with size-1 batch axes")
    _check_ivf_matches_mesh(ivf, n_model)
    n_shard = n // n_model
    a = min(n_shard, top_a or cfg.oma.projection_topk or 2 * cfg.h + 64)
    scale = float(batch) if eta_scale is None else float(eta_scale)
    cfg_up = dataclasses.replace(
        cfg, oma=dataclasses.replace(cfg.oma, eta=cfg.oma.eta * scale)
    )

    def local(catalog_shard, y, x, rs, *ivf_args):
        my_shard = jax.lax.axis_index(model_axis)
        off = my_shard * n_shard
        b = rs.shape[0]

        # ---- remote candidates: per-shard scan + top-C merge ------------
        local_overflow = jnp.zeros((), jnp.int32)
        if scan_chunk == 0 and ivf is None:
            # paper-faithful / bit-consistent path: one (b, n_shard) GEMM
            # feeds both the remote top-k and the cached-row top-k, exactly
            # as exact_candidate_fn_batched does on the full catalog (no
            # cached-row gather bound, so nothing can truncate).
            d_full = pairwise_dissimilarity(rs, catalog_shard)
            neg_r, loc_r = jax.lax.top_k(-d_full, cfg.c_remote)
            d_r, miss_r = -neg_r, jnp.zeros(neg_r.shape, bool)
            d_cached = jnp.where(x[None, :] > 0.5, d_full, jnp.inf)
            neg_l, loc_l = jax.lax.top_k(-d_cached, cfg.c_local)
            d_l = -neg_l
        else:
            ivf_shard = ((ivf_args[0], ivf_args[1], ivf.nprobe)
                         if ivf else None)
            d_r, loc_r = _local_scan(rs, catalog_shard, cfg.c_remote,
                                     scan_chunk, ivf_shard)
            miss_r = loc_r < 0
            # cached rows: gather once per shard (static 2h + 64 bound,
            # same policy as index_candidate_fn_batched) + one small GEMM.
            cap = min(n_shard, 2 * cfg.h + 64)
            if cfg.debug:
                # same truncation-visibility contract as the single-device
                # step (StepMetrics.local_overflow): per-shard excess over
                # the static gather bound, summed over the model axis.
                occ = jnp.sum((x > 0.5).astype(jnp.int32))
                local_overflow = jax.lax.psum(
                    jnp.maximum(occ - cap, 0), model_axis)
            cached = jnp.nonzero(x > 0.5, size=cap, fill_value=-1)[0]
            cached_embs = catalog_shard[jnp.clip(cached, 0, n_shard - 1)]
            d_loc = pairwise_dissimilarity(rs, cached_embs)
            d_loc = jnp.where((cached >= 0)[None, :], d_loc, jnp.inf)
            neg_l, pos = jax.lax.top_k(-d_loc, cfg.c_local)
            loc_l = jnp.where(jnp.isfinite(neg_l), cached[pos], 0)
            d_l = -neg_l

        d_remote, ids_remote = _merge_topc(d_r, loc_r, miss_r, cfg.c_remote,
                                           off, n, model_axis)
        d_local, ids_local = _merge_topc(d_l, loc_l,
                                         jnp.zeros(d_l.shape, bool),
                                         cfg.c_local, off, n, model_axis)

        # ---- slab assembly: exactly exact_candidate_fn_batched ----------
        ids = jnp.concatenate([ids_remote, ids_local], axis=1)   # (b, C)
        dcand = jnp.concatenate([d_remote, d_local], axis=1)
        valid = policy_lib.dedup_mask_batched(ids, n)
        x_at = _gather_sharded(x, ids, my_shard, n_shard, model_axis)
        cached_ok = jnp.concatenate(
            [jnp.ones((b, cfg.c_remote), bool),
             x_at[:, cfg.c_remote:] > 0.5], axis=1)
        valid = valid & cached_ok
        dcand = jnp.where(valid & jnp.isfinite(dcand), dcand, BIG_COST)

        # ---- serve + gain/subgradient (vs the same x_t / y_t) -----------
        y_at = _gather_sharded(y, ids, my_shard, n_shard, model_axis)
        x_cand = jnp.where(valid, x_at, 0.0)
        y_cand = jnp.where(valid, y_at, 0.0)
        served = gain_lib.serve_batch(dcand, x_cand, cfg.k, cfg.c_f)
        gain_frac, g_cand = gain_lib.gain_and_subgradient_batch(
            dcand, y_cand, cfg.k, cfg.c_f)

        # ---- route subgradients to owning y-shards ----------------------
        g_shard = _route_subgradients(g_cand, ids, valid, off, n_shard,
                                      batch_axes, denom=float(batch))

        # ---- OMA + distributed water-filling projection -----------------
        z = mirror_maps.dual_ascent_step(y, g_shard, cfg_up.oma.eta,
                                         cfg.oma.mirror)
        y_new = jnp.clip(
            _distributed_projection(z, cfg.h, a, n_model, model_axis),
            oma_lib.Y_FLOOR, 1.0)

        served_local = jnp.sum(served.from_cache.astype(jnp.int32), axis=1)
        return (y_new, served.gain, gain_frac, served.cost, served_local,
                local_overflow)

    in_specs = [P(model_axis, None), P(model_axis), P(model_axis),
                P(batch_axes, None)]
    extra = ()
    if ivf is not None:
        in_specs += [P(model_axis, None), P(model_axis, None)]
        extra = (ivf.centroids, ivf.invlists)
    mapped = shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs),
        # local_overflow is a model-axis psum (or a constant 0): identical
        # on every shard, hence replicated
        out_specs=(P(model_axis),) + (P(batch_axes),) * 4 + (P(),),
        check_vma=False,
    )

    def step(state: policy_lib.CacheState, rs: jax.Array):
        key, k_round = jax.random.split(state.key)
        y_new, gain_int, gain_frac, cost, served_local, overflow = mapped(
            catalog, state.y, state.x, rs, *extra)
        return policy_lib.finish_step_batched(
            cfg_up, state, key, k_round, batch, y_new, gain_int, gain_frac,
            cost, served_local, local_overflow=overflow)

    return step


def make_replay_sharded(
    cfg: policy_lib.AcaiConfig, mesh, catalog: jax.Array, batch: int,
    **kwargs,
) -> Callable:
    """Sharded mini-batched whole-trace replay — the multi-device twin of
    `policy.make_replay_batched` (same signature contract: (state,
    requests (T, d)) -> (state', StepMetrics (T,)), T divisible by batch).

    On a 1-device mesh with `cfg.oma.projection_topk == top_a` this is
    bit-consistent with `make_replay_batched` + exact candidates; on P
    shards the per-step communication is the top-C all-gathers plus the
    (P·A + 1) projection scalars (DESIGN.md §7).
    """
    return policy_lib.make_replay_from_step(
        make_step_sharded(cfg, mesh, catalog, batch, **kwargs), batch)
