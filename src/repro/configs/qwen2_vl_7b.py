"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision tower
is a STUB: input_specs() provides precomputed patch embeddings (B, P,
d_model) as a prefix plus 3-D (t/h/w) M-RoPE position ids for the full
sequence.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    pos_emb="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    modality="vision",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen2-vl-7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    qkv_bias=True, pos_emb="mrope", mrope_sections=(2, 3, 3),
    modality="vision",
)
