"""Online serving engine: request queue, dynamic batch former, admission
control, and interleaved catalog mutation (DESIGN.md §12).

This is the "millions of users" claim made measurable: instead of
offline replay at a fixed batch size, requests *arrive* (repro.serve.
arrivals), wait in a FIFO queue, get coalesced into dynamic batches by a
max-size/max-wait window, and are served through any registered
`CachePolicy`'s `serve_update_batch` — all on the deterministic virtual
millisecond clock the resilient tier introduced, so nothing sleeps and
a run is replayable bit-for-bit.

The model (one device, one batch in flight — the single-server queue
the paper's edge node is):

* **queue** — arrivals append FIFO; an optional `queue_cap` sheds
  arrivals past that depth at their arrival instant (back-pressure).
* **batch former** — when the server is idle and requests are pending, a
  batch of up to `max_batch` requests dispatches as soon as (a) the
  queue holds `max_batch` requests (size trigger), (b) the *oldest*
  pending request has waited `max_wait_ms` (timeout trigger — no request
  starves past the window while the server is idle), or (c) no further
  arrival can ever come (drain).  `max_wait_ms=None` disables the
  timeout: pure size-triggered batching, the **fixed-window** mode whose
  batch partition reproduces offline replay exactly (the bitwise-
  equivalence pin below).
* **admission control** — besides the queue cap, a `deadline_ms` sheds
  requests *at formation* when the batch's predicted completion
  (form time + service_ms over the pre-shed candidate size — one pass,
  deterministic) already overruns their arrival-relative deadline:
  serving a guaranteed-late request wastes a slot someone else could
  meet their SLO in.
* **service** — a formed batch of b requests occupies the server for
  `ServiceModel.service_ms(b)` *virtual* ms (affine by default:
  base + per_request · b, the empirical shape of the batched step).
  Virtual service time is a deterministic model — the bench reports
  measured wall step times separately — so latency distributions are
  exactly reproducible across machines.
* **mutation** — churn events (`trace.rolling_catalog_events` schedule
  shape: (step, insert_ids, remove_ids), keyed by trace position) apply
  between formed batches through the policy's `add_objects` /
  `remove_objects`, same convention as `churn.replay_with_churn`: an
  event fires before the batch containing request `step`; events landing
  past the last dispatch drain at the end.

Bitwise offline equivalence (the drift pin, asserted by
tests/test_serving_engine.py and by `benchmarks/serving_bench.py` on
every run): with `BatchFormerConfig(max_batch=B, max_wait_ms=None)`, no
admission control and no mutation, dispatch order is FIFO in size-B
chunks — exactly `make_replay_batched`'s partition — so an AÇAI policy's
per-request gain and final (y, x) state are bitwise identical to the
offline replay, regardless of the arrival process.
"""

from __future__ import annotations

import dataclasses
import time as _time
from collections import deque
from typing import Callable, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.core.policy import StepMetrics, shed_only_metrics
from repro.serve.arrivals import ArrivalSpec, make_source

#: shed reasons booked into per-request records
SHED_QUEUE_FULL = "queue_full"
SHED_DEADLINE = "deadline"


@dataclasses.dataclass(frozen=True)
class BatchFormerConfig:
    """Dynamic batch window: dispatch at `max_batch` pending requests or
    when the oldest has waited `max_wait_ms`, whichever first.
    `max_wait_ms=None` = pure size trigger (the fixed-window mode)."""

    max_batch: int = 8
    max_wait_ms: Optional[float] = 5.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1: {self.max_batch}")
        if self.max_wait_ms is not None and self.max_wait_ms < 0:
            raise ValueError(
                f"max_wait_ms must be >= 0 or None: {self.max_wait_ms}")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission control: `queue_cap` sheds arrivals past that queue
    depth; `deadline_ms` sheds requests at batch formation when the
    predicted completion already overruns arrival + deadline.  Both
    default off (admit everything — the fixed-window pin's regime)."""

    queue_cap: Optional[int] = None
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1: {self.queue_cap}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0: {self.deadline_ms}")


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Virtual service time of one batched step: affine in batch size
    (a fixed dispatch overhead plus per-request scan work — the measured
    shape of the fused batched pipeline, DESIGN.md §6).  Deterministic by
    construction so latency curves replay bit-for-bit; calibrate the two
    constants against a measured p50 if absolute numbers matter."""

    base_ms: float = 2.0
    per_request_ms: float = 0.25

    def __post_init__(self):
        if self.base_ms < 0 or self.per_request_ms < 0:
            raise ValueError(
                f"service model needs nonnegative costs: "
                f"({self.base_ms}, {self.per_request_ms})")
        if self.base_ms == 0 and self.per_request_ms == 0:
            raise ValueError("service model must take nonzero time "
                             "(a 0-ms server never queues)")

    def service_ms(self, batch: int) -> float:
        return self.base_ms + self.per_request_ms * batch

    def capacity_rps(self, max_batch: int) -> float:
        """Saturation throughput at full batches (requests/second) —
        the natural unit for offered-load sweeps."""
        return 1e3 * max_batch / self.service_ms(max_batch)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Pending(NamedTuple):
    rid: int
    arrival_ms: float


class RequestRecord(NamedTuple):
    """What one request experienced end to end (virtual time)."""

    rid: int
    arrival_ms: float
    form_ms: float      # batch-formation instant (= shed instant if shed)
    done_ms: float      # completion (= form_ms if shed)
    batch: int          # formed batch size (0 if shed)
    shed_reason: str    # '' | 'queue_full' | 'deadline'
    # answer-cache fast path (DESIGN.md §13): a hit's *answer* completes
    # at arrival + hit_ms without entering the batch former; what still
    # queues is only the deferred policy-learning update (so the batch
    # partition — and with it the fixed-window bitwise pin — is
    # unchanged).  `user_done_ms` is the user-visible completion: the
    # fast-path instant for hits, `done_ms` otherwise (0.0 = not set,
    # resolved at assembly).  A shed fast-path hit still answered at
    # arrival — the shed books the lost learning, not the lost answer.
    answer_hit: int = 0
    user_done_ms: float = 0.0

    @property
    def shed(self) -> bool:
        return bool(self.shed_reason)


class OnlineServingEngine:
    """Drive a `CachePolicy` through an arrival process on the virtual
    clock.  One `run(...)` consumes a request trace plus an arrival
    description and returns the full latency/goodput story.

    The policy only needs the batched step contract
    (`serve_update_batch(rs, ts=None) -> StepMetrics`), so every
    registered policy — AÇAI over any index backend, every baseline,
    resilient wrappers — serves through the same queue unchanged."""

    def __init__(self, policy,
                 former: BatchFormerConfig = BatchFormerConfig(),
                 admission: AdmissionConfig = AdmissionConfig(),
                 service: ServiceModel = ServiceModel()):
        self.policy = policy
        self.former = former
        self.admission = admission
        self.service = service

    # -- simulation --------------------------------------------------------

    def run(self, reqs, arrivals, *, catalog=None,
            events: Sequence = (), slo_ms: Optional[float] = None) -> dict:
        """Serve `reqs` (T, d) with arrival schedule `arrivals` (an
        `ArrivalSpec`, a nondecreasing times array, or a ready source).

        `events` is a churn schedule ([(step, insert_ids, remove_ids)],
        trace-position keyed) applied between batches; insert events read
        their embeddings from `catalog` (the full object universe —
        required when any event inserts).  `slo_ms` sets the goodput
        target (completion within arrival + slo_ms; shed = never good).

        Returns a dict of per-request arrays in trace order (gain, cost,
        served_local, shed, arrival/form/done timestamps, queue/service/
        total latency) plus aggregate latency percentiles, goodput,
        shed share, the batch-size histogram, per-request `StepMetrics`,
        and wall p50 of the policy step.
        """
        reqs = np.asarray(reqs)
        t = reqs.shape[0]
        source = make_source(arrivals, t)
        pending_events = sorted(events, key=lambda ev: ev[0])
        if any(len(ev[1]) for ev in pending_events) and catalog is None:
            raise ValueError("insert events need the full `catalog` "
                             "universe to read embeddings from")

        queue: deque[_Pending] = deque()
        records: dict[int, RequestRecord] = {}
        batch_metrics: List[tuple[List[int], StepMetrics]] = []
        step_walls: List[float] = []
        batch_sizes: List[int] = []
        now, busy_until = 0.0, 0.0
        max_depth, ev_i, mutation_s = 0, 0, 0.0
        mb, mw = self.former.max_batch, self.former.max_wait_ms
        cap, deadline = self.admission.queue_cap, self.admission.deadline_ms
        # answer-cache fast path (DESIGN.md §13): peek — non-counting, so
        # policy-step hit statistics stay replay-consistent — at arrival;
        # a hit's answer completes at arrival + hit_ms and only the
        # deferred learning update queues on (batch partition unchanged)
        ac = getattr(self.policy, "answer_cache", None)
        hit_ms = ac.spec.hit_ms if ac is not None else 0.0
        fast: dict[int, float] = {}  # rid -> user-visible completion

        def admit(up_to: float) -> None:
            nonlocal max_depth
            while True:
                nxt = source.peek()
                if nxt is None or nxt > up_to:
                    return
                at, rid = source.pop()
                if ac is not None and ac.cache.peek(reqs[rid]):
                    fast[rid] = at + hit_ms
                if cap is not None and len(queue) >= cap:
                    records[rid] = RequestRecord(rid, at, at, at, 0,
                                                 SHED_QUEUE_FULL,
                                                 int(rid in fast),
                                                 fast.get(rid, 0.0))
                    source.on_complete(rid, at)
                    continue
                queue.append(_Pending(rid, at))
                max_depth = max(max_depth, len(queue))

        def apply_events(before_pos: int) -> None:
            nonlocal ev_i, mutation_s
            while (ev_i < len(pending_events)
                   and pending_events[ev_i][0] < before_pos):
                _, ins, rem = pending_events[ev_i]
                t0 = _time.time()
                if len(ins):
                    self.policy.add_objects(catalog[np.asarray(ins)])
                if len(rem):
                    self.policy.remove_objects(rem)
                mutation_s += _time.time() - t0
                ev_i += 1

        while source.peek() is not None or queue:
            admit(now)
            if ac is not None:
                ac.tick(now)  # idle-unload clock (DESIGN.md §13)
            if busy_until <= now and queue:
                full = len(queue) >= mb
                # NB: compare against the *same float expression* the
                # clock-advance horizon computes (arrival + mw), not the
                # rearranged `now - arrival >= mw`: (a+mw)-a can round
                # below mw, which would leave the timer eternally
                # "almost expired" and the loop spinning in place
                timed_out = (mw is not None
                             and now >= queue[0].arrival_ms + mw)
                drained = source.peek() is None
                if full or timed_out or drained:
                    taken = [queue.popleft()
                             for _ in range(min(mb, len(queue)))]
                    kept = taken
                    if deadline is not None:
                        est_done = now + self.service.service_ms(len(taken))
                        kept = []
                        for q in taken:
                            if est_done > q.arrival_ms + deadline:
                                records[q.rid] = RequestRecord(
                                    q.rid, q.arrival_ms, now, now, 0,
                                    SHED_DEADLINE, int(q.rid in fast),
                                    fast.get(q.rid, 0.0))
                                source.on_complete(q.rid, now)
                            else:
                                kept.append(q)
                    if kept:
                        b = len(kept)
                        # churn events fire before the batch containing
                        # their trace position (replay_with_churn rule)
                        apply_events(max(q.rid for q in kept) + 1)
                        t0 = _time.time()
                        m = self.policy.serve_update_batch(
                            reqs[[q.rid for q in kept]], None)
                        step_walls.append(_time.time() - t0)
                        done = now + self.service.service_ms(b)
                        busy_until = done
                        batch_sizes.append(b)
                        batch_metrics.append(([q.rid for q in kept], m))
                        for q in kept:
                            records[q.rid] = RequestRecord(
                                q.rid, q.arrival_ms, now, done, b, "",
                                int(q.rid in fast), fast.get(q.rid, 0.0))
                            source.on_complete(q.rid, done)
                    continue  # re-evaluate triggers at the same instant
            # advance the clock to the next actionable event: the next
            # arrival, and either the server-free instant (a window timer
            # that expires while the server is busy cannot dispatch any
            # earlier than busy_until, so it is not an event) or — idle —
            # the oldest request's window expiry (strictly future here:
            # an expired timer with an idle server dispatched above)
            horizon = []
            nxt = source.peek()
            if nxt is not None:
                horizon.append(nxt)
            if busy_until > now:
                horizon.append(busy_until)
            elif queue and mw is not None:
                horizon.append(queue[0].arrival_ms + mw)
            if not horizon:
                # queue nonempty, size trigger unmet, no timer, server
                # idle, source drained: the drain trigger fires next pass
                continue
            now = max(now, min(horizon))
        # drain every remaining churn event (the replay_with_churn rule:
        # the catalog always ends in the schedule's final state, and
        # events_applied == len(events) unconditionally)
        apply_events(float("inf"))
        return self._assemble(records, batch_metrics, batch_sizes,
                              step_walls, slo_ms, mutation_s, ev_i,
                              max_depth)

    # -- result assembly ---------------------------------------------------

    def _assemble(self, records, batch_metrics, batch_sizes, step_walls,
                  slo_ms, mutation_s, events_applied, max_depth) -> dict:
        rids = sorted(records)
        n = len(rids)
        assert rids == list(range(n)), (
            f"request ids not contiguous: {n} records over range "
            f"{rids[:3]}..{rids[-3:] if n >= 3 else rids}")
        recs = [records[r] for r in rids]
        per_req = tree_rows_to_metrics(n, batch_metrics, recs)
        arrival = np.array([r.arrival_ms for r in recs])
        form = np.array([r.form_ms for r in recs])
        done = np.array([r.done_ms for r in recs])
        shed = np.array([r.shed for r in recs], bool)
        served = ~shed
        latency = done - arrival
        queue_ms = form - arrival
        service_ms = done - form
        sizes, counts = (np.unique(batch_sizes, return_counts=True)
                         if batch_sizes else (np.array([]), np.array([])))

        def pct(a, q):
            return float(np.percentile(a, q)) if len(a) else 0.0

        lat_served = latency[served]
        res = {
            "gain": np.asarray(per_req.gain_int, np.float64),
            "cost": np.asarray(per_req.cost, np.float64),
            "served_local": np.asarray(per_req.served_local),
            "hit": np.asarray(per_req.served_local) > 0,
            "occupancy": np.asarray(per_req.occupancy, np.float64),
            "metrics": per_req,
            "arrival_ms": arrival, "form_ms": form, "done_ms": done,
            "queue_ms": queue_ms, "service_ms": service_ms,
            "latency_ms": latency,
            "shed": shed,
            "shed_reasons": [r.shed_reason for r in recs],
            "requests": n,
            "served": int(served.sum()),
            "shed_total": int(shed.sum()),
            "shed_share": float(shed.mean()) if n else 0.0,
            "p50_ms": pct(lat_served, 50),
            "p99_ms": pct(lat_served, 99),
            "p999_ms": pct(lat_served, 99.9),
            "queue_p50_ms": pct(queue_ms[served], 50),
            "queue_p99_ms": pct(queue_ms[served], 99),
            "service_p50_ms": pct(service_ms[served], 50),
            "batch_hist": {int(s): int(c) for s, c in zip(sizes, counts)},
            "mean_batch": float(np.mean(batch_sizes)) if batch_sizes else 0.0,
            "batches": len(batch_sizes),
            "max_queue_depth": max_depth,
            "events_applied": events_applied,
            "mutation_s": mutation_s,
            "p50_step_s": (float(np.percentile(step_walls, 50))
                           if step_walls else 0.0),
        }
        # answer-cache fast-path decomposition (DESIGN.md §13): hits'
        # user-visible completion is the arrival-time fast path, not the
        # learn-batch completion; the legacy latency fields above keep
        # their (learn-path) meaning so cache-off results are unchanged.
        ahit = np.array([bool(r.answer_hit) for r in recs], bool)
        user_done = np.where(
            ahit, np.array([r.user_done_ms for r in recs]), done)
        user_latency = user_done - arrival
        answered = served | ahit  # a shed hit was still answered
        res.update({
            "answer_hit": ahit,
            "answer_hits": int(ahit.sum()),
            "answer_hit_rate": float(ahit.mean()) if n else 0.0,
            "user_latency_ms": user_latency,
            "p50_user_ms": pct(user_latency[answered], 50),
            "p99_user_ms": pct(user_latency[answered], 99),
            "p50_hit_ms": pct(user_latency[ahit], 50),
            "p50_miss_ms": pct(latency[served & ~ahit], 50),
        })
        if slo_ms is not None:
            good = served & (latency <= slo_ms)
            res["slo_ms"] = float(slo_ms)
            res["goodput_slo"] = float(good.mean()) if n else 0.0
        return res


def tree_rows_to_metrics(n: int, batch_metrics, recs) -> StepMetrics:
    """Scatter per-batch StepMetrics back to trace order and book the
    engine's shed rows (`shed_only_metrics`) into the same counters, so
    one (n,)-leaved StepMetrics tells the whole story: policy outcomes
    on served rows, shed=1 zero-gain rows on admission-control victims."""
    import jax.tree_util as jtu

    base = shed_only_metrics(n)
    cols = {f: np.asarray(getattr(base, f)).copy()
            for f in StepMetrics._fields}
    for rid, rec in enumerate(recs):
        if not rec.shed:
            cols["shed"][rid] = 0
    for rids, m in batch_metrics:
        arrs = jtu.tree_map(np.asarray, m)
        for j, rid in enumerate(rids):
            for f in StepMetrics._fields:
                v = np.asarray(getattr(arrs, f))
                # 0-d guard: StepMetrics fields with int defaults (e.g.
                # the resilient tier omits the answer-cache counters)
                # come through as scalars — broadcast, don't index
                cols[f][rid] = v[j] if v.ndim else v
    return StepMetrics(**cols)


def fixed_window_engine(policy, batch: int,
                        service: ServiceModel = ServiceModel()
                        ) -> OnlineServingEngine:
    """The offline-equivalent configuration (the drift pin): pure
    size-triggered batches of exactly `batch`, no admission control —
    FIFO dispatch in size-`batch` chunks, the same partition
    `make_replay_batched` scans.  With T divisible by `batch` the drain
    trigger never forms a partial batch."""
    return OnlineServingEngine(
        policy,
        former=BatchFormerConfig(max_batch=batch, max_wait_ms=None),
        admission=AdmissionConfig(),
        service=service)


def serve_trace_online(pol, reqs, arrivals, *,
                       former: BatchFormerConfig = BatchFormerConfig(),
                       admission: AdmissionConfig = AdmissionConfig(),
                       service: ServiceModel = ServiceModel(),
                       catalog=None, events: Sequence = (),
                       slo_ms: Optional[float] = None) -> dict:
    """One-call online replay — the serving twin of
    `policy_api.replay_trace`: build the engine, run the trace, return
    the result dict.  `arrivals` is an ArrivalSpec, a times array, or a
    ready source."""
    eng = OnlineServingEngine(pol, former=former, admission=admission,
                              service=service)
    return eng.run(reqs, arrivals, catalog=catalog, events=events,
                   slo_ms=slo_ms)
