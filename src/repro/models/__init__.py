"""Model zoo: unified transformer/SSM/hybrid stacks for the 10 assigned
architectures (see repro.configs)."""

from repro.models.config import ModelConfig
from repro.models.model import (ForwardResult, cross_entropy, forward,
                                init_cache, init_params, mtp_loss, unit_spec)

__all__ = ["ForwardResult", "ModelConfig", "cross_entropy", "forward",
           "init_cache", "init_params", "mtp_loss", "unit_spec"]
