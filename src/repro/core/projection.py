"""Bregman projections onto the capped simplex (paper Sec. IV-F, line 6 Alg. 1).

Feasible set:  B_h = { y in [0,1]^N : sum_i y_i = h }   (= conv(X) on the
local components; the augmented components are determined by y_{i+N}=1-y_i).

* negentropy map  Phi(y) = sum y log y:
    argmin_y D_Phi(y, z)  =>  y_i = min(1, s * z_i)  for the unique s > 0
    with sum_i min(1, s z_i) = h.  Solved EXACTLY by the sort-based
    threshold scan the paper adapts from Wang & Lu — O(N log N).
* euclidean map   Phi(y) = 1/2 ||y||^2:
    y_i = clip(z_i - tau, 0, 1); tau found by monotone bisection (the sum is
    continuous, piecewise-linear, strictly decreasing where feasible).

`negentropy_topk` is the beyond-paper accelerated variant: only the largest
A entries can hit the cap y=1 (s*z is order-preserving), so an O(N + A log A)
lax.top_k + scan over A suffices instead of a full sort.  Bitwise-identical
results whenever the number of capped entries is < A (always true in practice
since at most h entries cap and we take A >= h headroom).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_TINY = 1e-30


def _negentropy_scale_from_sorted(zs_desc: jax.Array, tail_sum, h):
    """Find s with sum min(1, s z) = h given the (descending) head `zs_desc`
    of z plus the scalar sum of the remaining (never-capped) tail."""
    a = zs_desc.shape[0]
    m = jnp.arange(a, dtype=zs_desc.dtype)  # number of capped entries
    # reversed cumsum: accumulates the small tail entries first — avoids the
    # float32 cancellation of the (total - prefix) formulation.
    suffix = jnp.cumsum(zs_desc[::-1])[::-1]  # sum_{i >= m} zs[i]
    denom = jnp.maximum(suffix + tail_sum, _TINY)
    s_m = (h - m) / denom
    # consistency: first uncapped scaled <= 1, last capped scaled >= 1
    cond_uncapped = zs_desc * s_m <= 1.0 + 1e-7
    prev = jnp.concatenate([jnp.full((1,), jnp.inf, zs_desc.dtype), zs_desc[:-1]])
    cond_capped = prev * s_m >= 1.0 - 1e-7
    valid = cond_uncapped & cond_capped & (h - m > 0)
    idx = jnp.argmax(valid)  # first (smallest m) valid split
    return jnp.take(s_m, idx), valid.any()


@partial(jax.jit, static_argnames=())
def capped_simplex_negentropy(z: jax.Array, h) -> jax.Array:
    """Exact sort-based negentropy Bregman projection, O(N log N)."""
    n = z.shape[0]
    z = jnp.maximum(z, 0.0)
    zs = jnp.sort(z)[::-1]
    s, ok = _negentropy_scale_from_sorted(zs, jnp.zeros((), z.dtype), h)
    y = jnp.minimum(1.0, z * s)
    # h >= N degenerate: everything capped.
    return jnp.where(jnp.asarray(h, z.dtype) >= n, jnp.ones_like(z), y)


@partial(jax.jit, static_argnames=("a",))
def capped_simplex_negentropy_topk(z: jax.Array, h, a: int) -> jax.Array:
    """O(N + A log A) variant: sort only the top-A entries (A >= h + slack)."""
    z = jnp.maximum(z, 0.0)
    ztop, idx = jax.lax.top_k(z, a)
    # sum the non-top tail directly (no total-minus-top cancellation)
    tail = jnp.sum(z.at[idx].set(0.0))
    s, ok = _negentropy_scale_from_sorted(ztop, tail, h)
    # degenerate z (e.g. heavy churn removal leaving < h live mass splits)
    # can leave no feasible water level; fall back to scale 1 rather than
    # garbage — mirrored by the distributed projection so the sharded
    # twin stays bitwise on a 1-device mesh
    s = jnp.where(ok, s, 1.0)
    return jnp.minimum(1.0, z * s)


@partial(jax.jit, static_argnames=("iters",))
def capped_simplex_euclidean(z: jax.Array, h, iters: int = 64) -> jax.Array:
    """Euclidean projection onto B_h via bisection on the shift tau."""
    lo = jnp.min(z) - 1.0
    hi = jnp.max(z)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        total = jnp.sum(jnp.clip(z - mid, 0.0, 1.0))
        too_big = total > h
        return (jnp.where(too_big, mid, lo), jnp.where(too_big, hi, mid))

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return jnp.clip(z - 0.5 * (lo + hi), 0.0, 1.0)
