"""Checkpointing: sharded-aware save/restore with atomic commits.

Layout:  <dir>/step_<N>/
            manifest.json          (pytree structure, shapes, dtypes, step)
            arrays.npz             (flattened leaves, keyed by path)
Writes go to a tmp dir + atomic rename, so a crash mid-save never corrupts
the latest checkpoint (restart-safety).  Restore is *elastic*: leaves are
device_put against whatever sharding tree the caller provides, so a run
can come back on a different mesh shape (fewer/more pods) — the
re-sharding is a plain device_put per leaf.

bf16 leaves are stored as uint16 views (npz has no bfloat16).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, tree, keep: int = 3) -> str:
    flat, _ = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays, manifest = {}, {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(leaf.dtype)
        if dtype == "bfloat16":
            arr = arr.view(np.uint16)
        arrays[key.replace("/", "__")] = arr
        manifest["leaves"][key] = {"dtype": dtype, "shape": list(arr.shape)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str):
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`; optionally device_put each
    leaf with the matching sharding from `shardings` (elastic re-shard)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = _flatten(like_tree)
    flat_shard = _flatten(shardings)[0] if shardings is not None else None
    leaves = {}
    for key, like in flat_like.items():
        arr = arrays[key.replace("/", "__")]
        dtype = manifest["leaves"][key]["dtype"]
        if dtype == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if flat_shard is not None:
            leaves[key] = jax.device_put(arr, flat_shard[key])
        else:
            leaves[key] = jnp.asarray(arr)
    ordered = [leaves[k] for k in flat_like]
    return jax.tree_util.tree_unflatten(treedef, ordered)
