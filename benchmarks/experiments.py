"""Config-driven experiment harness: (TraceSpec × PolicySpec × sweep) grids.

One harness replaces the eight hand-wired `fig*.py` scripts: a *grid*
names its traces (`repro.core.trace.TraceSpec`), its policies
(`repro.core.policy_api.PolicySpec`) and its sizes, and `run_grid` does
the rest — generate each trace once, precompute ONE `ServerOracle` per
trace (shared by every baseline cell), build each policy through
`build_policy`, replay, and emit NAG / hit ratio / p50 step latency per
(trace × policy) cell.  The fig scripts are thin wrappers over named
grids (`python -m benchmarks.run --suite fig1` still works and prints
the same figure-level summary lines).

The canonical cross-policy suite (`--suite experiments`) sweeps every
registered policy over the scenario set — stationary (`sift_like`),
drifting (`amazon_like`), shocked (`flash_crowd`) and worst-case
(`adversarial`) — and writes `BENCH_experiments.json` at the repo root
so the comparative trajectory (the paper's Figs. 1-8 claim: AÇAI ≥ every
baseline wherever there is structure to exploit) is tracked per PR.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import pathlib
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from benchmarks import common
from repro.core import baselines as B
from repro.core import policy_api as PA
from repro.core import trace as T
from repro.core.costs import CostModel, calibrate_fetch_cost
from repro.core.policy_api import PolicySpec
from repro.core.trace import TraceSpec

BENCH_JSON = (pathlib.Path(__file__).resolve().parent.parent
              / "BENCH_experiments.json")

# trace-name aliases the legacy CLI (--trace sift|amazon) used
TRACE_ALIASES = {"sift": "sift_like", "amazon": "amazon_like"}


@dataclasses.dataclass(frozen=True)
class Grid:
    """One experiment: traces × policies at a given size.

    `policies` may be a callable (c_f, h, k) -> specs so a grid can place
    cost-model-calibrated values (e.g. C_theta = 1.5 c_f) in its sweeps;
    `summarize(rows) -> [(label, value)]` emits the figure-level derived
    lines (improvement over 2nd best, spread, ...)."""

    name: str
    desc: str
    traces: Tuple[TraceSpec, ...]
    # tuple of PolicySpec, or a callable (c_f, h, k, full) -> specs, so a
    # grid can place cost-model-calibrated values (C_theta = 1.5 c_f) in
    # its sweeps and widen them at --full (the paper-scale protocol)
    policies: "Tuple[PolicySpec, ...] | Callable"
    h: int = 200
    k: int = 10
    full_h: int = 1000
    # fetch-cost calibrations to sweep: c_f = avg distance of the kth
    # closest neighbour, the paper's Sec. V-C construction.  More than
    # one entry = a c_f sweep (fig3).
    cf_kths: Tuple[int, ...] = (50,)
    batch: int = 8
    summarize: Optional[Callable] = None

    def policy_specs(self, c_f: float, h: int, k: int, full: bool):
        if callable(self.policies):
            return tuple(self.policies(c_f, h, k, full))
        return self.policies


def sweep(name: str, base: dict = None, **param_lists) -> list:
    """Expand a cartesian parameter sweep into PolicySpecs:
    sweep("sim_lru", {"h": 200}, k_prime=[10, 20], c_theta=[1.0, 1.5])
    -> 4 specs."""
    base = dict(base or {})
    keys = sorted(param_lists)
    out = []
    for combo in itertools.product(*(param_lists[k] for k in keys)):
        out.append(PolicySpec(name, {**base, **dict(zip(keys, combo))}))
    return out


def _tuned_baselines(c_f, h, k, names=("sim_lru", "cls_lru", "rnd_lru"),
                     extra=("lru", "qcache"), augmented=False):
    """The paper's baseline tuning protocol as explicit grid cells:
    (k', C_theta) sweeps for the SIM-LRU family, single cells for the
    parameter-free policies."""
    base = {"h": h, "k": k}
    if augmented:
        base["augmented"] = True
    specs = []
    for n in names:
        specs += sweep(n, base, k_prime=sorted({k, 2 * k, min(4 * k, h)}),
                       c_theta=[1.0 * c_f, 1.5 * c_f, 2.0 * c_f])
    for n in extra:
        specs.append(PolicySpec(n, dict(base)))
    return specs


def _fig2_hs(full):
    return (50, 100, 200, 500, 1000, 2000) if full else (50, 100, 200, 400)


def _fig4_ks(full):
    return (10, 20, 30, 50, 100) if full else (5, 10, 20, 40)


def _fig5_hs(full):
    return (50, 1000) if full else (50, 200)


# trace + oracle caches: a full `benchmarks.run` sweep touches the same
# (scenario, size) cell from many grids — generate and precompute once.
_TRACE_CACHE: Dict[tuple, tuple] = {}
_ORACLE_CACHE: Dict[tuple, "B.ServerOracle"] = {}


def _cache_key(tspec: TraceSpec, sizes: dict) -> tuple:
    return (tspec, tuple(sorted(sizes.items())))


def _get_trace(tspec: TraceSpec, sizes: dict):
    key = _cache_key(tspec, sizes)
    if key not in _TRACE_CACHE:
        if len(_TRACE_CACHE) >= 4:  # bound resident traces
            _TRACE_CACHE.pop(next(iter(_TRACE_CACHE)))
        catalog, reqs, _ids = T.build_trace(tspec, **sizes)
        _TRACE_CACHE[key] = (catalog, reqs)
    return _TRACE_CACHE[key]


def _get_oracle(tspec: TraceSpec, sizes: dict, catalog, reqs, kmax: int):
    key = _cache_key(tspec, sizes)
    oracle = _ORACLE_CACHE.get(key)
    if oracle is None or oracle.kmax < min(kmax, catalog.shape[0]):
        if len(_ORACLE_CACHE) >= 4:
            _ORACLE_CACHE.pop(next(iter(_ORACLE_CACHE)))
        oracle = B.ServerOracle(catalog, reqs, kmax=kmax)
        _ORACLE_CACHE[key] = oracle
    return oracle


def run_grid(grid: Grid, full: bool = False, trace_filter: str = None,
             sizes: dict = None) -> list[dict]:
    """Run every (trace × policy) cell of a grid; returns the row dicts.

    Per trace: one generation, one ServerOracle precompute (kmax sized to
    the largest k' any cell requests), one CostModel calibration — every
    policy of the cell set shares all three (cached across grids)."""
    sz = sizes or common.sizes(full)
    rows = []
    for tspec in grid.traces:
        if trace_filter and tspec.name != trace_filter:
            continue
        catalog, reqs = _get_trace(tspec, sz)
        import jax.numpy as jnp

        cat_j = jnp.asarray(catalog)
        h = grid.full_h if full else grid.h
        # one oracle per trace, shared by every (c_f × policy) cell AND
        # across grids of a full `benchmarks.run` sweep; kmax is sized to
        # the largest k' any cell can request
        kmax_guess = min(max(4 * grid.k, 128), catalog.shape[0])
        ts = np.arange(reqs.shape[0])
        oracle = None
        for kth in grid.cf_kths:
            c_f = float(calibrate_fetch_cost(
                cat_j, kth=min(kth, catalog.shape[0] - 1), sample=256))
            cm = CostModel(c_f=c_f)
            specs = grid.policy_specs(c_f, h, grid.k, full)
            kmax = max([max(int(s.params.get("k") or grid.k),
                            int(s.params.get("k_prime") or 0))
                        for s in specs] + [grid.k, 16])
            oracle = _get_oracle(tspec, sz, catalog, reqs,
                                 max(kmax, kmax_guess))
            cf_tag = f"cf@{kth}/" if len(grid.cf_kths) > 1 else ""
            for spec in specs:
                pol = PA.build_policy(spec, catalog, cm, oracle=oracle,
                                      seed=0)
                t0 = time.time()
                res = PA.replay_trace(pol, reqs, ts, batch=grid.batch)
                wall = time.time() - t0
                tt = res["requests"]
                nag_curve = B.nag(res["gain"], pol.k, pol.c_f)
                occ = res["occupancy"]
                hh = spec.params.get("h", h)
                row = {
                    "grid": grid.name, "trace": tspec.to_dict(),
                    "policy": spec.to_dict(), "label": spec.label,
                    "requests": tt, "h": hh,
                    "k": pol.k, "cf_kth": kth, "c_f": round(c_f, 5),
                    "nag": round(float(nag_curve[-1]), 4),
                    # time-to-90%-of-final NAG: the paper's "same gain in
                    # a shorter time" reactivity metric (fig6)
                    "t90": int(np.argmax(nag_curve >= 0.9 * nag_curve[-1])),
                    "hit_ratio": round(float(res["hit"].mean()), 4),
                    "local_share": round(
                        float(res["served_local"].sum()) / (pol.k * tt), 4),
                    "fetches_per_req": round(float(res["fetched"].mean()), 3),
                    "occupancy_mean": round(float(occ.mean()), 1),
                    # occupancy concentration under the relaxed capacity
                    # constraint (fig8 / App. G)
                    "occupancy_p99_dev": round(float(
                        np.percentile(np.abs(occ - hh), 99)) / hh, 4),
                    "p50_step_us": round(res["p50_step_s"] * 1e6, 1),
                    "us_per_request": round(wall / tt * 1e6, 2),
                }
                rows.append(row)
                common.emit(
                    f"{grid.name}/{tspec.name}/{cf_tag}{spec.label}",
                    row["us_per_request"],
                    f"NAG={row['nag']:.4f};hit={row['hit_ratio']:.3f};"
                    f"p50_step_us={row['p50_step_us']:.0f}")
        if grid.summarize:
            for label, value in grid.summarize(
                    [r for r in rows if r["trace"] == tspec.to_dict()]):
                common.emit(f"{grid.name}/{tspec.name}/{label}", 0.0, value)
    return rows


# ---------------------------------------------------------------------------
# Figure-level summaries
# ---------------------------------------------------------------------------

def _best(rows, name):
    vals = [r["nag"] for r in rows if r["policy"]["policy"] == name]
    return max(vals) if vals else float("-inf")


def _improvement_vs_2nd(rows):
    acai = _best(rows, "acai")
    second = max((r["nag"] for r in rows
                  if r["policy"]["policy"] != "acai"), default=float("-inf"))
    yield ("improvement_vs_2nd",
           f"{(acai - second) / max(second, 1e-9):+.2%}")


def _improvement_per(key: str):
    """Per-sweep-point improvement summary: group the trace's rows by the
    swept field (h for fig2, cf_kth for fig3, k for fig4) and compare
    AÇAI against the tuned 2nd best *within* each point — cells computed
    under different cost models / capacities are never pooled."""

    def summarize(rows):
        by = {}
        for r in rows:
            by.setdefault(r[key], []).append(r)
        for val, rs in sorted(by.items()):
            for label, v in _improvement_vs_2nd(rs):
                yield (f"{key}{val}/{label}", v)

    return summarize


def _spread_by_policy(rows):
    """Per (policy, h): NAG spread over that policy's hyper-parameter grid
    at fixed capacity — the paper's fig5 robustness claim (AÇAI flat over
    2 orders of magnitude of eta, baselines swinging with (k', C_theta))."""
    by = {}
    for r in rows:
        by.setdefault((r["h"], r["policy"]["policy"]), []).append(r["nag"])
    for (h, name), vals in sorted(by.items()):
        spread = (max(vals) - min(vals)) / max(max(vals), 1e-9)
        yield (f"h{h}/{name}-spread", f"{spread:.3f}")


# ---------------------------------------------------------------------------
# Named grids (the eight figures + the canonical cross-policy suite)
# ---------------------------------------------------------------------------

def _acai(h, k, c_f, batch=8, **extra) -> PolicySpec:
    # c_f rides in the spec so every emitted row's policy dict is
    # self-contained (round-trips into AcaiCache / build_policy alone)
    return PolicySpec("acai", {"h": h, "k": k, "c_f": c_f, "eta": extra.pop(
        "eta", 0.05 / c_f), "batch": batch, **extra})


_SIFT = TraceSpec("sift_like")
_AMZN = TraceSpec("amazon_like")
_FLASH = TraceSpec("flash_crowd")
_ADV = TraceSpec("adversarial")


def _grid_experiments(c_f, h, k, full=False):
    """The canonical suite: every registered policy (tuned baseline
    variants collapsed to the paper's defaults) on every scenario."""
    specs = [_acai(h, k, c_f)]
    for name in ("sim_lru", "cls_lru", "rnd_lru"):
        specs.append(PolicySpec(name, {"h": h, "k": k, "k_prime": 2 * k,
                                       "c_theta": 1.5 * c_f}))
    specs += [PolicySpec("lru", {"h": h, "k": k}),
              PolicySpec("qcache", {"h": h, "k": k})]
    return specs


GRIDS: Dict[str, Grid] = {}


def _register(grid: Grid) -> Grid:
    GRIDS[grid.name] = grid
    return grid


_register(Grid(
    "experiments",
    "all registered policies × all registered scenarios (BENCH json)",
    traces=(_SIFT, _AMZN, _FLASH, _ADV),
    policies=_grid_experiments,
    summarize=lambda rows: _improvement_vs_2nd(rows)))

_register(Grid(
    "fig1", "NAG vs requests: AÇAI vs every tuned baseline",
    traces=(_SIFT, _AMZN),
    policies=lambda c_f, h, k, full: [_acai(h, k, c_f)] + _tuned_baselines(
        c_f, h, k),
    summarize=lambda rows: _improvement_vs_2nd(rows)))

_register(Grid(
    "fig2", "NAG vs cache size h",
    traces=(_SIFT,),
    policies=lambda c_f, h, k, full: [
        s for hh in _fig2_hs(full)
        for s in ([_acai(hh, k, c_f)]
                  + _tuned_baselines(c_f, hh, k,
                                     names=("sim_lru", "cls_lru"),
                                     extra=("qcache",)))],
    summarize=_improvement_per("h")))


def _grid_fig3(c_f, h, k, full=False):
    # sweep c_f implicitly: baselines C_theta tracks each c_f via the
    # tuned sweep; AÇAI's eta tracks 0.05 / c_f through the builder
    return [_acai(h, k, c_f)] + _tuned_baselines(
        c_f, h, k, names=("sim_lru", "cls_lru"), extra=())


_register(Grid(
    "fig3", "NAG vs retrieval cost c_f (c_f = avg dist to i-th neighbour)",
    traces=(_SIFT,),
    cf_kths=(2, 10, 50, 100, 500, 1000),
    policies=_grid_fig3,
    summarize=_improvement_per("cf_kth")))

_register(Grid(
    "fig4", "NAG vs answers-per-request k",
    traces=(_SIFT,),
    policies=lambda c_f, h, k, full: [
        s for kk in _fig4_ks(full)
        for s in ([_acai(h, kk, c_f, c_remote=max(64, 4 * kk),
                         c_local=max(16, kk))]
                  + _tuned_baselines(c_f, h, kk,
                                     names=("sim_lru", "cls_lru"),
                                     extra=()))],
    summarize=_improvement_per("k")))

_register(Grid(
    "fig5", "robustness: AÇAI eta sweep vs baseline (k', C_theta) grids",
    traces=(_SIFT,),
    policies=lambda c_f, h, k, full: [
        s for hh in _fig5_hs(full)
        for s in ([_acai(hh, k, c_f, eta=0.05 / c_f * m)
                   for m in (0.1, 0.3, 1.0, 3.0, 10.0)]
                  + _tuned_baselines(c_f, hh, k,
                                     names=("sim_lru", "cls_lru"),
                                     extra=()))],
    summarize=_spread_by_policy))


def _summarize_fig6(rows):
    """Per mirror map: best NAG over the eta grid + that cell's t90 (the
    paper's 'same gain in a shorter time' reactivity claim)."""
    by = {}
    for r in rows:
        by.setdefault(r["policy"].get("mirror", "negentropy"),
                      []).append(r)
    for mirror, rs in sorted(by.items()):
        best = max(rs, key=lambda r: r["nag"])
        yield (f"{mirror}/best", f"{best['nag']:.4f}")
        yield (f"{mirror}/t90", str(best["t90"]))


_register(Grid(
    "fig6", "negentropy vs euclidean mirror maps",
    traces=(_SIFT,),
    h=100, full_h=100,
    policies=lambda c_f, h, k, full: (
        [_acai(h, k, c_f, eta=e, mirror="negentropy")
         for e in (0.01 / c_f, 0.05 / c_f, 0.2 / c_f)]
        + [_acai(h, k, c_f, eta=e, mirror="euclidean")
           for e in (0.1 / (c_f * h), 0.5 / (c_f * h), 2.0 / (c_f * h))]),
    summarize=_summarize_fig6))


def _grid_fig7(c_f, h, k, full=False):
    # dissection: plain tuned baselines + their augmented twins (AÇAI's
    # serving rule over the baseline's update logic) + AÇAI
    return ([_acai(h, k, c_f)]
            + _tuned_baselines(c_f, h, k, names=("sim_lru", "cls_lru"),
                               extra=("qcache",))
            + _tuned_baselines(c_f, h, k, names=("sim_lru", "cls_lru"),
                               extra=("qcache",), augmented=True))


def _summarize_fig7(rows):
    """Paper protocol (Sec. V-C): augment the *second-best* policy only —
    the augmented twin must come from the same policy as the best plain
    baseline, or the index-vs-OMA attribution mixes update rules."""
    acai = _best(rows, "acai")
    plain = [r for r in rows if r["policy"]["policy"] != "acai"
             and not r["policy"].get("augmented")]
    best_plain_row = max(plain, key=lambda r: r["nag"], default=None)
    best_plain = best_plain_row["nag"] if best_plain_row else 0.0
    second_name = (best_plain_row["policy"]["policy"]
                   if best_plain_row else "")
    aug = [r for r in rows if r["policy"].get("augmented")
           and r["policy"]["policy"] == second_name]
    best_aug = max((r["nag"] for r in aug), default=0.0)
    total = acai - best_plain
    from_idx = max(min(best_aug - best_plain, total), 0.0)
    share = from_idx / max(total, 1e-9)
    yield ("2nd_best", f"{second_name}:{best_plain:.4f}")
    yield ("2nd+index", f"{best_aug:.4f}")
    yield ("share_from_indexes", f"{share:.2f}")
    yield ("share_from_oma", f"{1 - share:.2f}")


_register(Grid(
    "fig7", "dissection: how much of AÇAI's edge is indexes vs OMA",
    traces=(_SIFT, _AMZN),
    policies=_grid_fig7,
    summarize=_summarize_fig7))

def _summarize_fig8(rows):
    """Per rounding scheme: update traffic + occupancy concentration
    under the relaxed capacity constraint (App. G)."""
    for r in rows:
        label = (f"{r['policy']['rounding']}-M{r['policy']['round_every']}"
                 if r["policy"]["rounding"] == "depround"
                 else r["policy"]["rounding"])
        yield (f"{label}/fetches_per_req", f"{r['fetches_per_req']:.3f}")
        yield (f"{label}/occupancy",
               f"mean={r['occupancy_mean']:.1f};"
               f"p99dev={r['occupancy_p99_dev']:.3f}")


_register(Grid(
    "fig8", "rounding schemes: update cost vs reactivity",
    traces=(_AMZN,),
    policies=lambda c_f, h, k, full: [
        _acai(h, k, c_f, rounding=r, round_every=m)
        for r, m in (("coupled", 1), ("independent", 1), ("depround", 1),
                     ("depround", 20), ("depround", 100))],
    summarize=_summarize_fig8))


def list_grids() -> str:
    lines = ["registered grids:"]
    for name, g in GRIDS.items():
        lines.append(f"  {name:12s} {g.desc}")
    lines.append("registered policies: "
                 + ", ".join(PA.registered_policies()))
    lines.append("registered traces:   " + ", ".join(T.registered_traces()))
    return "\n".join(lines)


def run_named(name: str, full: bool = False, trace: str = None) -> list[dict]:
    """Entry point for the fig wrappers: run one named grid, filtered to a
    single scenario when `trace` is given (legacy sift/amazon aliases
    accepted).  A scenario outside the grid's default trace set is run
    anyway (the pre-harness fig scripts accepted any --trace), on a
    default-parameter TraceSpec of that scenario."""
    grid = GRIDS[name]
    tf = TRACE_ALIASES.get(trace, trace) if trace else None
    if tf and all(t.name != tf for t in grid.traces):
        if tf not in T.registered_traces():
            raise ValueError(T._unknown_trace_msg(tf))
        grid = dataclasses.replace(grid, traces=(TraceSpec(tf),))
    return run_grid(grid, full=full, trace_filter=tf)


def main(full: bool = False, kind: str = None) -> list[dict]:
    """The canonical `--suite experiments` run: every policy × every
    scenario, results to BENCH_experiments.json.  A --trace filter skips
    the JSON (the tracked file must always carry the full scenario
    coverage, never a silently-narrowed subset)."""
    import jax

    tf = TRACE_ALIASES.get(kind, kind)
    if tf is not None and tf not in T.registered_traces():
        raise ValueError(T._unknown_trace_msg(tf))
    rows = run_grid(GRIDS["experiments"], full=full, trace_filter=tf)
    if kind is not None:
        common.emit("experiments/json", 0.0,
                    "skipped (trace filter active; the tracked JSON only "
                    "records full-coverage runs)")
        return rows
    sz = common.sizes(full)
    BENCH_JSON.write_text(json.dumps(
        {"full": full, **sz, "backend": jax.default_backend(),
         "policies": list(PA.registered_policies()),
         # the grid's static-membership scenarios: rolling_catalog is
         # registered too, but its point is *mutation*, which this
         # harness does not perform — the churn suite (BENCH_churn.json)
         # owns that workload
         "traces": sorted({t.name for t in GRIDS["experiments"].traces}),
         "rows": rows},
        indent=2) + "\n")
    common.emit("experiments/json", 0.0, str(BENCH_JSON.name))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--grid", default="experiments", choices=sorted(GRIDS))
    ap.add_argument("--trace", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        print(list_grids())
    elif args.grid == "experiments":
        main(args.full, args.trace)
    else:
        run_named(args.grid, args.full, args.trace)
