"""Training driver: end-to-end on any --arch (smoke sizes on CPU, full on
a real mesh), with checkpoint/restart fault tolerance and straggler
monitoring.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.configs.shapes import ShapeSpec
from repro.train import OptConfig, init_train_state, make_train_step
from repro.train.batching import synthetic_batch
from repro.train.data import Prefetcher, SyntheticDataset
from repro.train.fault import StragglerMonitor, TrainLoop
from repro.train import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    shape = ShapeSpec("train", args.seq_len, args.batch, "train")
    print(f"arch={cfg.name} params={cfg.param_count():,} "
          f"(active {cfg.active_param_count():,}) opt={cfg.optimizer}")

    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg)
    step_fn = jax.jit(make_train_step(
        cfg, OptConfig(name=cfg.optimizer, lr=args.lr), accum=args.accum))

    dataset = SyntheticDataset(cfg, shape)
    monitor = StragglerMonitor()

    if args.ckpt_dir:
        def loop_step(state, batch, step):
            p, o = state["params"], state["opt"]
            p, o, metrics = step_fn(p, o, batch, step)
            if step % args.log_every == 0:
                print(f"step {step}: loss={float(metrics.loss):.4f} "
                      f"gnorm={float(metrics.grad_norm):.3f}")
            return {"params": p, "opt": o}

        loop = TrainLoop(loop_step, {"params": params, "opt": opt_state},
                         args.ckpt_dir, ckpt_every=args.ckpt_every,
                         monitor=monitor)
        loop.run(args.steps, lambda s: dataset.batch(s))
        print(f"done; restarts={loop.restarts} "
              f"stragglers={len(monitor.flagged)}")
        return

    prefetcher = Prefetcher(dataset, prefetch=2)
    losses = []
    t0 = time.time()
    try:
        for i in range(args.steps):
            step, batch = prefetcher.next()
            ts = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch, step)
            jax.block_until_ready(metrics.loss)
            monitor.record(step, time.time() - ts)
            losses.append(float(metrics.loss))
            if i % args.log_every == 0:
                print(f"step {i}: loss={losses[-1]:.4f} "
                      f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    finally:
        prefetcher.stop()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"median step {np.median(np.diff([0] + [time.time()])):.3f}s")


if __name__ == "__main__":
    main()
