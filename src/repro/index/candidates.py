"""Candidate-set builders wiring the ANN indexes into the AÇAI policy.

Batched-first (DESIGN.md §6): `index_candidate_fn_batched` maps a whole
request mini-batch (B, d) to (ids, dists, valid) of shape (B, C); the
per-request `index_candidate_fn` (same signature as
repro.core.policy.exact_candidate_fn: fn(r, x) -> ids (C,), dists (C,),
valid (C,)) is just its B = 1 view, so sequential and batched replays share
one code path bit-for-bit.

Remote candidates come from the (approximate) remote-catalog index with a
*single* exact re-rank of the retrieved ids through the fused
gather+L2+top-k scan (AÇAI evaluates true costs on the retrieved set).
Local candidates are a top-k over *only the cached rows*: the cache
indicator x is turned into an id list (one O(N) mask pass, no distance
arithmetic), those ≤ local_cap embeddings are gathered once for the whole
batch, and a (B, cap) distance GEMM + top-k picks the candidates — the
full-catalog O(N·d) per-request scan of the seed implementation is gone.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.costs import BIG_COST
from repro.core.policy import dedup_mask_batched, per_request_view
from repro.index.base import track_jit
from repro.kernels import ops


def _local_cap(n: int, c_local: int, h: int | None, local_cap: int | None) -> int:
    """Static bound on how many cached rows the local scan gathers.

    DepRound keeps occupancy at exactly h; coupled/independent rounding
    concentrate around h (App. F), so 2h + a small slack covers every
    rounding mode.  Without h we fall back to a generous multiple of
    c_local — but callers should pass h (or local_cap): if occupancy ever
    exceeds the cap, `nonzero` silently keeps the lowest-id cached rows,
    hiding the rest from local serving (quality loss, not an error).  The
    truncation is observable: the candidate fns built here carry the cap
    as `fn.local_cap`, and the policy step books max(0, occupancy - cap)
    into `StepMetrics.local_overflow` when `AcaiConfig.debug` is on."""
    if local_cap is not None:
        return min(n, local_cap)
    if h is not None:
        return min(n, 2 * h + 64)
    return min(n, max(8 * c_local, 512))


def index_candidate_fn_batched(
    index, catalog: jax.Array, c_remote: int, c_local: int,
    h: int | None = None, local_cap: int | None = None,
):
    """Build the batched candidate generator backed by an ANN index.

    Args:
      index: remote-catalog index exposing `query(rs (B, d), k) ->
        (dists (B, k), ids (B, k))` with -1 marking underflow, and
        optionally `exact_distances = True` when its distances are already
        exact on `catalog` (skips the re-rank).
      catalog: (N, d) float32 shared embedding table.
      c_remote: remote-index candidates per request (>= cfg.k).
      c_local: cached-row candidates per request.
      h: cache capacity — sizes the static cached-row gather to 2h + 64
        (see `_local_cap`); pass it (or `local_cap`) whenever known.
      local_cap: explicit override of the cached-row gather bound.

    Returns:
      fn(rs (B, d), x (N,)) -> (ids (B, C), dists (B, C), valid (B, C))
      with C = c_remote + c_local (DESIGN.md §4 slab layout): int32
      candidate ids (n marks an invalid slot), float32 exact distances
      (BIG_COST on invalid slots), bool validity after cross-slab dedup.
      Compatible with `repro.core.policy.make_step_batched` /
      `make_replay_batched` and the B = 1 `per_request_view`.
    """
    n = catalog.shape[0]
    cap = _local_cap(n, c_local, h, local_cap)

    # Indexes whose query() already returns exact distances on the shared
    # catalog embeddings (FlatIndex, IVFFlat, LSH, NSW, IVFPQ with refine)
    # advertise `exact_distances = True`; only approximate-distance indexes
    # (e.g. refine-less IVFPQ ADC) pay the exact re-rank.
    rerank = not getattr(index, "exact_distances", False)

    def fn(rs: jax.Array, x: jax.Array):
        b = rs.shape[0]
        d_remote, ids_remote = index.query(rs, c_remote)     # (B, c_remote)
        if rerank:
            # single exact re-rank of the retrieved candidates (fused
            # scan); -1 misses stay -1 (dist = +inf) through the scan.
            d_remote, ids_remote = ops.ivf_scan_auto(
                rs, catalog, ids_remote, c_remote
            )
        rmiss = ids_remote < 0
        ids_remote = jnp.where(rmiss, n, ids_remote)         # n = invalid
        d_remote = jnp.where(rmiss, BIG_COST, d_remote)

        # local side: gather the cached rows *once* (shared across the
        # batch), then one (B, cap) distance GEMM + top-k — never an (N,)
        # distance scan, and no per-query duplicate of the cached slab.
        cached = jnp.nonzero(x > 0.5, size=cap, fill_value=-1)[0]  # (cap,)
        cached_embs = catalog[jnp.clip(cached, 0, n - 1)]          # (cap, d)
        d_loc = ops.pairwise_l2_xla(rs, cached_embs)               # (B, cap)
        d_loc = jnp.where((cached >= 0)[None, :], d_loc, jnp.inf)
        neg, pos = jax.lax.top_k(-d_loc, c_local)
        ids_local = jnp.where(jnp.isfinite(neg), cached[pos], -1)
        d_local = -neg
        lmiss = ids_local < 0
        ids_local = jnp.where(lmiss, n, ids_local)
        d_local = jnp.where(lmiss, BIG_COST, d_local)

        ids = jnp.concatenate([ids_remote, ids_local], axis=1)
        d = jnp.concatenate([d_remote, d_local], axis=1)
        valid = dedup_mask_batched(ids, n)
        d = jnp.where(valid, d, BIG_COST)
        return ids, d, valid

    fn.local_cap = cap  # static cached-row bound, read by the policy step
    return fn


def index_candidate_fn(
    index, catalog: jax.Array, c_remote: int, c_local: int,
    h: int | None = None, local_cap: int | None = None,
):
    """Per-request view of index_candidate_fn_batched (B = 1)."""
    return per_request_view(index_candidate_fn_batched(
        index, catalog, c_remote, c_local, h=h, local_cap=local_cap
    ))


# ---------------------------------------------------------------------------
# Mutable-catalog candidate generation (DESIGN.md §10)
# ---------------------------------------------------------------------------

@track_jit("assemble_mutable_slab")
@partial(jax.jit, static_argnames=("c_local", "cap", "c_remote", "rerank"))
def _assemble_mutable_slab(rs, x, catalog, alive, ids_remote, d_remote,
                           c_local: int, cap: int, c_remote: int,
                           rerank: bool):
    """Jitted slab assembly behind `mutable_index_candidate_fn`: takes the
    index's (eagerly computed) remote candidates plus the live catalog
    slab/mask as runtime arguments and produces the standard candidate
    slab (ids, dists, valid) — same layout as index_candidate_fn_batched,
    with tombstoned rows resolved to invalid slots throughout."""
    n = catalog.shape[0]
    b = rs.shape[0]
    if rerank:
        # exact re-rank of approximate-distance retrievals, tombstones
        # folded to -1 inside the fused scan
        d_remote, ids_remote = ops.ivf_scan_auto(
            rs, catalog, ids_remote, c_remote, alive)
    else:
        # the index's own masking already excludes tombstones; belt and
        # braces for foreign indexes that predate the mutation contract
        dead = (ids_remote >= 0) & ~alive[jnp.clip(ids_remote, 0, n - 1)]
        ids_remote = jnp.where(dead, -1, ids_remote)
        d_remote = jnp.where(dead, jnp.inf, d_remote)
    rmiss = ids_remote < 0
    ids_remote = jnp.where(rmiss, n, ids_remote)             # n = invalid
    d_remote = jnp.where(rmiss, BIG_COST, d_remote)

    # local side: identical to the static generator — the x(dead) = 0
    # invalidation invariant keeps tombstoned rows out of the gather
    cached = jnp.nonzero(x > 0.5, size=cap, fill_value=-1)[0]    # (cap,)
    cached_embs = catalog[jnp.clip(cached, 0, n - 1)]            # (cap, d)
    d_loc = ops.pairwise_l2_xla(rs, cached_embs)                 # (B, cap)
    ok = (cached >= 0) & alive[jnp.clip(cached, 0, n - 1)]
    d_loc = jnp.where(ok[None, :], d_loc, jnp.inf)
    neg, pos = jax.lax.top_k(-d_loc, c_local)
    ids_local = jnp.where(jnp.isfinite(neg), cached[pos], -1)
    d_local = -neg
    lmiss = ids_local < 0
    ids_local = jnp.where(lmiss, n, ids_local)
    d_local = jnp.where(lmiss, BIG_COST, d_local)

    ids = jnp.concatenate([ids_remote, ids_local], axis=1)
    d = jnp.concatenate([d_remote, d_local], axis=1)
    valid = dedup_mask_batched(ids, n)
    d = jnp.where(valid, d, BIG_COST)
    return ids, d, valid


def mutable_index_candidate_fn(
    index, c_remote: int, c_local: int,
    h: int | None = None, local_cap: int | None = None,
):
    """Mutable-catalog candidate generator over an ANN index.

    The static `index_candidate_fn_batched` closes over the index arrays
    at trace time, so a mutated index would serve stale candidates from a
    cached jit.  This variant runs in two stages per step instead: the
    index's `query` (itself jitted over its *runtime* structure arrays)
    executes eagerly, then `_assemble_mutable_slab` builds the candidate
    slab with the current embedding slab + liveness mask as arguments —
    zero retraces under churn at fixed capacity, one on each capacity
    doubling.

    Returns fn(rs (B, d), x (N,)) -> (ids, dists, valid) with the same
    slab conventions as `index_candidate_fn_batched` (N = the slab
    capacity, which `fn` reads from the live index on every call).
    Carries `local_cap` like the static generator so the debug overflow
    counter keeps working.
    """
    rerank = not getattr(index, "exact_distances", False)

    def fn(rs: jax.Array, x: jax.Array):
        d_remote, ids_remote = index.query(rs, c_remote)
        cap = _local_cap(index.capacity, c_local, h, local_cap)
        return _assemble_mutable_slab(
            rs, x, index.embeddings, index.valid, ids_remote, d_remote,
            c_local, cap, c_remote, rerank)

    fn.local_cap = _local_cap(index.capacity, c_local, h, local_cap)
    return fn
