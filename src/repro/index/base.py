"""Unified index API: protocol, spec, and config-driven backend registry.

AÇAI's central design choice (paper Sec. III) is that the *same* policy
machinery works over any approximate index for the catalog — the index
only shapes the candidate-set quality/cost trade-off.  This module makes
that pluggability first-class:

* `Index` — the batched query protocol every backend implements.  A whole
  request mini-batch goes down in one call, so the policy's batched
  pipeline (DESIGN.md §6) never loops over requests.
* `IndexSpec` — a serializable (backend name + kwargs) description of an
  index, the one config knob that selects a backend end-to-end: in
  `AcaiConfig.index`, `SemanticCachedLM(index_spec=...)`,
  `launch/serve.py --remote-index/--index-opt`, the `backends` benchmark
  suite, and dry-run provenance records.
* `build_index(spec, catalog, mesh=None)` — the registry constructor.
  Single-device backends (`flat | ivf | ivfpq | lsh | nsw`) build from
  the catalog alone; sharded backends (`ivf_sharded`) additionally take
  the device mesh and return the structure the sharded step consumes
  (`repro.core.distributed.ShardedIVF`).

Backends register themselves via `register_backend`, so adding a new one
is a single registration — no cross-cutting edits in core/serve/launch.

Mutable catalogs (DESIGN.md §10): every registered backend additionally
implements the batched mutation contract —

* `add(vectors (B, d)) -> (B,) int32 row ids` — online insertion.  New
  rows are *appended* at the slab high-water mark (flat append, IVF/LSH
  list append with capacity doubling, IVF-PQ encode-on-insert, NSW
  incremental linking); row ids are assigned monotonically and **never
  recycled**, so stale references (policy state, payload tables, cached
  entries) can never alias a new object.
* `remove(ids)` — online expiry via tombstones: the rows flip in the
  (capacity,) `valid` mask, auxiliary structures are untouched, and every
  query path masks tombstoned rows so they can never surface (the fused
  scans fold them into their -1 invalid-slot convention).
* `refresh()` — periodic rebuild: re-derive the auxiliary structures
  (quantizers, inverted lists, buckets, graphs, entry points) from the
  live rows only, restoring fresh-build recall after heavy churn.  Row
  ids are stable across refresh (the slab is not compacted) — only the
  structures are.

Query paths take the mutable arrays (slab, mask, tables) as *runtime*
jit arguments, so add/remove/refresh at fixed capacity never retrace;
recompilation happens only when the capacity-doubling growth changes
array shapes — O(log growth) times over an index's lifetime.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class Index(Protocol):
    """Batched ANN index over a fixed catalog of `n` embeddings.

    Required surface (the conformance contract pinned by
    tests/test_index_api.py):

    * `query(rs (B, d), k) -> (dists (B, k), ids (B, k))` — per-query k
      best candidates, ascending float32 squared distances, int32 catalog
      row ids; **-1 marks underflow** (fewer than k real candidates, dist
      = +inf on those slots).  Must accept a whole request mini-batch —
      a (d,) vector is promoted to B = 1.
    * `exact_distances: bool` — True when returned distances are exact on
      the shared catalog embeddings, letting
      `repro.index.candidates.index_candidate_fn_batched` skip its exact
      re-rank.
    * `n: int` — catalog size (number of indexed objects).
    * `memory_bytes() -> int` — resident bytes of the index structures
      (embedding slab + tables/codes), the cost side of the paper's
      quality/cost trade-off.

    Mutation surface (implemented by every registered single-device
    backend; see the module docstring and tests/test_mutable_index.py):

    * `add(vectors (B, d)) -> (B,) int32 row ids` — append new objects.
    * `remove(ids)` — tombstone rows (mask-only; ids never recycled).
    * `refresh()` — rebuild auxiliary structures over the live rows.
    * `valid: (capacity,) bool` — the tombstone mask; `capacity: int` and
      `n_slots: int` (high-water mark) describe the slab; `n` counts
      *live* rows only.

    Optional: `shard(mesh)` — return a mesh-sharded equivalent consumed by
    `repro.core.distributed.make_step_sharded` (today only the IVF family
    implements the sharded layout, via the `ivf_sharded` backend).
    """

    exact_distances: bool

    def query(self, rs: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
        ...

    @property
    def n(self) -> int:
        ...

    def memory_bytes(self) -> int:
        ...


def arrays_bytes(*arrays) -> int:
    """Sum of .nbytes over the given arrays (None entries skipped)."""
    return int(sum(a.size * a.dtype.itemsize for a in arrays if a is not None))


def check_finite_queries(rs, where: str) -> None:
    """Query-path input hygiene: reject NaN/Inf query vectors with a clear
    error instead of letting them corrupt top-k and OMA state (a NaN query
    makes every distance NaN, which silently breaks the top-k masking and
    then poisons the subgradient forever).

    Host-side check only: under `jax.jit` tracing (the candidate
    generators call `Index.query` inside traced functions) the values are
    abstract, so the check is skipped — eager entry points
    (`AcaiCache.serve_update*`, `BaselinePolicy.serve_update*`, direct
    backend queries) are where poisoned inputs actually enter."""
    if isinstance(rs, jax.core.Tracer):
        return
    a = np.asarray(rs)
    if np.issubdtype(a.dtype, np.floating) and not np.isfinite(a).all():
        rows = (np.nonzero(~np.isfinite(a.reshape(a.shape[0], -1)).all(
            axis=1))[0].tolist() if a.ndim > 1 else [0])
        raise ValueError(
            f"{where}: query vector(s) contain NaN/Inf (rows {rows}) — "
            f"refusing to serve; sanitize the embedding upstream (a NaN "
            f"query would corrupt top-k and OMA state)")


# ---------------------------------------------------------------------------
# Mutable-catalog slab machinery (DESIGN.md §10)
# ---------------------------------------------------------------------------

def grow_capacity(n_slots: int, needed: int, cap: int) -> int:
    """Capacity-doubling growth schedule: the smallest power-of-two-style
    doubling of `cap` that fits `n_slots + needed` rows.  Doubling keeps
    reallocation (and the shape-driven jit retrace that comes with it)
    amortised O(log growth) over an index's lifetime."""
    new_cap = max(cap, 1)
    while n_slots + needed > new_cap:
        new_cap *= 2
    return new_cap


def slab_append(emb: jax.Array, valid: jax.Array, n_slots: int,
                vectors) -> Tuple[jax.Array, jax.Array, np.ndarray]:
    """Append rows to a capacity slab, growing by doubling when full.

    Args:
      emb: (cap, d) float32 embedding slab (rows >= n_slots are unused).
      valid: (cap,) bool liveness mask (False on unused + tombstoned rows).
      n_slots: current high-water mark (rows ever assigned).
      vectors: (B, d) new embeddings.

    Returns:
      (emb', valid', ids): the (possibly grown) slab and mask with the new
      rows written and marked live, plus their assigned row ids
      (np.int32 (B,), = arange(n_slots, n_slots + B)).  Ids are never
      recycled — tombstoned slots stay dead until a full rebuild.
    """
    vectors = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
    b = vectors.shape[0]
    cap = emb.shape[0]
    if n_slots + b > cap:
        new_cap = grow_capacity(n_slots, b, cap)
        emb = jnp.pad(emb, ((0, new_cap - cap), (0, 0)))
        valid = jnp.pad(valid, (0, new_cap - cap), constant_values=False)
    ids = np.arange(n_slots, n_slots + b, dtype=np.int32)
    emb = emb.at[ids].set(vectors)
    valid = valid.at[ids].set(True)
    return emb, valid, ids


class MutableRows:
    """Capacity-slab + tombstone bookkeeping shared by every backend.

    Owns `embeddings` (capacity, d), `valid` (capacity,) bool, the
    high-water mark `n_slots` and the live count `n` — the state side of
    the mutation contract.  Backends call `_append_rows` from `add` (slab
    growth + id assignment) and `_tombstone_rows` from `remove`, and add
    their structure-specific bookkeeping on top.
    """

    embeddings: jax.Array
    valid: jax.Array

    def _init_rows(self, embeddings) -> None:
        self.embeddings = jnp.atleast_2d(
            jnp.asarray(embeddings, jnp.float32))
        self._n_slots = int(self.embeddings.shape[0])
        self._live = self._n_slots
        self.valid = jnp.ones((self._n_slots,), bool)

    @property
    def n(self) -> int:
        """Live (indexed, non-tombstoned) objects."""
        return self._live

    @property
    def capacity(self) -> int:
        """Slab rows allocated (= the id-space bound every query result
        respects; grows by doubling)."""
        return int(self.embeddings.shape[0])

    @property
    def n_slots(self) -> int:
        """High-water mark: rows ever assigned (live + tombstoned)."""
        return self._n_slots

    def live_rows(self) -> np.ndarray:
        """Row ids of the live objects, ascending (refresh rebuilds walk
        this order so a refreshed structure matches a fresh build on the
        live rows modulo the id remap)."""
        return np.nonzero(np.asarray(self.valid))[0]

    def _append_rows(self, vectors) -> np.ndarray:
        self.embeddings, self.valid, ids = slab_append(
            self.embeddings, self.valid, self._n_slots, vectors)
        self._n_slots += len(ids)
        self._live += len(ids)
        return ids

    def _tombstone_rows(self, ids) -> np.ndarray:
        ids = np.atleast_1d(np.asarray(ids, np.int32))
        if len(ids) == 0:
            return ids
        if ids.min() < 0 or ids.max() >= self._n_slots:
            raise ValueError(
                f"remove: ids must be assigned rows in [0, {self._n_slots});"
                f" got range [{ids.min()}, {ids.max()}]")
        alive = np.asarray(self.valid[ids])
        if not alive.all():
            raise ValueError(
                f"remove: rows {ids[~alive].tolist()} are already dead "
                f"(tombstoned or never assigned)")
        if len(np.unique(ids)) != len(ids):
            raise ValueError("remove: duplicate ids in one batch")
        self.valid = self.valid.at[ids].set(False)
        self._live -= len(ids)
        return ids

    def add(self, vectors) -> np.ndarray:
        """Default `add`: slab append only (structure-free backends)."""
        return self._append_rows(vectors)

    def remove(self, ids) -> None:
        """Tombstone `ids` (mask-only: every query path filters through
        `valid`, so the rows can never surface again)."""
        self._tombstone_rows(ids)

    def refresh(self) -> None:
        """Default refresh: nothing to rebuild (mask-exact backends)."""


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Serializable index selection: backend name + build kwargs.

    `params` are passed verbatim to the registered builder (after the
    catalog / mesh), so valid keys are exactly the builder's keyword
    arguments — e.g. ``IndexSpec("ivf", {"nlist": 256, "nprobe": 16})``.

    Round-trips through a flat dict (`to_dict` / `from_dict`) so a spec
    can live in CLI flags, benchmark rows and dry-run records:
    ``{"backend": "ivf", "nlist": 256, "nprobe": 16}``.
    """

    backend: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))
        if "backend" in self.params:
            raise ValueError("'backend' is the spec field, not a param")

    def __hash__(self):  # params is a dict; hash the canonical item tuple
        return hash((self.backend, tuple(sorted(self.params.items()))))

    def to_dict(self) -> Dict[str, Any]:
        """Flat dict form: {'backend': name, **params}."""
        return {"backend": self.backend, **self.params}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "IndexSpec":
        d = dict(d)
        try:
            backend = d.pop("backend")
        except KeyError:
            raise ValueError(f"index spec dict needs a 'backend' key: {d}")
        spec = cls(backend, d)
        if backend not in _REGISTRY:
            raise ValueError(_unknown_backend_msg(backend))
        return spec

    def with_params(self, **updates) -> "IndexSpec":
        return IndexSpec(self.backend, {**self.params, **updates})


@dataclasses.dataclass(frozen=True)
class _Backend:
    build: Callable
    sharded: bool  # builder signature is (catalog, mesh, **params)


_REGISTRY: Dict[str, _Backend] = {}


def register_backend(name: str, *, sharded: bool = False):
    """Decorator registering `fn(catalog, **params)` (or
    `fn(catalog, mesh, **params)` when sharded=True) under `name`."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"index backend {name!r} already registered")
        _REGISTRY[name] = _Backend(fn, sharded)
        return fn

    return deco


def registered_backends(*, sharded: bool | None = None) -> Tuple[str, ...]:
    """Sorted backend names; filter by shardedness when given."""
    return tuple(sorted(
        name for name, b in _REGISTRY.items()
        if sharded is None or b.sharded == sharded
    ))


def _unknown_backend_msg(name: str) -> str:
    return (f"unknown index backend {name!r}; registered: "
            f"{', '.join(registered_backends())}")


def build_index(spec: IndexSpec, catalog: jax.Array, mesh=None):
    """Construct the index a spec describes over `catalog`.

    Single-device backends ignore `mesh`; sharded backends require it
    (their layout depends on the mesh's `model` axis size).  Unknown
    backends and bad params raise ValueError/TypeError at build time —
    before any jit tracing.
    """
    if isinstance(spec, Mapping):  # accept the flat-dict form directly
        spec = IndexSpec.from_dict(spec)
    try:
        backend = _REGISTRY[spec.backend]
    except KeyError:
        raise ValueError(_unknown_backend_msg(spec.backend))
    if backend.sharded:
        if mesh is None:
            raise ValueError(
                f"index backend {spec.backend!r} is sharded: build_index "
                f"needs the device mesh (mesh=...)")
        return backend.build(catalog, mesh, **spec.params)
    return backend.build(catalog, **spec.params)


# Reserved spec-less backend name: "exact" means *no* index — the policy's
# perfect-recall exact candidate generator (one GEMM feeding both slabs).
# It is deliberately not in the registry (there is nothing to build);
# surfaces that accept serialized specs resolve it through `resolve_spec`.
EXACT = "exact"


def resolve_spec(value) -> "IndexSpec | None":
    """Normalize any user-facing spec form to IndexSpec-or-None.

    Accepts None, an IndexSpec, a backend-name string, or the flat dict
    form — with the reserved name "exact" (however spelled) mapping to
    None, so provenance records like ``{"backend": "exact"}`` round-trip
    through every surface (SemanticCachedLM, CLI, dryrun records).
    """
    if isinstance(value, IndexSpec):
        if value.backend != EXACT:
            if value.backend not in _REGISTRY:
                raise ValueError(_unknown_backend_msg(value.backend))
            return value
        if value.params:
            raise ValueError(
                f"'exact' takes no params (it is the spec-less exact "
                f"candidate generator): {value.params}")
        return None
    if value is None:
        return None
    if isinstance(value, str):
        if value == EXACT:
            return None
        if value not in _REGISTRY:
            raise ValueError(_unknown_backend_msg(value))
        return IndexSpec(value)
    if isinstance(value, Mapping):
        if value.get("backend") == EXACT:
            if len(value) > 1:
                raise ValueError(
                    f"'exact' takes no params (it is the spec-less exact "
                    f"candidate generator): {dict(value)}")
            return None
        return IndexSpec.from_dict(value)
    raise TypeError(f"cannot resolve an index spec from {value!r}")


def parse_index_opts(opts) -> Dict[str, Any]:
    """Parse CLI `--index-opt key=value` pairs into builder kwargs.

    Values are coerced int -> float -> str in that order, so
    `nlist=256 refine=0 kernel=xla` all land with their natural types.
    """
    out: Dict[str, Any] = {}
    for opt in opts or ():
        key, sep, val = opt.partition("=")
        if not sep or not key:
            raise ValueError(
                f"--index-opt expects key=value, got {opt!r}")
        for cast in (int, float):
            try:
                out[key] = cast(val)
                break
            except ValueError:
                continue
        else:
            out[key] = val
    return out


# ---------------------------------------------------------------------------
# Backend registrations.  Builders are thin closures over the class
# constructors so the registry maps spec params 1:1 onto constructor
# kwargs; `ivf_sharded` imports lazily (repro.core.distributed imports the
# policy layer, which imports this module).
# ---------------------------------------------------------------------------

@register_backend("flat")
def _build_flat(catalog, **kw):
    from repro.index.exact import FlatIndex

    return FlatIndex(catalog, **kw)


@register_backend("ivf")
def _build_ivf(catalog, **kw):
    from repro.index.ivf import IVFFlatIndex

    return IVFFlatIndex(catalog, **kw)


@register_backend("ivfpq")
def _build_ivfpq(catalog, **kw):
    from repro.index.pq import IVFPQIndex

    return IVFPQIndex(catalog, **kw)


@register_backend("lsh")
def _build_lsh(catalog, **kw):
    from repro.index.lsh import LSHIndex

    return LSHIndex(catalog, **kw)


@register_backend("nsw")
def _build_nsw(catalog, **kw):
    from repro.index.nsw import NSWIndex

    return NSWIndex(catalog, **kw)


@register_backend("ivf_sharded", sharded=True)
def _build_ivf_sharded(catalog, mesh, *, model_axis: str = "model", **kw):
    """Per-shard IVF for the sharded serving path: one coarse quantizer +
    inverted-list table per catalog shard on the mesh's `model` axis.
    Returns `repro.core.distributed.ShardedIVF` (consumed via
    `make_step_sharded(ivf=...)` / `AcaiCache(mesh=..., cfg.index=...)`)."""
    from repro.core.distributed import build_sharded_ivf

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if model_axis not in sizes:
        raise ValueError(
            f"ivf_sharded shards over mesh axis {model_axis!r}, but the "
            f"mesh has axes {tuple(sizes)} — pass model_axis=<axis name> "
            f"in the spec params or rename the mesh axis")
    return build_sharded_ivf(catalog, sizes[model_axis], **kw)


# Smallest sensible build kwargs per single-device backend (seconds to
# build on a few-hundred-row catalog).  The single source of truth for
# the conformance test (tests/test_index_api.py) and the scripts/smoke.sh
# sweep — a new backend registers here once and both pick it up.
TINY_BUILD_KWARGS = {
    "flat": {},
    "ivf": {"nlist": 8, "nprobe": 4},
    "ivfpq": {"nlist": 8, "nprobe": 4, "m": 4, "refine": 4},
    "lsh": {"tables": 4, "bits": 5},
    "nsw": {"degree": 8, "beam": 16, "steps": 8},
}
