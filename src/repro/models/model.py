"""Model assembly: embedding -> (scanned) layer stack -> head, for all ten
assigned architectures, with train (no-cache), prefill and decode paths.

Scan strategy: layers are grouped into repeating *units* so heterogeneous
stacks still scan (compile time stays flat in depth):
  dense / mixtral / mamba2        unit = 1 layer
  deepseek-v3                     3 dense-FFN MLA layers unrolled (prefix),
                                  58 MoE MLA layers scanned
  jamba                           unit = 8 layers (7 mamba + 1 attn at slot
                                  4, MoE on odd slots), 9 units scanned

Params are nested dicts; layer kinds are static (derived from cfg), so the
scan body is homogeneous per unit.  Caches thread through the scan as
stacked pytrees.  Remat wraps the unit body for training.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.sharding.ctx import annotate, mesh_active


# --------------------------------------------------------------------------
# Layer-kind schedule
# --------------------------------------------------------------------------

class UnitSpec(NamedTuple):
    kinds: tuple            # tuple of (mixer_kind, ffn_kind) per slot
    n_prefix: int           # unrolled prefix layers
    n_units: int            # scanned units


def _mixer_kind(cfg: ModelConfig, i: int) -> str:
    if not cfg.is_attn_layer(i):
        return "mamba"
    return "mla" if cfg.attn_type == "mla" else "attn"


def _ffn_kind(cfg: ModelConfig, i: int) -> str:
    if cfg.is_moe_layer(i):
        return "moe"
    return "dense" if cfg.d_ff else "none"  # pure mamba2 blocks have no FFN


def unit_spec(cfg: ModelConfig) -> UnitSpec:
    kinds = [(_mixer_kind(cfg, i), _ffn_kind(cfg, i))
             for i in range(cfg.n_layers)]
    n_prefix = cfg.moe_layer_start if cfg.n_experts else 0
    body = kinds[n_prefix:]
    # the smallest period that tiles the body becomes the scan unit
    for u in range(1, len(body) + 1):
        if len(body) % u:
            continue
        unit = tuple(body[:u])
        if all(tuple(body[j:j + u]) == unit for j in range(0, len(body), u)):
            return UnitSpec(kinds=unit, n_prefix=n_prefix,
                            n_units=len(body) // u)
    raise AssertionError("unreachable: the full body is always a period")


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind) -> dict:
    mixer_kind, ffn_kind = kind
    k1, k2 = jax.random.split(key)
    dt = L.dtype_of(cfg)
    p: dict[str, Any] = {
        "norm1": jnp.ones((cfg.d_model,), dt),
        "norm2": jnp.ones((cfg.d_model,), dt),
    }
    if mixer_kind == "attn":
        p["mixer"] = L.init_attention(k1, cfg)
    elif mixer_kind == "mla":
        p["mixer"] = MLA.init_mla(k1, cfg)
    else:
        p["mixer"] = SSM.init_mamba(k1, cfg)
    if ffn_kind == "moe":
        p["ffn"] = MOE.init_moe(k2, cfg)
    elif ffn_kind == "dense":
        p["ffn"] = L.init_mlp(k2, cfg)
    else:
        del p["norm2"]  # no FFN sub-block at all
    return p


def _init_unit(key, cfg: ModelConfig, kinds) -> dict:
    ks = jax.random.split(key, len(kinds))
    return {f"slot{j}": _init_layer(ks[j], cfg, kinds[j])
            for j in range(len(kinds))}


def init_params(key, cfg: ModelConfig) -> dict:
    spec = unit_spec(cfg)
    dt = L.dtype_of(cfg)
    keys = jax.random.split(key, 4 + spec.n_prefix + spec.n_units)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab, dt)
    kinds_all = [(_mixer_kind(cfg, i), _ffn_kind(cfg, i))
                 for i in range(spec.n_prefix)]
    if spec.n_prefix:
        params["prefix"] = {
            f"layer{i}": _init_layer(keys[2 + i], cfg, kinds_all[i])
            for i in range(spec.n_prefix)
        }
    unit_params = [
        _init_unit(keys[2 + spec.n_prefix + u], cfg, spec.kinds)
        for u in range(spec.n_units)
    ]
    params["body"] = jax.tree.map(lambda *xs: jnp.stack(xs), *unit_params)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": L.dense_init(keys[3], 2 * cfg.d_model, cfg.d_model, dt),
            "block": _init_layer(keys[3], cfg, (
                _mixer_kind(cfg, cfg.n_layers - 1), "dense")),
            "norm": jnp.ones((cfg.d_model,), dt),
        }
    return params


# --------------------------------------------------------------------------
# Caches
# --------------------------------------------------------------------------

def _init_layer_cache(cfg: ModelConfig, kind, batch: int, s_max: int, dtype):
    mixer_kind, _ = kind
    if mixer_kind == "attn":
        t = min(s_max, cfg.sliding_window) if cfg.sliding_window else s_max
        return {
            "k": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    if mixer_kind == "mla":
        return MLA.init_mla_cache(cfg, batch, s_max, dtype)
    return SSM.init_mamba_cache(cfg, batch, dtype)


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    dt = L.dtype_of(cfg)
    spec = unit_spec(cfg)
    cache: dict[str, Any] = {}
    if spec.n_prefix:
        kinds_all = [(_mixer_kind(cfg, i), _ffn_kind(cfg, i))
                     for i in range(spec.n_prefix)]
        cache["prefix"] = {
            f"layer{i}": _init_layer_cache(cfg, kinds_all[i], batch, s_max, dt)
            for i in range(spec.n_prefix)
        }
    unit_cache = {
        f"slot{j}": _init_layer_cache(cfg, spec.kinds[j], batch, s_max, dt)
        for j in range(len(spec.kinds))
    }
    cache["body"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (spec.n_units,) + x.shape).copy()
        if spec.n_units else x[None][0:0],
        unit_cache,
    )
    return cache


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _apply_layer(p, kind, x, positions, cfg: ModelConfig, cache, cache_len,
                 positions3):
    mixer_kind, ffn_kind = kind
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer_kind == "attn":
        y, new_cache = L.attention(p["mixer"], h, positions, cfg,
                                   cache=cache, cache_len=cache_len,
                                   positions3=positions3)
    elif mixer_kind == "mla":
        y, new_cache = MLA.mla_attention(p["mixer"], h, positions, cfg,
                                         cache=cache, cache_len=cache_len)
    else:
        y, new_cache = SSM.mamba_mixer(p["mixer"], h, cfg, cache=cache)
    x = x + y
    if ffn_kind == "none":
        return x, new_cache, jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    if ffn_kind == "moe":
        y, aux = MOE.moe_ffn(p["ffn"], h, cfg)
    else:
        y, aux = L.mlp(p["ffn"], h), jnp.zeros((), jnp.float32)
    return x + y, new_cache, aux


def _apply_unit(p_unit, kinds, x, positions, cfg, cache_unit, cache_len,
                positions3):
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    for j, kind in enumerate(kinds):
        c = None if cache_unit is None else cache_unit[f"slot{j}"]
        x, nc, aux = _apply_layer(p_unit[f"slot{j}"], kind, x, positions,
                                  cfg, c, cache_len, positions3)
        if cache_unit is not None:
            new_caches[f"slot{j}"] = nc
        aux_total = aux_total + aux
    return x, (new_caches if cache_unit is not None else None), aux_total


def embed_lookup(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    """Token-embedding lookup.

    Under a mesh context the vocab axis is `model`-sharded; a plain gather
    makes the SPMD partitioner replicate the whole table ("involuntary full
    rematerialization", ~TBs of all-reduce on the large-vocab archs).  The
    TPU-idiomatic form is a one-hot contraction: each shard contracts its
    vocab slice locally and one small (tokens, d) all-reduce combines —
    §Perf 'embed-onehot' iteration."""
    if not mesh_active():
        return embed[tokens]
    flat = tokens.reshape(-1)
    onehot = jax.nn.one_hot(flat, embed.shape[0], dtype=embed.dtype)
    out = onehot @ embed
    return out.reshape(tokens.shape + (embed.shape[1],))


class ForwardResult(NamedTuple):
    logits: jax.Array
    cache: Optional[dict]
    aux_loss: jax.Array
    hidden: jax.Array


def forward(params, cfg: ModelConfig, tokens=None, embeds=None,
            positions=None, positions3=None, cache=None, cache_len=None,
            train: bool = False) -> ForwardResult:
    """tokens: (B, S) int32 and/or embeds: (B, P, d) prefix (VLM/audio).

    cache/cache_len: incremental mode (prefill writes at [0, S), decode at
    cache_len)."""
    spec = unit_spec(cfg)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(L.dtype_of(cfg)))
    if tokens is not None:
        parts.append(embed_lookup(params["embed"], tokens))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    x = annotate(x, ("batch", None, None))
    b, s, _ = x.shape
    if positions is None:
        base = 0 if cache_len is None else cache_len
        positions = base + jnp.broadcast_to(jnp.arange(s), (b, s))

    aux_total = jnp.zeros((), jnp.float32)
    cl = jnp.asarray(0 if cache_len is None else cache_len, jnp.int32)

    # prefix (unrolled)
    new_prefix_cache = {}
    kinds_all = [(_mixer_kind(cfg, i), _ffn_kind(cfg, i))
                 for i in range(spec.n_prefix)]
    for i in range(spec.n_prefix):
        c = None if cache is None else cache["prefix"][f"layer{i}"]
        x, nc, aux = _apply_layer(params["prefix"][f"layer{i}"], kinds_all[i],
                                  x, positions, cfg, c, cl, positions3)
        aux_total = aux_total + aux
        if cache is not None:
            new_prefix_cache[f"layer{i}"] = nc

    # scanned body
    def unit_body(carry, xs):
        xcur, aux_sum = carry
        p_unit, c_unit = xs
        xcur, new_c, aux = _apply_unit(p_unit, spec.kinds, xcur, positions,
                                       cfg, c_unit, cl, positions3)
        return (xcur, aux_sum + aux), new_c

    body_fn = jax.checkpoint(unit_body) if (cfg.remat and train) else unit_body
    body_cache = None if cache is None else cache["body"]
    if cache is None:
        (x, aux_total), _ = jax.lax.scan(
            lambda c, p: body_fn(c, (p, None)), (x, aux_total), params["body"])
        new_body_cache = None
    else:
        (x, aux_total), new_body_cache = jax.lax.scan(
            body_fn, (x, aux_total), (params["body"], body_cache))

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head).astype(jnp.float32)

    new_cache = None
    if cache is not None:
        new_cache = {"body": new_body_cache}
        if spec.n_prefix:
            new_cache["prefix"] = new_prefix_cache
    return ForwardResult(logits=logits, cache=new_cache, aux_loss=aux_total,
                         hidden=x)


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None):
    logp = jax.nn.log_softmax(logits, axis=-1)
    if mesh_active():
        # one-hot contraction instead of a gather across the vocab-sharded
        # logits (same rationale as embed_lookup)
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logp.dtype)
        ll = jnp.sum(logp * onehot, axis=-1)
    else:
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def mtp_loss(params, cfg: ModelConfig, hidden, tokens, positions):
    """DeepSeek MTP (depth 1): predict token t+2 from [h_t ; emb(x_{t+1})]."""
    if not cfg.mtp_depth:
        return jnp.zeros((), jnp.float32)
    p = params["mtp"]
    b, s, d = hidden.shape
    emb_next = embed_lookup(params["embed"], tokens[:, 1:])  # (B, S-1, d)
    inp = jnp.concatenate([hidden[:, :-1], emb_next], axis=-1) @ p["proj"]
    kind = (_mixer_kind(cfg, cfg.n_layers - 1), "dense")
    out, _, _ = _apply_layer(p["block"], kind, inp, positions[:, :-1], cfg,
                             None, jnp.zeros((), jnp.int32), None)
    out = L.rms_norm(out, p["norm"], cfg.norm_eps)
    logits = (out @ params["embed"].T).astype(jnp.float32)  # shared head
    return cross_entropy(logits[:, :-1], tokens[:, 2:])
