"""Serving engine: prefill + decode with KV/SSM caches, continuous batching.

`make_prefill` / `make_decode_step` return pure jittable functions — these
are exactly what launch/dryrun.py lowers for the decode_32k / long_500k
shapes.  `ServeEngine` wraps them with slot-based continuous batching:
a fixed batch of B slots, each carrying its own cache_len; finished slots
are refilled from the request queue without recompiling (static shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import forward, init_cache
from repro.models.config import ModelConfig


def make_prefill(cfg: ModelConfig, s_max: int) -> Callable:
    def prefill(params, batch, cache):
        kw = {k: batch[k] for k in ("tokens", "embeds", "positions3")
              if k in batch}
        out = forward(params, cfg, cache=cache, cache_len=0, **kw)
        return out.logits, out.cache

    return prefill


def make_decode_step(cfg: ModelConfig, temperature: float = 0.0) -> Callable:
    def decode_step(params, cache, last_tokens, cache_len, key=None,
                    positions3=None):
        """last_tokens: (B, 1) -> (next (B, 1), logits, new cache)."""
        out = forward(params, cfg, tokens=last_tokens, cache=cache,
                      cache_len=cache_len, positions3=positions3)
        logits = out.logits[:, -1]
        if temperature > 0 and key is not None:
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, out.cache

    return decode_step


def generate(params, cfg: ModelConfig, prompt: jax.Array, steps: int,
             s_max: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0):
    """Greedy/temperature generation: prefill + lax.scan'd decode."""
    b, s = prompt.shape
    s_max = s_max or (s + steps)
    cache = init_cache(cfg, b, s_max)
    prefill = make_prefill(cfg, s_max)
    decode = make_decode_step(cfg, temperature)

    logits, cache = jax.jit(prefill)(params, {"tokens": prompt}, cache)
    last = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    def step(carry, key):
        last, cache, pos = carry
        nxt, _, cache = decode(params, cache, last, pos, key)
        return (nxt, cache, pos + 1), nxt[:, 0]

    if steps <= 1:
        return last
    keys = jax.random.split(jax.random.PRNGKey(seed), steps - 1)
    (_, _, _), tokens = jax.jit(
        lambda c, k: jax.lax.scan(step, c, k))((last, cache, jnp.asarray(s)),
                                               keys)
    # emitted sequence: the prefill-argmax token + the scanned decode tokens
    return jnp.concatenate([last, tokens.T], axis=1)


@dataclasses.dataclass
class Slot:
    active: bool = False
    request_id: int = -1
    cache_len: int = 0
    budget: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class ServeEngine:
    """Continuous batching over B fixed slots (static shapes, no recompiles)."""

    def __init__(self, params, cfg: ModelConfig, batch: int, s_max: int,
                 temperature: float = 0.0):
        self.params, self.cfg = params, cfg
        self.batch, self.s_max = batch, s_max
        self.cache = init_cache(cfg, batch, s_max)
        self.slots = [Slot() for _ in range(batch)]
        self.queue: list[tuple[int, jax.Array, int]] = []
        self.done: dict[int, list[int]] = {}
        self._prefill1 = jax.jit(make_prefill(cfg, s_max))
        self._decode = jax.jit(make_decode_step(cfg, temperature))
        self._last = jnp.zeros((batch, 1), jnp.int32)
        self._lens = jnp.zeros((batch,), jnp.int32)

    def submit(self, request_id: int, prompt: jax.Array, max_tokens: int):
        self.queue.append((request_id, prompt, max_tokens))

    def _admit(self) -> int:
        """Refill free slots FIFO from the submit queue (continuous
        batching's admission step — the token-level twin of the request
        batch former in repro.serve.queue).  A slot freed by a finished
        request is reused for the next queued one on the following
        `step`; returns how many requests were admitted this call."""
        admitted = 0
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            rid, prompt, budget = self.queue.pop(0)
            # single-slot prefill: runs as a batch-1 jit (own compile), then
            # the cache row is written into the engine batch.
            cache1 = init_cache(self.cfg, 1, self.s_max)
            logits, cache1 = self._prefill1(
                self.params, {"tokens": prompt[None]}, cache1)
            self.cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), i, axis=0),
                self.cache, cache1)
            nxt = int(jnp.argmax(logits[0, -1]))
            self._last = self._last.at[i, 0].set(nxt)
            self._lens = self._lens.at[i].set(prompt.shape[0])
            self.slots[i] = Slot(active=True, request_id=rid,
                                 cache_len=prompt.shape[0], budget=budget,
                                 tokens=[nxt])
            admitted += 1
        return admitted

    def step(self):
        """One decode step for every active slot."""
        self._admit()
        if not any(s.active for s in self.slots):
            return False
        # NOTE: slots share a common cache_len frontier per decode call;
        # per-slot lens are handled by the attention write offsets.
        pos = int(max(s.cache_len for s in self.slots if s.active))
        nxt, _, self.cache = self._decode(
            self.params, self.cache, self._last, jnp.asarray(pos))
        self._last = nxt
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            slot.tokens.append(int(nxt[i, 0]))
            slot.cache_len += 1
            slot.budget -= 1
            if slot.budget <= 0:
                self.done[slot.request_id] = slot.tokens
                self.slots[i] = Slot()
        return True
