"""NSW graph index — the HNSW-style local-catalog index, TPU-adapted.

HNSW's pointer-chasing search is hostile to jit; we keep the navigable-
small-world *semantics* (greedy beam search over a neighbour graph, entry
point = medoid) but store the graph as a dense (N, degree) table and run a
fixed-width, fixed-step beam with masked gathers (DESIGN.md §3).  Recall is
controlled by (degree, beam, steps, expand) just like HNSW's (M, efSearch).

Batched-first (DESIGN.md §6): `query` carries the whole mini-batch's beams
as (B, beam) arrays through one fori_loop — per step it expands the
`expand` best unexpanded beam entries of every query at once (top-k,
gather, dedup and re-top-k all along axis 1), so the MXU sees (B, E·deg)
tiles instead of a per-query scalar loop.

Build: exact kNN graph + long-range shortcuts (random far edges), the
classic NSW construction, done once in numpy at setup.  (Reverse-edge
symmetrization of the shortcut slots was measured and REFUTED: replacing
the random far edges with incoming-kNN edges drops amazon-trace recall
0.84 -> 0.75 — the shortcuts are what lets the beam cross clusters.)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import arrays_bytes
from repro.kernels import ops


def build_nsw_graph(emb: np.ndarray, degree: int = 16, shortcuts: int = 2,
                    seed: int = 0, chunk: int = 1024) -> np.ndarray:
    n = emb.shape[0]
    rng = np.random.default_rng(seed)
    knn = min(degree - shortcuts, n - 1)
    graph = np.empty((n, degree), np.int32)
    cn = (emb ** 2).sum(1)
    for s in range(0, n, chunk):
        q = emb[s:s + chunk]
        d = (q ** 2).sum(1)[:, None] - 2 * q @ emb.T + cn[None]
        np.fill_diagonal(d[:, s:s + q.shape[0]], np.inf)
        part = np.argpartition(d, knn, axis=1)[:, :knn]
        graph[s:s + chunk, :knn] = part
    graph[:, knn:] = rng.integers(0, n, (n, degree - knn))
    return graph


class NSWIndex:
    exact_distances = True  # candidates scored with exact L2

    def __init__(self, embeddings, degree: int = 16, beam: int = 32,
                 steps: int = 12, expand: int = 2, seed: int = 0):
        emb = np.asarray(embeddings, np.float32)
        self.embeddings = jnp.asarray(emb)
        self.graph = jnp.asarray(build_nsw_graph(emb, degree, seed=seed))
        self.beam, self.steps, self.degree = beam, steps, degree
        self.expand = max(1, min(expand, beam))
        # entry points = catalog points nearest to k-means centroids: the
        # static-shape stand-in for HNSW's upper navigation layers — ensures
        # every density mode seeds the beam (DESIGN.md §3).
        from repro.index.kmeans import kmeans as _kmeans

        nentry = min(beam, emb.shape[0])
        cents, _ = _kmeans(jax.random.PRNGKey(seed), self.embeddings, nentry)
        d2 = ops.pairwise_l2_xla(cents, self.embeddings)
        self.entry_points = jnp.argmin(d2, axis=1).astype(jnp.int32)  # (nentry,)

    @property
    def n(self) -> int:
        return self.embeddings.shape[0]

    def memory_bytes(self) -> int:
        return arrays_bytes(self.embeddings, self.graph, self.entry_points)

    @partial(jax.jit, static_argnames=("self", "k"))
    def query(self, q: jax.Array, k: int):
        """(B, d) -> (dists (B, k), ids (B, k)); ids = -1 on underflow."""
        q = jnp.atleast_2d(q)
        b = q.shape[0]
        beam, deg, e = self.beam, self.degree, self.expand
        rows = jnp.arange(b)[:, None]

        seeds = jnp.resize(self.entry_points, (beam,))            # (beam,)
        beam_ids = jnp.broadcast_to(seeds[None, :], (b, beam))
        beam_d = jnp.sum(
            (self.embeddings[seeds][None, :, :] - q[:, None, :]) ** 2, -1)
        # mark duplicate seeds so they are not re-expanded
        nentry = self.entry_points.shape[0]
        dup0 = jnp.concatenate(
            [jnp.zeros((nentry,), bool), jnp.ones((beam - nentry,), bool)]
        ) if beam > nentry else jnp.zeros((beam,), bool)
        beam_d = jnp.where(dup0[None, :], jnp.inf, beam_d)
        expanded = jnp.broadcast_to(dup0[None, :], (b, beam))

        def step(_, carry):
            ids, dist, exp = carry                          # all (b, beam)
            # expand the e best unexpanded beam entries of every query
            cand_d = jnp.where(exp, jnp.inf, dist)
            _, sel = jax.lax.top_k(-cand_d, e)                    # (b, e)
            exp = exp.at[rows, sel].set(True)
            sel_ids = jnp.take_along_axis(ids, sel, axis=1)
            nbrs = self.graph[sel_ids].reshape(b, e * deg)
            nd = jnp.sum(
                (self.embeddings[nbrs] - q[:, None, :]) ** 2, axis=-1)
            all_ids = jnp.concatenate([ids, nbrs], axis=1)
            all_d = jnp.concatenate([dist, nd], axis=1)
            all_exp = jnp.concatenate(
                [exp, jnp.zeros((b, e * deg), bool)], axis=1)
            # dedup: keep the first occurrence of each id (sorted by id,
            # mark repeats with +inf) then take the best `beam`
            order = jnp.argsort(all_ids, axis=1)
            sid = jnp.take_along_axis(all_ids, order, axis=1)
            dup = jnp.concatenate(
                [jnp.zeros((b, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1)
            dupmask = jnp.zeros_like(dup).at[rows, order].set(dup)
            all_d = jnp.where(dupmask, jnp.inf, all_d)
            neg, pos = jax.lax.top_k(-all_d, beam)
            return (jnp.take_along_axis(all_ids, pos, axis=1), -neg,
                    jnp.take_along_axis(all_exp, pos, axis=1))

        ids, dist, _ = jax.lax.fori_loop(
            0, self.steps, step, (beam_ids, beam_d, expanded))
        # the beam can only ever hold `beam` candidates: k beyond it is
        # structural underflow — pad with the protocol's -1 / +inf slots
        # (so e.g. cfg.c_remote > beam degrades instead of crashing)
        kk = min(k, beam)
        neg, pos = jax.lax.top_k(-dist, kk)
        out_ids = jnp.take_along_axis(ids, pos, axis=1)
        out_d = -neg
        out_ids = jnp.where(jnp.isfinite(neg), out_ids, -1)
        if kk < k:
            out_d = jnp.pad(out_d, ((0, 0), (0, k - kk)),
                            constant_values=jnp.inf)
            out_ids = jnp.pad(out_ids, ((0, 0), (0, k - kk)),
                              constant_values=-1)
        return out_d, out_ids

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
