"""Fused distance + per-block partial top-k Pallas kernel.

GPU FAISS fuses the distance GEMM with a warp-level k-selection network.
TPU has no warp shuffles; the idiomatic two-level equivalent (DESIGN.md §3)
is: each (BQ, BN) tile emits its k smallest distances (iterative masked-min
extraction — k is small), and the host-side wrapper merges the per-block
partial results with one `lax.top_k` over (Q, nblocks * k).  This avoids
materialising the full (Q, N) distance matrix in HBM: the memory written
drops from Q*N to Q*N*k/BN floats (k/BN ≈ 1/16 compression at k=8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BN = 128
_INF = float("inf")  # python literal: avoids captured-constant tracing in Pallas


def extract_block_topk(d: jax.Array, base, k: int):
    """Per-tile k smallest of d (BQ, BN) by iterative masked-min extraction
    (k is small; no warp shuffles on TPU).  Returns (dists (BQ, k),
    positions (BQ, k)) with positions offset by `base` — the shared
    second-level contract of l2_topk and ivf_scan: callers merge the
    per-block partials with one lax.top_k.  Exhausted tiles yield +inf."""

    def body(t, carry):
        d_cur, outd, outi = carry
        m = jnp.min(d_cur, axis=1)                        # (BQ,)
        a = jnp.argmin(d_cur, axis=1).astype(jnp.int32)   # (BQ,)
        outd = outd.at[:, t].set(m)
        outi = outi.at[:, t].set(base + a)
        cols = jax.lax.broadcasted_iota(jnp.int32, d_cur.shape, 1)
        d_cur = jnp.where(cols == a[:, None], _INF, d_cur)
        return d_cur, outd, outi

    outd = jnp.full((d.shape[0], k), _INF, jnp.float32)
    outi = jnp.zeros((d.shape[0], k), jnp.int32)
    _, outd, outi = jax.lax.fori_loop(0, k, body, (d, outd, outi))
    return outd, outi


def _l2_topk_kernel(k: int, n_valid: int, masked: bool, *refs):
    if masked:
        q_ref, x_ref, m_ref, od_ref, oi_ref = refs
    else:
        q_ref, x_ref, od_ref, oi_ref = refs
        m_ref = None
    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    xn = jnp.sum(x * x, axis=1)[None, :]
    d = jnp.maximum(qn - 2.0 * dots + xn, 0.0)  # (BQ, BN)

    j = pl.program_id(1)
    base = j * BN
    # mask padded catalog rows so they never displace real candidates
    gcol = base + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(gcol >= n_valid, _INF, d)
    if m_ref is not None:
        # additive row penalty (0 = live, +inf = tombstoned): the
        # mutable-catalog validity mask of DESIGN.md §10
        d = d + m_ref[...]

    od_ref[...], oi_ref[...] = extract_block_topk(d, base, k)


def l2_topk_pallas(
    q: jax.Array, x: jax.Array, k: int, *, n_valid: int | None = None,
    mask: jax.Array | None = None, interpret: bool = False
):
    """Returns per-block partial results (Q, nblocks*k) dists + global ids.

    Callers merge with lax.top_k (see ops.topk_l2).  `n_valid` marks the
    number of real catalog rows (the rest are padding).  `mask` is an
    optional (1, N) float32 additive row penalty — 0 for live rows, +inf
    for tombstoned ones — so a mutable catalog's removed rows can never
    displace real candidates (their partial dists come back +inf and the
    merge turns them into id = -1)."""
    qq, d = q.shape
    n, _ = x.shape
    assert qq % BQ == 0 and n % BN == 0 and k <= BN
    if mask is not None:
        assert mask.shape == (1, n), (mask.shape, n)
    grid = (qq // BQ, n // BN)
    nb = n // BN
    in_specs = [
        pl.BlockSpec((BQ, d), lambda i, j: (i, 0)),
        pl.BlockSpec((BN, d), lambda i, j: (j, 0)),
    ]
    args = (q, x)
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, BN), lambda i, j: (0, j)))
        args = (q, x, mask.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_l2_topk_kernel, k,
                          n if n_valid is None else n_valid, mask is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((BQ, k), lambda i, j: (i, j)),
            pl.BlockSpec((BQ, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qq, nb * k), jnp.float32),
            jax.ShapeDtypeStruct((qq, nb * k), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
