"""All-backends sweep through the unified index registry (DESIGN.md §8).

Every registered single-device backend (flat | ivf | ivfpq | lsh | nsw) is
built from an `IndexSpec`, wired into the batched AÇAI replay via
`index_candidate_fn_batched`, and swept over B ∈ {8, 64}.  Per row we
report the quality/cost trade-off the paper studies: NAG (gain), p50
per-request latency, recall@10 vs the flat (exact) index, and resident
index bytes.  Results land in BENCH_backends.json at the repo root so the
backend trajectory is tracked per PR.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import oma, policy, trace
from repro.core.costs import calibrate_fetch_cost
from repro.index import IndexSpec, build_index, registered_backends
from repro.index.candidates import index_candidate_fn_batched

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_backends.json"

# Per-backend build kwargs at bench scale — the registry means adding a
# backend here is the *only* edit this suite ever needs.
SPECS = {
    "flat": {},
    "ivf": {"nlist": 48, "nprobe": 10},
    "ivfpq": {"nlist": 48, "nprobe": 10, "m": 8, "refine": 4},
    "lsh": {"tables": 12, "bits": 8},
    "nsw": {"degree": 16, "beam": 48, "steps": 16},
}


def _recall_at_10(index, flat, q) -> float:
    truth = np.asarray(flat.query(q, 10)[1])
    ids = np.asarray(index.query(q, 10)[1])
    return float(np.mean([len(set(ids[b]) & set(truth[b])) / 10
                          for b in range(q.shape[0])]))


def main(full: bool = False, kind: str = "sift") -> None:
    missing = set(registered_backends(sharded=False)) - set(SPECS)
    assert not missing, \
        f"backends bench table is missing registered backends: {missing}"
    n, t, d = (20000, 8192, 32) if full else (2000, 1024, 16)
    gen = trace.sift_like if kind == "sift" else trace.amazon_like
    catalog, reqs, _ = gen(n=n, d=d, t=t, seed=0)
    cat, reqs_j = jnp.array(catalog), jnp.array(reqs)
    c_f = float(calibrate_fetch_cost(cat, kth=min(50, n - 1), sample=256))
    cfg = policy.AcaiConfig(h=64, k=8, c_f=c_f, c_remote=32, c_local=16,
                            oma=oma.OMAConfig(eta=0.05 / c_f))
    flat = build_index(IndexSpec("flat"), cat)

    rows = []
    for backend, params in SPECS.items():
        spec = IndexSpec(backend, params)
        index = build_index(spec, cat)
        recall = (1.0 if backend == "flat"
                  else _recall_at_10(index, flat, reqs_j[:64]))
        fnb = index_candidate_fn_batched(index, cat, cfg.c_remote,
                                         cfg.c_local, h=cfg.h)
        for b in (8, 64):
            step = jax.jit(policy.make_step_batched(cfg, fnb, b))
            calls = (t // b)
            state = policy.init_state(n, cfg)
            state, m = step(state, reqs_j[:b])      # compile + warmup
            m.gain_int.block_until_ready()
            gains, times = [], []
            state = policy.init_state(n, cfg)
            t0 = time.time()
            for c in range(calls):
                tc = time.time()
                state, m = step(state, reqs_j[c * b:(c + 1) * b])
                m.gain_int.block_until_ready()
                times.append(time.time() - tc)
                gains.append(np.asarray(m.gain_int))
            dt = time.time() - t0
            tt = calls * b
            nag = float(np.sum(np.concatenate(gains))) / (cfg.k * c_f * tt)
            p50_us = float(np.percentile(times, 50)) / b * 1e6
            rows.append({
                "backend": backend, "spec": spec.to_dict(), "batch": b,
                "nag": round(nag, 4), "recall_at_10": round(recall, 4),
                "p50_us_per_request": round(p50_us, 2),
                "requests_per_s": round(tt / dt, 1),
                "index_mbytes": round(index.memory_bytes() / 2 ** 20, 2),
                "requests": tt,
            })
            common.emit(f"backends/{kind}/{backend}/B{b}", p50_us,
                        f"NAG={nag:.4f};recall={recall:.3f};rps={tt / dt:.0f}")
    BENCH_JSON.write_text(json.dumps(
        {"kind": kind, "full": full, "n": n, "d": d,
         "backend": jax.default_backend(), "rows": rows}, indent=2) + "\n")
    common.emit("backends/json", 0.0, str(BENCH_JSON.name))


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
