"""Launchers: production mesh, multi-pod dry-run, train/serve drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in a
dedicated process (the CLI), never from tests or library code.
"""
