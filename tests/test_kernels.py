"""Pallas kernels: shape/dtype sweeps, interpret-mode vs the jnp oracles."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("q,n,d", [(4, 100, 16), (128, 256, 128), (37, 513, 64),
                                   (1, 2000, 32), (130, 129, 48)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_pairwise_l2(q, n, d, dtype):
    rng = np.random.default_rng(0)
    qa = jnp.array(rng.normal(size=(q, d)).astype(dtype))
    xa = jnp.array(rng.normal(size=(n, d)).astype(dtype))
    got = np.array(ops.pairwise_l2(qa, xa))
    want = np.array(ref.pairwise_l2_ref(qa, xa))
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol * 10, atol=tol)


@pytest.mark.parametrize("q,n,d,k", [(4, 100, 16, 1), (64, 400, 32, 8),
                                     (9, 300, 24, 16), (1, 150, 8, 5)])
def test_topk_l2(q, n, d, k):
    rng = np.random.default_rng(1)
    qa = jnp.array(rng.normal(size=(q, d)).astype(np.float32))
    xa = jnp.array(rng.normal(size=(n, d)).astype(np.float32))
    gd, gi = ops.topk_l2(qa, xa, k)
    wd, wi = ref.l2_topk_ref(qa, xa, k)
    np.testing.assert_allclose(np.array(gd), np.array(wd), rtol=1e-4, atol=1e-4)
    # ids may differ under distance ties: check distances of returned ids
    d_of_ids = np.array(ref.pairwise_l2_ref(qa, xa))[
        np.arange(q)[:, None], np.array(gi)
    ]
    np.testing.assert_allclose(d_of_ids, np.array(wd), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,n,m,c", [(2, 64, 4, 16), (128, 300, 8, 256),
                                     (5, 1000, 16, 256), (1, 50, 2, 4)])
def test_pq_adc(q, n, m, c):
    rng = np.random.default_rng(2)
    lut = jnp.array(rng.random((q, m, c)).astype(np.float32))
    codes = jnp.array(rng.integers(0, c, (n, m)).astype(np.int32))
    got = np.array(ops.pq_adc(lut, codes))
    want = np.array(ref.pq_adc_ref(lut, codes))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_l2_nonnegative_and_zero_diagonal():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(64, 32)).astype(np.float32))
    d = np.array(ops.pairwise_l2(x, x))
    assert (d >= 0).all()
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)


@pytest.mark.parametrize("b,s,t,h,kv,d,causal,window",
                         [(2, 64, 64, 4, 2, 32, True, 0),
                          (1, 128, 128, 8, 8, 64, True, 0),
                          (2, 64, 64, 4, 4, 32, False, 0),
                          (2, 64, 64, 4, 2, 32, True, 24),
                          (1, 32, 128, 4, 2, 32, True, 0)])
def test_flash_attention_kernel(b, s, t, h, kv, d, causal, window):
    """Pallas flash-attention vs the dense attention_core oracle."""
    import dataclasses
    from repro.configs import SMOKE_ARCHS
    from repro.models import layers as L

    rng = np.random.default_rng(0)
    q = jnp.array(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.array(rng.normal(size=(b, t, kv, d)).astype(np.float32))
    v = jnp.array(rng.normal(size=(b, t, kv, d)).astype(np.float32))
    cfg = dataclasses.replace(SMOKE_ARCHS["minitron-8b"], causal=causal,
                              sliding_window=window)
    want = L.attention_core(q, k, v, t - s, cfg, written_upto=t)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=t - s, written_upto=t)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-4)
