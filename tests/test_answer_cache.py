"""Answer-cache tier (DESIGN.md §13): spec round-trips, the LRU store's
unit semantics, the bitwise parity pin (cache-on == cache-off) across
every registered backend with and without churn, the
removed-id-is-never-served invariant under arbitrary interleavings
(hypothesis property when available, seeded fallback otherwise), the
online engine's arrival-time fast path, and idle unload/reload."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import trace
from repro.core import policy_api as PA
from repro.core.costs import CostModel
from repro.index import IndexSpec, build_index
from repro.index.base import TINY_BUILD_KWARGS as TINY
from repro.serve.answer_cache import (AnswerCache, AnswerCacheSpec,
                                      CachedIndex, parse_answer_cache_opts,
                                      resolve_answer_cache_spec)

K = 4


@pytest.fixture(scope="module")
def setup():
    catalog, reqs, _ = trace.sift_like(n=240, d=12, t=64, zipf_a=1.1,
                                       jitter=0.0, seed=3)
    rng = np.random.default_rng(5)
    newv = (rng.random((24, 12)) * 0.9 + 0.05).astype(np.float32)
    return catalog, reqs, newv


def _policy(catalog, index_spec, cap, *, batch=8, seed=0, **spec_kw):
    cm = CostModel(c_f=0.3)
    return PA.build_policy(
        PA.PolicySpec("acai", {"h": 32, "k": K, "batch": batch}),
        catalog, cm, index_spec=index_spec, seed=seed,
        answer_cache=AnswerCacheSpec(capacity=cap, **spec_kw))


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------

def test_spec_roundtrip_and_validation():
    spec = AnswerCacheSpec(capacity=128, hit_ms=0.5, idle_unload_ms=40.0)
    assert AnswerCacheSpec.from_dict(spec.to_dict()) == spec
    assert spec.with_params(capacity=0).capacity == 0
    with pytest.raises(ValueError, match="capacity"):
        AnswerCacheSpec(capacity=-1)
    with pytest.raises(ValueError, match="hit_ms"):
        AnswerCacheSpec(hit_ms=-0.1)
    with pytest.raises(ValueError, match="idle_unload_ms"):
        AnswerCacheSpec(idle_unload_ms=0)
    with pytest.raises(ValueError, match="unknown fields"):
        AnswerCacheSpec.from_dict({"capcity": 7})


def test_resolve_forms():
    assert resolve_answer_cache_spec(None) is None
    assert resolve_answer_cache_spec(False) is None
    assert resolve_answer_cache_spec(True) == AnswerCacheSpec()
    assert resolve_answer_cache_spec(512).capacity == 512
    assert resolve_answer_cache_spec({"capacity": 9}).capacity == 9
    spec = AnswerCacheSpec(capacity=3)
    assert resolve_answer_cache_spec(spec) is spec
    with pytest.raises(TypeError, match="answer_cache"):
        resolve_answer_cache_spec("big")


def test_parse_opts():
    got = parse_answer_cache_opts(
        ["hit_ms=0.1", "idle_unload_ms=none", "capacity=7"])
    assert got == {"hit_ms": 0.1, "idle_unload_ms": None, "capacity": 7}
    with pytest.raises(ValueError, match="KEY=VALUE"):
        parse_answer_cache_opts(["hit_ms"])


# ---------------------------------------------------------------------------
# AnswerCache unit semantics
# ---------------------------------------------------------------------------

def _fill(cache, rs, base=0):
    b = rs.shape[0]
    d = np.tile(np.arange(1.0, K + 1, dtype=np.float32), (b, 1))
    ids = (base + np.arange(b * K, dtype=np.int32)).reshape(b, K)
    cache.store_batch(rs, K, d, ids)
    return ids


def test_store_lookup_lru_eviction(setup):
    catalog, _, _ = setup
    cache = AnswerCache(AnswerCacheSpec(capacity=4))
    ids = _fill(cache, catalog[:4])
    entries, mask = cache.lookup_batch(catalog[:4], K)
    assert mask.all() and cache.hits == 4
    assert all(np.array_equal(e.ids, row) for e, row in zip(entries, ids))
    # same query at another fan-out is a distinct entry → miss
    _, mask2 = cache.lookup_batch(catalog[:1], K + 1)
    assert not mask2.any()
    # rows 0..3 were just touched; storing 2 more evicts the two oldest
    # (which are 0 and 1 — the lookup refreshed recency in batch order)
    _fill(cache, catalog[4:6], base=100)
    assert cache.evictions >= 2 and len(cache._store) == 4
    _, mask3 = cache.lookup_batch(catalog[:2], K)
    assert not mask3.any()
    st = cache.stats()
    assert st["entries"] == 4 and st["stores"] == 6


def test_peek_is_noncounting(setup):
    catalog, _, _ = setup
    cache = AnswerCache(AnswerCacheSpec(capacity=8))
    _fill(cache, catalog[:2])
    h0, m0 = cache.hits, cache.misses
    assert cache.peek(catalog[0]) and cache.peek(catalog[0], K)
    assert not cache.peek(catalog[3]) and not cache.peek(catalog[0], K + 2)
    assert (cache.hits, cache.misses) == (h0, m0)
    assert not AnswerCache(AnswerCacheSpec(capacity=0)).peek(catalog[0])


def test_invalidate_removed_is_precise(setup):
    catalog, _, _ = setup
    cache = AnswerCache(AnswerCacheSpec(capacity=16))
    ids = _fill(cache, catalog[:4])  # disjoint answers per row
    n = cache.invalidate_removed([int(ids[1, 2])])
    assert n == 1 and cache.inv_remove == 1
    _, mask = cache.lookup_batch(catalog[:4], K)
    assert mask.tolist() == [True, False, True, True]
    # an id nobody serves invalidates nothing
    assert cache.invalidate_removed([10_000]) == 0


def test_invalidate_added_radius(setup):
    catalog, _, _ = setup
    cache = AnswerCache(AnswerCacheSpec(capacity=16))
    q = catalog[:2]
    d = np.array([[0.1, 0.2, 0.3, 0.4], [0.1, 0.2, 0.3, 0.4]], np.float32)
    ids = np.arange(8, dtype=np.int32).reshape(2, 4)
    cache.store_batch(q, K, d, ids)
    # a vector far outside both radii touches nothing
    far = q[0] + 100.0
    assert cache.invalidate_added(far[None]) == 0
    # the query itself is at distance 0 < kth → entry 0 must die
    assert cache.invalidate_added(q[0][None]) >= 1
    _, mask = cache.lookup_batch(q, K)
    assert not mask[0]
    # an underfull answer (kth = +inf) always invalidates
    cache.store_batch(q[:1], K, np.array([[0.1, np.inf, np.inf, np.inf]],
                                         np.float32),
                      np.array([[3, -1, -1, -1]], np.int32))
    assert cache.invalidate_added(far[None]) == 1


def test_remap_ids_rewrites_entries_and_inverted_map(setup):
    """Compaction seam (DESIGN.md §14): an id-shifting remap rewrites
    every stored answer in place, passes -1 underflow through, rebuilds
    the inverted id→keys map in the new id space, and refuses to remap
    an answer onto a dead row."""
    catalog, _, _ = setup
    cache = AnswerCache(AnswerCacheSpec(capacity=8))
    rs = np.asarray(catalog[:2], np.float32)
    d = np.array([[0.1, 0.2, np.inf], [0.3, 0.4, 0.5]], np.float32)
    ids = np.array([[5, 9, -1], [2, 9, 11]], np.int32)
    cache.store_batch(rs, 3, d, ids)
    remap = np.full(16, -1, np.int32)
    for new, old in enumerate((2, 5, 9, 11)):  # order-preserving
        remap[old] = new
    cache.remap_ids(remap)
    assert cache.epoch == 1 and cache.invalidations == 0
    entries, mask = cache.lookup_batch(rs, 3)
    assert mask.all()
    np.testing.assert_array_equal(entries[0].ids, [1, 2, -1])
    np.testing.assert_array_equal(entries[1].ids, [0, 2, 3])
    np.testing.assert_array_equal(entries[1].d, d[1])  # answers untouched
    # inverted map lives in the new id space: dropping new id 2 (old 9)
    # kills exactly the two entries that contain it
    assert cache.invalidate_removed([2]) == 2 and len(cache) == 0
    # remapping a stored id onto a dead row is a loud contract violation
    cache.store_batch(rs[:1], 3, d[:1], np.array([[0, 1, -1]], np.int32))
    bad = np.full(16, -1, np.int32)
    bad[0] = 0
    with pytest.raises(ValueError, match="dead row"):
        cache.remap_ids(bad)


def test_flush_and_step_stats(setup):
    catalog, _, _ = setup
    cache = AnswerCache(AnswerCacheSpec(capacity=16))
    _fill(cache, catalog[:3])
    assert cache.flush("refresh") == 3 and cache.epoch == 1
    assert cache.inv_refresh == 3 and len(cache._store) == 0
    cache.lookup_batch(catalog[:3], K)
    mask, inval = cache.take_step_stats(3)
    assert not mask.any() and inval == 3
    # drained: a second take returns zeros
    mask2, inval2 = cache.take_step_stats(3)
    assert not mask2.any() and inval2 == 0


def test_rid_namespace(setup):
    catalog, _, _ = setup
    cache = AnswerCache(AnswerCacheSpec(capacity=8))
    d = np.zeros((2, K), np.float32)
    ids = np.arange(8, dtype=np.int32).reshape(2, 4)
    cache.store_batch(catalog[:2], K, d, ids, rids=[17, 23])
    _, mask = cache.lookup_batch(catalog[:2], K, rids=[17, 23])
    assert mask.all()
    # rid identity, not vector identity: other vectors, same rids → hit
    _, mask2 = cache.lookup_batch(catalog[10:12], K, rids=[17, 23])
    assert mask2.all()
    _, mask3 = cache.lookup_batch(catalog[:2], K)  # vec namespace: miss
    assert not mask3.any()
    with pytest.raises(ValueError, match="rids length"):
        cache.lookup_batch(catalog[:2], K, rids=[17])


# ---------------------------------------------------------------------------
# the parity pin: cache-on == cache-off, bitwise, every backend, with churn
# ---------------------------------------------------------------------------

def _served_recorder(pol, sink):
    idx = pol.cache.index
    orig = idx.query

    def wrapped(rs, k):
        d, ids = orig(rs, k)
        sink.append(np.asarray(ids))
        return d, ids

    idx.query = wrapped


@pytest.mark.parametrize("backend", sorted(TINY))
def test_bitwise_parity_under_churn(setup, backend):
    """The acceptance pin: identical NAG, per-request gain, policy state
    and served ids with the cache on vs off, through an interleaving of
    serving and every mutation kind, on every registered backend."""
    catalog, reqs, newv = setup
    ispec = IndexSpec(backend, TINY[backend])
    arms = {}
    for cap in (64, 0):
        pol = _policy(catalog, ispec, cap)
        served, gains = [], []
        _served_recorder(pol, served)
        # serve → add → serve (repeat a batch: hits) → remove → serve →
        # refresh → serve; both arms execute the identical schedule
        for rs in (reqs[:8], reqs[:8]):
            gains.append(np.asarray(pol.serve_update_batch(rs).gain_int))
        pol.add_objects(newv)
        gains.append(np.asarray(pol.serve_update_batch(reqs[:8]).gain_int))
        doomed = np.unique(np.concatenate(served)[-8:])
        doomed = doomed[doomed >= 0][:3]
        pol.remove_objects(doomed)
        gains.append(np.asarray(pol.serve_update_batch(reqs[8:16]).gain_int))
        pol.refresh()
        gains.append(np.asarray(pol.serve_update_batch(reqs[:8]).gain_int))
        arms[cap] = (np.concatenate(gains),
                     np.asarray(pol.cache.state.y),
                     np.asarray(pol.cache.state.x),
                     np.concatenate([s.ravel() for s in served]),
                     pol, doomed)
    g_on, y_on, x_on, ids_on, pol_on, doomed = arms[64]
    g_off, y_off, x_off, ids_off, _, _ = arms[0]
    assert np.array_equal(g_on, g_off), f"{backend}: gain diverged"
    assert np.array_equal(y_on, y_off), f"{backend}: state.y diverged"
    assert np.array_equal(x_on, x_off), f"{backend}: state.x diverged"
    assert np.array_equal(ids_on, ids_off), f"{backend}: served ids diverged"
    # the invariant the invalidation rules exist for: after the remove,
    # no removed id was ever served again (either arm)
    after = np.concatenate([s.ravel() for s in
                            (ids_on[-16 * pol_on.cache.cfg.c_remote:],)])
    assert not set(doomed.tolist()) & set(after.tolist())
    st = pol_on.answer_cache.stats()
    assert st["hits"] > 0, f"{backend}: repeat batch never hit"
    assert st["invalidations"] > 0, f"{backend}: churn invalidated nothing"


@pytest.mark.parametrize("backend", sorted(TINY))
def test_bitwise_parity_under_compaction(setup, backend):
    """Epoch compaction keeps the cache-on arm bitwise (DESIGN.md §14):
    the structure-free flat backend remaps its stored answers in place —
    the remap is order-preserving, so even top-k tie-breaks survive and
    the entries keep hitting — while every structure-backed backend
    flushes (compaction rebuilds its auxiliaries over the live set: IVF
    re-trains k-means, LSH re-draws truncation-capped buckets, NSW
    re-links — the stored answers could diverge from the rebuilt index).
    Either way gains, policy state and served ids match the cache-off
    arm exactly through remove → compact → serve."""
    catalog, reqs, newv = setup
    ispec = IndexSpec(backend, TINY[backend])
    # the added rows sit far outside the catalog's ball, and the removes
    # target only them: the precise invalidation rules (radius check on
    # add, inverted-map walk on remove) leave the memoized entries alone,
    # so what happens to the store at compaction is compaction's doing
    far = newv + 8.0
    arms = {}
    for cap in (64, 0):
        pol = _policy(catalog, ispec, cap)
        served, gains = [], []
        _served_recorder(pol, served)
        for rs in (reqs[:8], reqs[:8]):
            gains.append(np.asarray(pol.serve_update_batch(rs).gain_int))
        added = np.asarray(pol.add_objects(far))
        pol.remove_objects(added[:4])
        remap = np.asarray(pol.compact())
        entries_after_compact = len(pol.answer_cache.cache) if cap else 0
        hits_before = pol.answer_cache.cache.hits if cap else 0
        # the repeated batch right after compaction: on the stable arm it
        # must hit the remapped entries, on both arms serve identically
        gains.append(np.asarray(pol.serve_update_batch(reqs[:8]).gain_int))
        gains.append(np.asarray(pol.serve_update_batch(reqs[8:16]).gain_int))
        hits_delta = (pol.answer_cache.cache.hits - hits_before) if cap else 0
        arms[cap] = (np.concatenate(gains), np.asarray(pol.cache.state.y),
                     np.concatenate([s.ravel() for s in served]), remap,
                     entries_after_compact, hits_delta, pol)
    g_on, y_on, ids_on, remap_on, entries_on, hits_on, pol_on = arms[64]
    g_off, y_off, ids_off, remap_off, _, _, _ = arms[0]
    np.testing.assert_array_equal(remap_on, remap_off)
    assert np.array_equal(g_on, g_off), f"{backend}: gain diverged"
    assert np.array_equal(y_on, y_off), f"{backend}: state.y diverged"
    assert np.array_equal(ids_on, ids_off), f"{backend}: served ids diverged"
    st = pol_on.answer_cache.stats()
    if backend == "flat":
        # structure-free + exact: the store survived via the id remap
        assert entries_on > 0, "flat compaction flushed instead of remapping"
        assert hits_on > 0, "remapped entries never hit again"
    else:
        # compaction rebuilt this backend's structures: conservative flush
        assert entries_on == 0, (
            f"{backend} kept entries past a structure-rebuilding compact")
    assert st["epoch"] >= 1


def test_replay_parity_and_metrics(setup):
    """Replay-level pin on the default (flat) backend + the StepMetrics
    counters: answer_hits flow into the per-request replay arrays."""
    catalog, reqs, _ = setup
    res = {}
    for cap in (64, 0):
        pol = _policy(catalog, IndexSpec("flat"), cap)
        res[cap] = (pol.replay(reqs), pol)
    r_on, pol_on = res[64]
    r_off, _ = res[0]
    assert np.array_equal(r_on["gain"], r_off["gain"])
    t = r_on["requests"]
    assert pol_on.normalized_gain(float(r_on["gain"].sum()), t) == \
        pytest.approx(pol_on.normalized_gain(float(r_off["gain"].sum()), t))
    assert r_on["answer_hits"].shape == (t,)
    assert r_on["answer_hits"].sum() > 0
    assert r_off["answer_hits"].sum() == 0
    st = pol_on.answer_cache.stats()
    assert st["hit_rate"] > 0 and st["scans_skipped"] > 0


def test_metrics_leaf_shapes(setup):
    catalog, reqs, _ = setup
    pol = _policy(catalog, IndexSpec("flat"), 64)
    m = pol.serve_update_batch(reqs[:8])
    for f in ("answer_hits", "answer_misses", "answer_invalidations"):
        assert np.asarray(getattr(m, f)).shape == (8,), f
    hits = np.asarray(m.answer_hits) + np.asarray(m.answer_misses)
    assert (hits == 1).all()
    served_id = next(iter(pol.answer_cache.cache._inv))
    pol.remove_objects([served_id])
    m2 = pol.serve_update_batch(reqs[:8])
    assert int(np.asarray(m2.answer_invalidations).sum()) > 0


def test_build_policy_validation(setup):
    catalog, _, _ = setup
    cm = CostModel(c_f=0.3)
    with pytest.raises(ValueError, match="oracle-exact"):
        PA.build_policy(PA.PolicySpec("lru", {"h": 16, "k": K}), catalog,
                        cm, answer_cache=AnswerCacheSpec())
    with pytest.raises(ValueError, match="cfg.index"):
        PA.build_policy(PA.PolicySpec("acai", {"h": 16, "k": K}), catalog,
                        cm, answer_cache=AnswerCacheSpec())
    pol = _policy(catalog, IndexSpec("flat"), 8)
    assert isinstance(pol.answer_cache, CachedIndex)


# ---------------------------------------------------------------------------
# interleaving property: never serve a removed id, always match a fresh
# exact scan (the uncached twin executing the identical schedule)
# ---------------------------------------------------------------------------

def _check_interleaving(ops, catalog):
    """Run an op schedule against a cached flat index and its uncached
    twin; every query must match the twin bitwise (flat = the exact
    fused scan) and never contain a removed id."""
    cached = CachedIndex(
        build_index(IndexSpec("flat"), jnp.asarray(catalog)),
        AnswerCacheSpec(capacity=32))
    exact = build_index(IndexSpec("flat"), jnp.asarray(catalog))
    live = set(range(catalog.shape[0]))
    dead: set = set()
    for op, seed in ops:
        rng = np.random.default_rng(seed)
        if op == "query":
            rows = catalog[rng.integers(0, catalog.shape[0], 3)]
            d_c, i_c = cached.query(rows, K)
            d_e, i_e = exact.query(rows, K)
            i_c, i_e = np.asarray(i_c), np.asarray(i_e)
            assert np.array_equal(i_c, i_e), "cached ids != exact scan"
            assert np.array_equal(np.asarray(d_c), np.asarray(d_e))
            assert not (set(i_c.ravel().tolist()) - {-1}) & dead, \
                "served a removed id"
        elif op == "add":
            v = rng.random((1 + seed % 3, catalog.shape[1]),
                           dtype=np.float32)
            got = np.asarray(cached.add(v))
            assert np.array_equal(got, np.asarray(exact.add(v)))
            live |= set(got.tolist())
        elif op == "remove" and len(live) > 4:
            victims = rng.choice(sorted(live), size=min(2, len(live) - 4),
                                 replace=False)
            cached.remove(victims)
            exact.remove(victims)
            live -= set(victims.tolist())
            dead |= set(victims.tolist())
        elif op == "refresh":
            cached.refresh()
            exact.refresh()


_PLAIN_SCHEDULES = [
    [("query", 0), ("add", 1), ("query", 2), ("remove", 3), ("query", 4),
     ("refresh", 0), ("query", 5)],
    [("query", 7), ("query", 7), ("remove", 8), ("remove", 9),
     ("query", 10), ("add", 11), ("add", 12), ("query", 13)],
    [("remove", 1), ("refresh", 0), ("query", 2), ("query", 2),
     ("add", 3), ("remove", 4), ("query", 5)],
]


@pytest.mark.parametrize("schedule", range(len(_PLAIN_SCHEDULES)))
def test_interleaving_seeded(setup, schedule):
    """Deterministic fallback of the hypothesis property (runs whether
    or not hypothesis is installed)."""
    catalog, _, _ = setup
    _check_interleaving(_PLAIN_SCHEDULES[schedule], catalog[:60])


def test_interleaving_property(setup):
    """Arbitrary add/remove/refresh/query interleavings never serve a
    removed id and always match a fresh exact scan."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    catalog, _, _ = setup
    ops = st.lists(
        st.tuples(st.sampled_from(["query", "add", "remove", "refresh"]),
                  st.integers(0, 2 ** 16)),
        min_size=1, max_size=16)

    @settings(max_examples=25, deadline=None)
    @given(ops=ops)
    def run(ops):
        _check_interleaving(ops, catalog[:60])

    run()


# ---------------------------------------------------------------------------
# engine fast path + idle unload
# ---------------------------------------------------------------------------

def test_engine_fast_path(setup):
    from repro.serve.arrivals import ArrivalSpec
    from repro.serve.queue import (BatchFormerConfig, OnlineServingEngine,
                                   ServiceModel)

    catalog, reqs, _ = setup
    service = ServiceModel()
    arrival = ArrivalSpec(kind="poisson",
                          rate_rps=0.7 * service.capacity_rps(8), seed=2)
    runs = {}
    for cap in (64, 0):
        pol = _policy(catalog, IndexSpec("flat"), cap, hit_ms=0.25)
        eng = OnlineServingEngine(
            pol, former=BatchFormerConfig(max_batch=8, max_wait_ms=4.0),
            service=service)
        runs[cap] = eng.run(reqs, arrival)
    on, off = runs[64], runs[0]
    # the learn path is untouched: same batches, same gains, bitwise
    assert np.array_equal(on["gain"], off["gain"])
    assert on["answer_hit_rate"] > 0 and off["answer_hit_rate"] == 0
    # a hit's user-visible answer completes at arrival + hit_ms
    hit_lat = on["user_latency_ms"][on["answer_hit"]]
    assert np.allclose(hit_lat, 0.25)
    assert on["p50_hit_ms"] < on["p50_miss_ms"]
    # misses' user latency is the ordinary served latency
    miss = ~on["answer_hit"] & ~on["shed"]
    assert np.array_equal(on["user_latency_ms"][miss],
                          on["latency_ms"][miss])


def test_idle_unload_reload_bitwise(setup):
    """tick() past the idle threshold offloads the heavy structures;
    hits keep serving (still unloaded); the first miss reloads — and
    every answer stays bitwise identical to a never-unloaded twin."""
    catalog, reqs, _ = setup
    spec = AnswerCacheSpec(capacity=32, idle_unload_ms=10.0)
    ivf = IndexSpec("ivf", TINY["ivf"])
    ci = CachedIndex(build_index(ivf, jnp.asarray(catalog)), spec)
    twin = CachedIndex(build_index(ivf, jnp.asarray(catalog)),
                       AnswerCacheSpec(capacity=32))
    hot, cold = catalog[:8], catalog[8:16]
    ref_hot = [np.asarray(a) for a in twin.query(hot, K)]
    ref_cold = [np.asarray(a) for a in twin.query(cold, K)]
    ci.query(hot, K)                     # scan + store at t=0
    ci.tick(5.0)
    assert ci.loaded                     # under threshold
    ci.tick(20.0)
    assert not ci.loaded and ci.unloads == 1
    got = ci.query(hot, K)               # all-hit: serves while unloaded
    assert not ci.loaded
    assert np.array_equal(np.asarray(got[1]), ref_hot[1])
    got2 = ci.query(cold, K)             # miss: reload, scan, store
    assert ci.loaded and ci.reloads == 1
    assert np.array_equal(np.asarray(got2[1]), ref_cold[1])
    assert np.array_equal(np.asarray(got2[0]), ref_cold[0])
    # reload restored device arrays, not rebuilt ones: a repeat of the
    # first batch still matches the twin bitwise
    got3 = ci.query(hot, K)
    assert np.array_equal(np.asarray(got3[0]), ref_hot[0])
    st = ci.stats()
    assert st["unloads"] == 1 and st["reloads"] == 1


def test_capacity_zero_never_stores(setup):
    catalog, _, _ = setup
    ci = CachedIndex(build_index(IndexSpec("flat"), jnp.asarray(catalog)),
                     AnswerCacheSpec(capacity=0))
    for _ in range(3):
        ci.query(catalog[:4], K)
    st = ci.stats()
    assert st["entries"] == 0 and st["hits"] == 0
    assert st["scans"] == 3 and st["scans_skipped"] == 0
