"""Bregman projections onto the capped simplex vs the bisection oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import projection as P
from repro.core import ref


@pytest.mark.parametrize("seed", range(10))
def test_negentropy_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 400))
    h = int(rng.integers(1, n))
    z = (rng.random(n) ** 3 * rng.choice([0.1, 1, 10])).astype(np.float32) + 1e-8
    y = np.array(P.capped_simplex_negentropy(jnp.array(z), h))
    yo = ref.project_capped_simplex_bisect(z.astype(np.float64), h, "negentropy")
    assert abs(y.sum() - h) < 2e-3 * h
    np.testing.assert_allclose(y, yo, atol=2e-3)
    assert (y >= -1e-7).all() and (y <= 1 + 1e-6).all()


@pytest.mark.parametrize("seed", range(10))
def test_euclidean_matches_oracle(seed):
    rng = np.random.default_rng(50 + seed)
    n = int(rng.integers(10, 400))
    h = int(rng.integers(1, n))
    z = rng.normal(0, 1, n).astype(np.float32)
    y = np.array(P.capped_simplex_euclidean(jnp.array(z), h))
    yo = ref.project_capped_simplex_bisect(z.astype(np.float64), h, "euclidean")
    assert abs(y.sum() - h) < 1e-2
    np.testing.assert_allclose(y, yo, atol=2e-3)


@pytest.mark.parametrize("seed", range(10))
def test_topk_variant_equals_full_sort(seed):
    rng = np.random.default_rng(99 + seed)
    n = int(rng.integers(50, 500))
    h = int(rng.integers(1, n // 2))
    z = (rng.random(n) ** 2).astype(np.float32) + 1e-8
    full = np.array(P.capped_simplex_negentropy(jnp.array(z), h))
    fast = np.array(
        P.capped_simplex_negentropy_topk(jnp.array(z), h, min(n, h + 16))
    )
    np.testing.assert_allclose(fast, full, atol=1e-3)


def test_identity_when_feasible():
    """Projecting a point already in B_h returns it (Bregman projection
    optimality: D(y, z) = 0 iff y = z)."""
    rng = np.random.default_rng(5)
    n, h = 64, 8
    z = rng.random(n).astype(np.float32)
    z = np.minimum(z / z.sum() * h, 1.0)
    z += (h - z.sum()) * (1 - z) / (1 - z).sum()
    z = np.clip(z, 1e-6, 1.0)
    y = np.array(P.capped_simplex_negentropy(jnp.array(z), h))
    np.testing.assert_allclose(y, z, atol=5e-3)
