"""Arrival processes for the online serving engine (DESIGN.md §12).

Every throughput number before this module came from offline replay at a
fixed batch size; real serving sees requests *arrive* — and the latency a
user experiences is queueing + batching + service, not just service.
This module generates the arrival side of that story on the same virtual
millisecond clock the resilient tier introduced (repro.serve.remote):
nothing sleeps, every timestamp is derived from a seeded schedule, and a
run is replayable bit-for-bit.

Three registered kinds (`ARRIVAL_KINDS`):

* ``poisson`` — the open-loop memoryless process of the similarity-
  caching performance models: i.i.d. exponential inter-arrivals at
  `rate_rps` requests/second.
* ``flash_crowd`` — an open-loop *modulated* Poisson process: a periodic
  burst train multiplies the instantaneous rate by `burst_factor` for
  `burst_width_ms` out of every `burst_every_ms` (the arrival-side twin
  of the flash_crowd trace scenario's popularity shocks).  The base rate
  is normalised so the *mean* offered load equals `rate_rps`, keeping
  load sweeps comparable across kinds.
* ``closed_loop`` — `users` concurrent clients, each submitting one
  request, waiting for its completion plus an exponential think time
  (`think_ms` mean), then submitting the next.  Arrival times emerge
  from completions, so this kind is driven by the engine through the
  `ArrivalSource` protocol rather than precomputed.

Determinism: open-loop schedules are a pure function of (spec, t) —
`arrival_times` draws all inter-arrival randomness in one pass keyed by
`SeedSequence((seed, tag))`, so the times are identical across machines
and (trivially) independent of the engine's queue-drain order.  The
closed loop keys every think draw by `SeedSequence((seed, user, n))`, so
per-user schedules are order-independent too.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

ARRIVAL_KINDS = ("poisson", "flash_crowd", "closed_loop")

#: dissociates the arrival stream from other consumers of the same seed
_ARRIVAL_TAG = 0xA221


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Serializable arrival-process selection (the workload-timing twin of
    TraceSpec): kind + rate/burst/think knobs.

    * `rate_rps` — mean offered load of the open-loop kinds (requests per
      *second*; the virtual clock runs in ms).
    * `burst_*` — flash_crowd modulation: every `burst_every_ms` the rate
      multiplies by `burst_factor` for `burst_width_ms`.
    * `users` / `think_ms` — closed-loop population and mean think time.
    * `seed` — the one knob that changes the draw (same seed = same
      schedule, bit for bit).
    """

    kind: str = "poisson"
    rate_rps: float = 1000.0
    burst_factor: float = 8.0
    burst_every_ms: float = 250.0
    burst_width_ms: float = 50.0
    users: int = 8
    think_ms: float = 10.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(
                f"unknown arrival kind {self.kind!r}; registered: "
                f"{', '.join(ARRIVAL_KINDS)}")
        if self.kind != "closed_loop" and self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0: {self.rate_rps}")
        if self.kind == "flash_crowd":
            if self.burst_factor < 1.0:
                raise ValueError(
                    f"burst_factor must be >= 1: {self.burst_factor}")
            if not 0 < self.burst_width_ms <= self.burst_every_ms:
                raise ValueError(
                    f"need 0 < burst_width_ms <= burst_every_ms: "
                    f"({self.burst_width_ms}, {self.burst_every_ms})")
        if self.kind == "closed_loop":
            if self.users < 1:
                raise ValueError(f"users must be >= 1: {self.users}")
            if self.think_ms < 0:
                raise ValueError(f"think_ms must be >= 0: {self.think_ms}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ArrivalSpec":
        return cls(**dict(d))


def _unit_exponentials(spec: ArrivalSpec, t: int) -> np.ndarray:
    """The one randomness draw of an open-loop schedule: t unit-mean
    exponential inter-arrival targets, keyed by the spec seed alone."""
    rng = np.random.default_rng(
        np.random.SeedSequence((spec.seed, _ARRIVAL_TAG)))
    return rng.exponential(1.0, size=t)


def _flash_crowd_base_rate_ms(spec: ArrivalSpec) -> float:
    """Base (off-burst) rate in requests/ms such that the *mean* rate of
    the modulated process equals spec.rate_rps."""
    duty = spec.burst_width_ms / spec.burst_every_ms
    mean_factor = 1.0 + (spec.burst_factor - 1.0) * duty
    return (spec.rate_rps / 1e3) / mean_factor


def arrival_times(spec: ArrivalSpec, t: int) -> np.ndarray:
    """Open-loop arrival schedule: (t,) nondecreasing virtual-ms floats.

    Poisson inverts the constant cumulative rate directly; flash_crowd
    inverts the piecewise-constant cumulative rate segment by segment
    (time-rescaling theorem: arrivals of an inhomogeneous Poisson process
    are unit-exponential gaps in integrated-rate space).  Raises for
    closed_loop — its times emerge from completions (`ClosedLoopSource`).
    """
    if spec.kind == "closed_loop":
        raise ValueError(
            "closed_loop arrival times depend on completions; drive the "
            "engine with ClosedLoopSource(spec, t) instead")
    gaps = _unit_exponentials(spec, t)
    if spec.kind == "poisson":
        return np.cumsum(gaps) / (spec.rate_rps / 1e3)
    # flash_crowd: walk the piecewise-constant rate r(tau)
    base = _flash_crowd_base_rate_ms(spec)
    burst = base * spec.burst_factor
    every, width = spec.burst_every_ms, spec.burst_width_ms
    out = np.empty(t, np.float64)
    tau = 0.0   # current virtual time (ms)
    acc = 0.0   # integrated rate up to tau (expected arrival count)
    targets = np.cumsum(gaps)
    for i, s in enumerate(targets):
        while True:
            phase = tau % every
            in_burst = phase < width
            r = burst if in_burst else base
            seg_end = tau - phase + (width if in_burst else every)
            cap = acc + r * (seg_end - tau)
            if s <= cap:
                tau += (s - acc) / r
                acc = s
                break
            tau, acc = seg_end, cap
        out[i] = tau
    return out


class OpenLoopSource:
    """ArrivalSource over a precomputed time schedule (poisson /
    flash_crowd, or an explicit times array from a test).  Request ids
    are trace positions, assigned in schedule order."""

    def __init__(self, times: np.ndarray):
        self._times = np.asarray(times, np.float64)
        if len(self._times) and (np.diff(self._times) < 0).any():
            raise ValueError("arrival times must be nondecreasing")
        self._i = 0

    def peek(self) -> Optional[float]:
        """Time of the next scheduled arrival (None = exhausted)."""
        if self._i >= len(self._times):
            return None
        return float(self._times[self._i])

    def pop(self) -> Tuple[float, int]:
        """Consume the next arrival: (time_ms, request id)."""
        i = self._i
        self._i += 1
        return float(self._times[i]), i

    def on_complete(self, rid: int, done_ms: float) -> None:
        """Open loops ignore completions (arrivals are exogenous)."""


class ClosedLoopSource:
    """ArrivalSource for the closed loop: `users` clients, each cycling
    submit -> wait for completion -> think -> submit, up to `t` total
    requests.  The engine reports completions through `on_complete`
    (which schedules that user's next arrival), so offered load adapts
    to service capacity — the self-limiting regime open loops can't
    model.  Think draws are keyed per (seed, user, cycle), so a user's
    schedule never depends on other users' drain order."""

    def __init__(self, spec: ArrivalSpec, t: int):
        if spec.kind != "closed_loop":
            raise ValueError(f"ClosedLoopSource needs kind='closed_loop', "
                             f"got {spec.kind!r}")
        self.spec = spec
        self.budget = int(t)
        self._heap: List[Tuple[float, int]] = []  # (time, user)
        self._user_of: Dict[int, int] = {}        # rid -> user
        self._cycle = [0] * spec.users            # per-user think counter
        self._next_rid = 0
        for u in range(min(spec.users, self.budget)):
            # staggered cold start: each user begins after one think time
            heapq.heappush(self._heap, (self._think(u), u))

    def _think(self, u: int) -> float:
        n = self._cycle[u]
        self._cycle[u] += 1
        if self.spec.think_ms == 0:
            return 0.0
        rng = np.random.default_rng(
            np.random.SeedSequence((self.spec.seed, _ARRIVAL_TAG, u, n)))
        return float(rng.exponential(self.spec.think_ms))

    def peek(self) -> Optional[float]:
        if not self._heap or self._next_rid >= self.budget:
            return None
        return self._heap[0][0]

    def pop(self) -> Tuple[float, int]:
        time_ms, u = heapq.heappop(self._heap)
        rid = self._next_rid
        self._next_rid += 1
        self._user_of[rid] = u
        return time_ms, rid

    def on_complete(self, rid: int, done_ms: float) -> None:
        """Schedule the user's next request one think time after this
        one resolved — served *or* shed (a shed user retries), which
        keeps the closed population constant.  Guarded against double
        completion of the same rid."""
        if self._next_rid >= self.budget:
            return
        u = self._user_of.pop(rid, None)
        if u is None:
            return
        heapq.heappush(self._heap, (done_ms + self._think(u), u))


def make_source(spec_or_times, t: int):
    """Normalise any arrival description to an ArrivalSource: an
    `ArrivalSpec` (open kinds precompute `arrival_times`; closed_loop
    builds a `ClosedLoopSource`), a raw times array, or a ready source
    (anything with peek/pop/on_complete) passed through."""
    if isinstance(spec_or_times, ArrivalSpec):
        if spec_or_times.kind == "closed_loop":
            return ClosedLoopSource(spec_or_times, t)
        return OpenLoopSource(arrival_times(spec_or_times, t))
    if hasattr(spec_or_times, "peek") and hasattr(spec_or_times, "pop"):
        return spec_or_times
    return OpenLoopSource(np.asarray(spec_or_times, np.float64)[:t])
