"""Training substrate: optimizers (AdamW/Adafactor from scratch), train
step with grad-accum + remat, synthetic data pipeline, checkpointing with
elastic restore, fault-tolerant loop + straggler monitor."""

from repro.train.optimizer import OptConfig, apply_opt, init_opt
from repro.train.train_step import (TrainMetrics, init_train_state, loss_fn,
                                    make_train_step)

__all__ = ["OptConfig", "TrainMetrics", "apply_opt", "init_opt",
           "init_train_state", "loss_fn", "make_train_step"]
