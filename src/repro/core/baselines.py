"""State-of-the-art similarity-caching baselines (paper Sec. II and Sec. V).

All baselines maintain an ordered list of key->value pairs (key = a past
request embedding, value = its k' closest catalog objects) and update it
LRU-style; the cache size is h objects, i.e. h // k' entries (the paper's
inefficiency (i): overlapping value sets still consume separate slots).

  LRU      — exact-match only (k' = k): hit iff the request equals a key.
  SIM-LRU  — l = 1: hit iff the closest key is within C_theta.
  CLS-LRU  — SIM-LRU + hypersphere-center updates (medoid of served history).
  RND-LRU  — SIM-LRU with randomised miss: P(miss | d) increasing in d.
  QCACHE   — k' = k, l > 1: merge the l closest entries' values; hit iff
             >= 2 selected objects are *guaranteed* true neighbours (ball
             containment argument of Falchi et al.) or the distance profile
             matches the stored entries' profiles.

The *update* logic is deliberately sequential plain python (these are
order-dependent data-structure policies); the *distance math* is batched:

* `ServerOracle` precomputes exact kNN answers for a whole trace through
  the fused scan `repro.kernels.ops.topk_l2_chunked` (the same
  memory-roofline path the distributed AÇAI step runs), float32 end to
  end, with the catalog chunk sized to a memory budget so 1M-point
  catalogs never materialise a dense (B, N) intermediate.
* `KeyValueCache.step_batch` serves a request mini-batch with ONE
  (B, M) distance GEMM (M = every object the batch can possibly touch)
  plus one (B, E) key GEMM, then runs the sequential hit/update loop
  against those tables — the per-step python distance loops disappear
  while the update semantics stay exactly LRU.

`augmented=True` gives every policy AÇAI's serving rule (Fig. 7/11-13 of the
paper): the answer is composed per-object from the union of cached objects
(cost c_d) and the server's kNN (cost c_d + c_f), while the cache-update
logic stays untouched.  This isolates how much of AÇAI's edge comes from
the indexes vs from the OMA updates.

Geometric tests (QCACHE) run in *Euclidean* distance (triangle inequality);
costs are whatever the CostModel says (squared Euclidean by default), exactly
as in the paper's experiments.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Optional

import numpy as np


# --------------------------------------------------------------------------
# Server oracle: precomputed exact kNN answers for every trace request.
# --------------------------------------------------------------------------

class ServerOracle:
    """Exact kNN answers from the remote server.

    Trace mode (`requests` given): every answer is precomputed in one pass
    through the fused chunked scan and `knn(t, k)` is a table lookup.

    Online mode (`requests=None`, the serving tier): `extend(rs)` computes
    answers for newly arriving requests on demand — one fused (B, N) scan
    per mini-batch — appends them to the table and returns their trace
    positions, so the policies' `knn(t, k)` contract is identical in both
    modes.  With `retain_all=False` only the latest extend's block is
    kept (policies never re-read past positions), so an unbounded
    serving stream does not grow the table: `knn(t, k)` then only
    accepts positions from the most recent block.

    `mem_budget_mb` bounds the scan intermediates: the catalog is streamed
    in row chunks of `chunk` (derived so query_block × chunk stays inside
    the budget), float32 throughout — a 1M×128 catalog scans in ~64 MB
    blocks instead of a dense (B, N) float matrix.

    Mutable catalog (DESIGN.md §10): `add_objects(embs)` appends rows (the
    server learned new content) and `remove_objects(ids)` tombstones them
    via a validity mask the fused scan honors — a removed object can never
    appear in a kNN answer again.  Either mutation invalidates the retained
    answer table (precomputed answers are stale against the new catalog):
    stale `knn(t)` reads raise KeyError, and callers re-answer through
    `extend` / the online ts=None path.
    """

    _QUERY_BLOCK = 512

    def __init__(self, catalog: np.ndarray, requests: np.ndarray = None,
                 kmax: int = 128, chunk: Optional[int] = None,
                 mem_budget_mb: int = 64, retain_all: bool = True):
        self.catalog = np.ascontiguousarray(catalog, dtype=np.float32)
        n = self.catalog.shape[0]
        self.kmax = min(kmax, n)
        if chunk is None:
            budget_rows = (mem_budget_mb * 2 ** 20) // (self._QUERY_BLOCK * 4)
            chunk = int(np.clip(budget_rows, 256, max(n, 256)))
        self.chunk = chunk
        self.retain_all = retain_all
        self._cat_j = None  # device catalog, created on first scan
        self._valid_j = None
        self.valid = np.ones(n, bool)  # liveness mask (tombstones)
        self._mutated = False
        self.t = 0
        self._base = 0  # trace position of table row 0
        self.ids = np.empty((0, self.kmax), np.int32)
        self.d2 = np.empty((0, self.kmax), np.float32)  # squared euclidean
        # stale-read repair block (DESIGN.md §11): per-position recomputed
        # answers from `ensure`, each booked as a remote call; bare stale
        # reads without an `ensure` still raise (silent staleness is the
        # bug the PR-5 KeyError was added to catch).
        self._repaired: dict[int, tuple] = {}
        self.remote_recomputes = 0
        if requests is not None:
            self.extend(requests)

    # device-catalog row quantum: a growing catalog is padded (dead rows)
    # to multiples of this, so insertions re-jit the fused scan only when
    # they cross a block boundary instead of on every shape change
    _ROW_QUANTUM = 512

    def _scan(self, q: np.ndarray):
        """One fused top-kmax scan of the catalog: (B, d) float32 queries ->
        (ids (B, kmax) int32, d2 (B, kmax) float32 ascending)."""
        import jax.numpy as jnp

        from repro.kernels import ops

        if self._cat_j is None:
            n = self.catalog.shape[0]
            pad = ((-n) % self._ROW_QUANTUM) if self._mutated else 0
            cat = (np.pad(self.catalog, ((0, pad), (0, 0))) if pad
                   else self.catalog)
            self._cat_j = jnp.asarray(cat)
            self._valid_j = (jnp.asarray(np.pad(self.valid, (0, pad)))
                             if self._mutated else None)
        d2, ids = ops.topk_l2_chunked(jnp.asarray(q), self._cat_j, self.kmax,
                                      chunk=min(self.chunk,
                                                self._cat_j.shape[0]),
                                      valid=self._valid_j)
        return np.asarray(ids, np.int32), np.asarray(d2, np.float32)

    # -- online catalog mutation (DESIGN.md §10) ----------------------------

    def _invalidate_answers(self) -> None:
        """Precomputed answers are stale against a mutated catalog: drop
        the retained block so stale positions raise instead of silently
        serving removed/outdated kNN sets."""
        self._mutated = True
        self._cat_j = None
        self._base = self.t
        self.ids = np.empty((0, self.kmax), np.int32)
        self.d2 = np.empty((0, self.kmax), np.float32)
        self._repaired = {}  # repairs answer the *old* catalog: stale too

    def add_objects(self, embs: np.ndarray) -> np.ndarray:
        """Append new catalog rows; returns their (monotonic) ids."""
        embs = np.atleast_2d(np.asarray(embs, np.float32))
        ids = np.arange(self.catalog.shape[0],
                        self.catalog.shape[0] + embs.shape[0], dtype=np.int32)
        self.catalog = np.concatenate([self.catalog, embs])
        self.valid = np.concatenate([self.valid, np.ones(len(ids), bool)])
        self.kmax = min(max(self.kmax, 1), self.catalog.shape[0])
        self._invalidate_answers()
        return ids

    def remove_objects(self, ids) -> None:
        """Tombstone catalog rows: they vanish from every future answer."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if len(ids) == 0:
            return
        if ids.min() < 0 or ids.max() >= self.catalog.shape[0]:
            raise ValueError(
                f"remove_objects: ids outside [0, {self.catalog.shape[0]})")
        if not self.valid[ids].all():
            raise ValueError("remove_objects: some rows are already dead")
        self.valid[ids] = False
        self._invalidate_answers()

    def compact(self) -> np.ndarray:
        """Epoch compaction (DESIGN.md §14): drop tombstoned rows and
        renumber the survivors in ascending-id order, so the catalog stops
        growing with total-ever-seen.  Returns the (old_n,) int32 remap
        (new row id, or -1 for dead rows); the caller owns pushing it to
        every id holder (cache entries, payload stores).  Precomputed
        answers hold old ids and are invalidated wholesale."""
        live = np.nonzero(self.valid)[0]
        remap = np.full(self.catalog.shape[0], -1, np.int32)
        remap[live] = np.arange(live.size, dtype=np.int32)
        self.catalog = np.ascontiguousarray(self.catalog[live])
        self.valid = np.ones(live.size, bool)
        self.kmax = min(max(self.kmax, 1), max(self.catalog.shape[0], 1))
        self._invalidate_answers()
        return remap

    def extend(self, requests: np.ndarray) -> np.ndarray:
        """Answer kNN for `requests` (B, d), append to the table, and
        return their trace positions (B,)."""
        # cast BEFORE the gemm: float64 request streams must not promote
        # the (block, chunk) distance intermediates
        q = np.ascontiguousarray(requests, dtype=np.float32)
        b = q.shape[0]
        ids = np.empty((b, self.kmax), np.int32)
        d2 = np.empty((b, self.kmax), np.float32)
        for s in range(0, b, self._QUERY_BLOCK):
            ids[s:s + self._QUERY_BLOCK], d2[s:s + self._QUERY_BLOCK] = \
                self._scan(q[s:s + self._QUERY_BLOCK])
        ts = np.arange(self.t, self.t + b)
        if self.retain_all:
            self.ids = np.concatenate([self.ids, ids]) if self.t else ids
            self.d2 = np.concatenate([self.d2, d2]) if self.t else d2
        else:  # keep only this block: O(B) memory on unbounded streams
            self._base = self.t
            self.ids, self.d2 = ids, d2
        self.t += b
        return ts

    def _row(self, t: int) -> int:
        row = t - self._base
        if row < 0 or row >= self.ids.shape[0]:
            raise KeyError(
                f"trace position {t} is outside the retained answer block "
                f"[{self._base}, {self.t}) — precompute it (constructor "
                f"requests= / extend) or pass ts=None for online mode")
        return row

    def ensure(self, ts: np.ndarray, rs: np.ndarray) -> int:
        """Repair stale answer-table reads (DESIGN.md §11): recompute the
        answers for any of `ts` outside the retained block from the
        request embeddings `rs` (aligned with `ts`) and hold them in a
        per-batch repair block that `knn`/`knn_block`/`empty_cost`
        consult first.  Each recomputed position is booked as a remote
        call in `remote_recomputes` — this IS a server fetch, just an
        explicit one — so churned baselines compose with the fault model
        instead of crashing on the PR-5 KeyError.  Returns the number of
        positions recomputed (0 = everything was already retained)."""
        ts = np.atleast_1d(np.asarray(ts, np.int64))
        rs = np.atleast_2d(np.ascontiguousarray(rs, np.float32))
        rows = ts - self._base
        need = np.nonzero((rows < 0) | (rows >= self.ids.shape[0]))[0]
        if need.size == 0:
            return 0
        # repairs are per-batch: policies never re-read past positions,
        # so the block is reset instead of growing without bound
        self._repaired = {}
        for s in range(0, need.size, self._QUERY_BLOCK):
            blk = need[s:s + self._QUERY_BLOCK]
            ids, d2 = self._scan(rs[blk])
            for j, pos in enumerate(blk):
                self._repaired[int(ts[pos])] = (ids[j], d2[j])
        self.remote_recomputes += int(need.size)
        return int(need.size)

    def knn(self, t: int, k: int):
        rep = self._repaired.get(int(t))
        if rep is not None:
            return rep[0][:k], rep[1][:k]
        row = self._row(t)
        return self.ids[row, :k], self.d2[row, :k]

    def knn_block(self, ts: np.ndarray, k: int) -> np.ndarray:
        """Answer ids for a whole batch of trace positions: (B, k)."""
        ts = np.asarray(ts)
        rows = ts - self._base
        retained = (rows >= 0) & (rows < self.ids.shape[0])
        if retained.all():
            return self.ids[rows, :k]
        # stale positions resolve through the repair block (or raise,
        # via knn -> _row, when no ensure() repaired them)
        out = np.empty((len(ts), k), np.int32)
        for j, t in enumerate(ts):
            out[j] = self.knn(int(t), k)[0]
        return out

    def empty_cost(self, t: int, k: int, c_f: float, metric: str = "sqeuclidean"):
        rep = self._repaired.get(int(t))
        if rep is not None:
            d2 = rep[1][:k]
        else:
            d2 = self.d2[self._row(t), :k]
        d = d2 if metric == "sqeuclidean" else np.sqrt(d2)
        return float(d.sum() + k * c_f)


def _dist2(q: np.ndarray, pts: np.ndarray) -> np.ndarray:
    diff = pts - q[None, :]
    return np.maximum((diff * diff).sum(1), 0.0)


def _dist2_cross(qs: np.ndarray, pts: np.ndarray) -> np.ndarray:
    """(B, d) x (M, d) -> (B, M) squared distances, one float32 GEMM."""
    qs = qs.astype(np.float32, copy=False)
    pts = pts.astype(np.float32, copy=False)
    qn = (qs * qs).sum(1)[:, None]
    pn = (pts * pts).sum(1)[None, :]
    return np.maximum(qn - 2.0 * qs @ pts.T + pn, 0.0)


@dataclasses.dataclass
class StepResult:
    cost: float
    gain: float
    hit: bool
    served_local: int
    fetched: int  # objects fetched into the cache this step


class _Entry:
    __slots__ = ("key_emb", "value_ids", "value_d2_key", "history", "key_tag")

    def __init__(self, key_emb, value_ids, value_d2_key, key_tag=None):
        self.key_emb = key_emb
        self.value_ids = value_ids            # (k',) catalog ids
        self.value_d2_key = value_d2_key      # (k',) squared dist to key
        self.history: deque = deque(maxlen=16)
        # provenance of key_emb for the batched distance tables:
        # ("req", j) = request j of the active mini-batch,
        # ("cat", i) = catalog object i (CLS-LRU medoid), None = older.
        self.key_tag = key_tag


class _BatchCtx:
    """Per-mini-batch distance tables (see KeyValueCache.step_batch)."""

    __slots__ = ("b", "key_tab", "eid_col", "req_gram", "cat_tab", "cat_ids")

    def __init__(self, b, key_tab, eid_col, req_gram, cat_tab, cat_ids):
        self.b = b                  # current request position in the batch
        self.key_tab = key_tab      # (B, E0) d2 to batch-start entry keys
        self.eid_col = eid_col      # eid -> column of key_tab
        self.req_gram = req_gram    # (B, B) d2 between batch requests
        self.cat_tab = cat_tab      # (B, M) d2 to the candidate object set
        self.cat_ids = cat_ids      # (M,) sorted catalog ids of cat_tab

    def obj_d2(self, ids: np.ndarray):
        if not len(self.cat_ids):
            return None
        pos = np.searchsorted(self.cat_ids, ids)
        pos = np.minimum(pos, len(self.cat_ids) - 1)
        hit = self.cat_ids[pos] == ids
        if not hit.all():  # safety net; the candidate set should cover ids
            return None
        return self.cat_tab[self.b, pos]


class KeyValueCache:
    """Shared machinery for the LRU-family policies."""

    name = "base"

    def __init__(self, catalog: np.ndarray, oracle: ServerOracle, *, h: int,
                 k: int, c_f: float, k_prime: Optional[int] = None,
                 c_theta: Optional[float] = None, metric: str = "sqeuclidean",
                 augmented: bool = False, seed: int = 0):
        self.catalog = catalog
        self.oracle = oracle
        self.h, self.k = h, k
        self.k_prime = k_prime or k
        self.max_entries = max(h // self.k_prime, 1)
        self.c_f = c_f
        self.c_theta = c_theta if c_theta is not None else 1.5 * c_f
        self.metric = metric
        self.augmented = augmented
        self.rng = np.random.default_rng(seed)
        self.entries: "OrderedDict[int, _Entry]" = OrderedDict()  # MRU first
        self._next_id = 0
        self._ctx: Optional[_BatchCtx] = None

    # -- cost helpers -------------------------------------------------------

    def _cost(self, d2: np.ndarray) -> np.ndarray:
        return d2 if self.metric == "sqeuclidean" else np.sqrt(d2)

    def cached_object_ids(self) -> np.ndarray:
        if not self.entries:
            return np.empty((0,), np.int32)
        return np.unique(np.concatenate([e.value_ids for e in self.entries.values()]))

    def drop_objects(self, ids) -> int:
        """Invalidate cached entries referencing removed catalog objects
        (mutable catalog, DESIGN.md §10): an entry whose value set lost a
        member no longer answers its key correctly, so the whole entry is
        evicted — the LRU logic refetches on the next miss.  Returns the
        number of entries dropped."""
        dead = set(int(i) for i in np.atleast_1d(np.asarray(ids)))
        doomed = [eid for eid, e in self.entries.items()
                  if dead.intersection(int(v) for v in e.value_ids)]
        for eid in doomed:
            del self.entries[eid]
        return len(doomed)

    def remap_objects(self, remap: np.ndarray) -> None:
        """Rewrite every entry's value ids through a compaction remap
        (DESIGN.md §14).  Entries only hold live objects (drop_objects
        evicts on removal), so all ids must land on a new row; a -1 here
        means the caller compacted without draining removals first."""
        for e in self.entries.values():
            new_ids = remap[e.value_ids]
            if (new_ids < 0).any():
                raise ValueError(
                    "remap_objects: cached entry references a dead row — "
                    "drop_objects must run before compaction")
            e.value_ids = new_ids.astype(e.value_ids.dtype)

    # -- batched distance tables -------------------------------------------

    def _obj_d2(self, r_emb: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Squared distances request -> catalog[ids]; table lookup when a
        mini-batch context is active, direct numpy otherwise."""
        if self._ctx is not None:
            d2 = self._ctx.obj_d2(np.asarray(ids))
            if d2 is not None:
                return d2
        return _dist2(r_emb, self.catalog[ids])

    def _key_d2(self, r_emb: np.ndarray) -> np.ndarray:
        """Squared distances request -> every entry key (entry order)."""
        ctx = self._ctx
        if ctx is None:
            keys = np.stack([e.key_emb for e in self.entries.values()])
            return _dist2(r_emb, keys)
        out = np.empty(len(self.entries), np.float32)
        missing = []
        for j, (eid, e) in enumerate(self.entries.items()):
            col = ctx.eid_col.get(eid)
            if col is not None and e.key_tag is None:
                out[j] = ctx.key_tab[ctx.b, col]
            elif e.key_tag is not None and e.key_tag[0] == "req":
                out[j] = ctx.req_gram[ctx.b, e.key_tag[1]]
            elif e.key_tag is not None and e.key_tag[0] == "cat":
                d2 = ctx.obj_d2(np.asarray([e.key_tag[1]]))
                if d2 is None:
                    missing.append(j)
                else:
                    out[j] = d2[0]
            else:
                missing.append(j)
        if missing:  # safety net — keys the tables do not cover
            vals = list(self.entries.values())
            for j in missing:
                out[j] = _dist2(r_emb, vals[j].key_emb[None, :])[0]
        return out

    def step_batch(self, ts: np.ndarray, rs: np.ndarray) -> list:
        """Serve a request mini-batch: the distance math runs as two
        float32 GEMMs over everything the batch can touch, then the
        sequential hit/update loop consumes the tables.  Returns the
        per-request StepResult list (same semantics as calling `step` in
        a loop, with GEMM- instead of per-row-accumulated distances)."""
        rs = np.ascontiguousarray(rs, dtype=np.float32)
        b = rs.shape[0]
        # hit tests: distances to the keys existing at batch start + the
        # request gram (keys inserted during the batch are batch requests)
        eid_col = {eid: j for j, eid in enumerate(self.entries)}
        if eid_col:
            keys = np.stack([e.key_emb for e in self.entries.values()])
            key_tab = _dist2_cross(rs, keys)
        else:
            key_tab = np.empty((b, 0), np.float32)
        req_gram = _dist2_cross(rs, rs)
        # serving costs: every object the batch can cache or serve = the
        # batch-start cache content + each request's k' server answers;
        # positions the (possibly churned) oracle no longer retains are
        # repaired first — each a booked remote recompute (DESIGN.md §11)
        self.oracle.ensure(np.asarray(ts), rs)
        cached = self.cached_object_ids()
        srv = self.oracle.knn_block(ts, max(self.k, self.k_prime))
        cat_ids = np.unique(np.concatenate([cached.ravel(), srv.ravel()]))
        cat_tab = _dist2_cross(rs, self.catalog[cat_ids])
        # entries created before this batch resolve via eid_col
        for e in self.entries.values():
            if e.key_tag is not None and e.key_tag[0] == "req":
                e.key_tag = None
        ctx = _BatchCtx(0, key_tab, eid_col, req_gram, cat_tab, cat_ids)
        self._ctx = ctx
        try:
            out = []
            for j, (t, r) in enumerate(zip(np.asarray(ts), rs)):
                ctx.b = j
                out.append(self.step(int(t), r))
        finally:
            self._ctx = None
        return out

    # -- LRU bookkeeping ----------------------------------------------------

    def _touch(self, eid: int):
        self.entries.move_to_end(eid, last=False)

    def _insert(self, r_emb: np.ndarray, ids: np.ndarray, d2: np.ndarray) -> int:
        eid = self._next_id
        self._next_id += 1
        tag = None
        if self._ctx is not None:
            tag = ("req", self._ctx.b)
        self.entries[eid] = _Entry(r_emb.copy(), ids.copy(), d2.copy(),
                                   key_tag=tag)
        self.entries.move_to_end(eid, last=False)
        evicted = 0
        while len(self.entries) > self.max_entries:
            self.entries.popitem(last=True)
            evicted += 1
        return evicted

    # -- serving ------------------------------------------------------------

    def _serve_from_ids(self, t: int, r_emb: np.ndarray, local_ids: np.ndarray
                        ) -> StepResult:
        """AÇAI-style per-object composition over local_ids + server kNN."""
        srv_ids, srv_d2 = self.oracle.knn(t, self.k)
        if local_ids.size:
            loc_d2 = self._obj_d2(r_emb, local_ids)
            costs = np.concatenate([self._cost(loc_d2), self._cost(srv_d2) + self.c_f])
            obj = np.concatenate([local_ids, srv_ids])
            is_local = np.concatenate([np.ones(local_ids.size, bool),
                                       np.zeros(self.k, bool)])
        else:
            costs = self._cost(srv_d2) + self.c_f
            obj = srv_ids
            is_local = np.zeros(self.k, bool)
        # dedup: a cached object also in the server answer keeps the cheap copy
        order = np.argsort(costs, kind="stable")
        seen, pick = set(), []
        for p in order:
            if int(obj[p]) in seen:
                continue
            seen.add(int(obj[p]))
            pick.append(p)
            if len(pick) == self.k:
                break
        pick = np.array(pick)
        cost = float(costs[pick].sum())
        served_local = int(is_local[pick].sum())
        gain = self.oracle.empty_cost(t, self.k, self.c_f, self.metric) - cost
        return StepResult(cost, gain, served_local > 0, served_local, 0)

    def _answer_cost_local(self, t: int, r_emb: np.ndarray, ids: np.ndarray
                           ) -> StepResult:
        """Serve k objects entirely from `ids` (approximate hit)."""
        d2 = self._obj_d2(r_emb, ids)
        order = np.argsort(d2, kind="stable")[: self.k]
        cost = float(self._cost(d2[order]).sum())
        gain = self.oracle.empty_cost(t, self.k, self.c_f, self.metric) - cost
        return StepResult(cost, gain, True, self.k, 0)

    def _answer_cost_miss(self, t: int) -> StepResult:
        cost = self.oracle.empty_cost(t, self.k, self.c_f, self.metric)
        return StepResult(cost, 0.0, False, 0, self.k_prime)

    def step_degraded(self, r_emb: np.ndarray, *, ceiling: float = 2.0):
        """Remote-failure serve (DESIGN.md §11): answer from cached
        objects only.  No oracle read and no entry insert/touch — the LRU
        state must not learn from a request whose fetch failed.  Cached
        objects within `ceiling x` the request's best healthy-serve cost
        (nearest catalog dissimilarity + c_f — the same scale-free
        ceiling as repro.serve.resilience.degraded_serve) are eligible;
        with none the request is shed.  The empty-cache reference cost is
        computed locally against the live catalog (distances are
        edge-local metadata — only payload fetches need the remote
        tier), so gains stay comparable with the healthy path's.

        Returns (StepResult, shed: bool)."""
        r_emb = np.asarray(r_emb, np.float32)
        valid = getattr(self.oracle, "valid", None)
        cat = (self.catalog if valid is None
               else self.catalog[valid[: len(self.catalog)]])
        d_all = self._cost(np.sort(_dist2(r_emb, cat))[: self.k])
        empty_slots = d_all + self.c_f  # j-th cheapest all-remote answer
        empty_cost = float(empty_slots.sum())
        ids = self.cached_object_ids()
        if ids.size == 0:
            return StepResult(empty_cost, 0.0, False, 0, 0), True
        d_loc = self._cost(np.sort(_dist2(r_emb, self.catalog[ids])))
        d_loc = d_loc[d_loc <= ceiling * (d_all[0] + self.c_f)][: self.k]
        if d_loc.size == 0:
            return StepResult(empty_cost, 0.0, False, 0, 0), True
        # per-slot pairing against the empty-cache answer, clamped at 0
        gain = float(np.maximum(empty_slots[: d_loc.size] - d_loc,
                                0.0).sum())
        return StepResult(float(d_loc.sum()), gain, True, int(d_loc.size),
                          0), False

    # -- per-policy hooks ---------------------------------------------------

    def _closest_entry(self, r_emb: np.ndarray):
        if not self.entries:
            return None, np.inf
        eids = list(self.entries.keys())
        d2 = self._key_d2(r_emb)
        j = int(np.argmin(d2))
        return eids[j], self._cost(np.array([d2[j]]))[0]

    def _is_hit(self, t: int, r_emb: np.ndarray):
        raise NotImplementedError

    def _on_hit(self, t, r_emb, eid):
        self._touch(eid)

    def step(self, t: int, r_emb: np.ndarray) -> StepResult:
        hit, eid = self._is_hit(t, r_emb)
        if hit:
            self._on_hit(t, r_emb, eid)
            entry = self.entries[eid]
            if self.augmented:
                res = self._serve_from_ids(t, r_emb, self.cached_object_ids())
            else:
                res = self._answer_cost_local(t, r_emb, entry.value_ids)
            return res
        ids, d2 = self.oracle.knn(t, self.k_prime)
        self._insert(r_emb, ids, d2)
        if self.augmented:
            res = self._serve_from_ids(t, r_emb, self.cached_object_ids())
            return StepResult(res.cost, res.gain, res.hit, res.served_local,
                              self.k_prime)
        return self._answer_cost_miss(t)


class LRU(KeyValueCache):
    """Naive exact-match similarity cache (paper Sec. V-B)."""

    name = "LRU"

    def __init__(self, *args, **kwargs):
        kwargs.setdefault("k_prime", kwargs.get("k", None))
        super().__init__(*args, **kwargs)
        self._key_lookup: dict[bytes, int] = {}

    def _is_hit(self, t, r_emb):
        eid = self._key_lookup.get(r_emb.tobytes())
        return (eid is not None and eid in self.entries), eid

    def _insert(self, r_emb, ids, d2):
        evicted = super()._insert(r_emb, ids, d2)
        eid = next(iter(self.entries))
        self._key_lookup[r_emb.tobytes()] = eid
        return evicted


class SimLRU(KeyValueCache):
    name = "SIM-LRU"

    def _is_hit(self, t, r_emb):
        eid, d = self._closest_entry(r_emb)
        return (eid is not None and d <= self.c_theta), eid


class RndLRU(SimLRU):
    """SIM-LRU with randomised hit decision: P(miss) grows with d."""

    name = "RND-LRU"

    def _is_hit(self, t, r_emb):
        eid, d = self._closest_entry(r_emb)
        if eid is None:
            return False, None
        p_miss = min(1.0, float(d) / max(self.c_theta, 1e-12))
        return (self.rng.random() >= p_miss), eid


class ClsLRU(SimLRU):
    """SIM-LRU + center updates: on a hit the entry's key moves to the medoid
    of its served-request history (pushes intersecting hyperspheres apart)."""

    name = "CLS-LRU"

    def _on_hit(self, t, r_emb, eid):
        super()._on_hit(t, r_emb, eid)
        e = self.entries[eid]
        e.history.append(r_emb.copy())
        if len(e.history) >= 2:
            hist = np.stack(e.history)
            cand = self.catalog[e.value_ids]
            # medoid: cached object minimising total distance to the history
            tot = ((cand[:, None, :] - hist[None, :, :]) ** 2).sum(-1).sum(1)
            j = int(np.argmin(tot))
            new_center = cand[j]
            e.key_emb = new_center.copy()
            e.key_tag = ("cat", int(e.value_ids[j]))
            e.value_d2_key = _dist2(new_center, cand)


class QCache(KeyValueCache):
    """QCACHE (Falchi et al. 2012): k' = k, search the l closest entries."""

    name = "QCACHE"

    def __init__(self, *args, l: Optional[int] = None, theta_guaranteed: int = 2,
                 profile_tol: float = 1.25, **kwargs):
        kwargs.setdefault("k_prime", kwargs.get("k"))
        super().__init__(*args, **kwargs)
        self.l = l  # None => all entries (paper: l = h/k)
        self.theta_guaranteed = theta_guaranteed
        self.profile_tol = profile_tol

    def _is_hit(self, t, r_emb):
        if not self.entries:
            return False, None
        eids = list(self.entries.keys())
        dk = np.sqrt(self._key_d2(r_emb))  # euclidean for geometry
        take = np.argsort(dk, kind="stable")
        if self.l is not None:
            take = take[: self.l]
        merged_ids, merged_guard = [], []
        for j in take:
            e = self.entries[eids[int(j)]]
            rho = float(np.sqrt(e.value_d2_key.max()))  # covering radius
            guard = rho - dk[int(j)]  # guarantee margin for this entry
            merged_ids.append(e.value_ids)
            merged_guard.append(np.full(e.value_ids.shape, guard))
        ids = np.concatenate(merged_ids)
        guard = np.concatenate(merged_guard)
        d_obj = np.sqrt(self._obj_d2(r_emb, ids))
        # keep best copy per object id
        order = np.argsort(d_obj, kind="stable")
        seen, pick = set(), []
        for p in order:
            if int(ids[p]) in seen:
                continue
            seen.add(int(ids[p]))
            pick.append(p)
            if len(pick) == self.k:
                break
        if len(pick) < self.k:
            return False, take[0] if len(take) else None
        pick = np.array(pick)
        guaranteed = int((d_obj[pick] <= guard[pick] + 1e-12).sum())
        if guaranteed >= self.theta_guaranteed:
            return True, eids[int(take[0])]
        # distance-profile test: mean distance of the selected k vs the mean
        # key->value distance profile of the stored entries
        prof = np.mean([np.sqrt(e.value_d2_key).mean()
                        for e in self.entries.values()])
        if d_obj[pick].mean() <= self.profile_tol * prof:
            return True, eids[int(take[0])]
        return False, eids[int(take[0])]

    def _on_hit(self, t, r_emb, eid):
        # touch every contributing entry (paper: pairs that contributed move
        # to the front); we touch the closest one — the dominant contributor.
        self._touch(eid)

    def step(self, t, r_emb):
        # QCACHE serves from the merged value sets, not a single entry.
        hit, eid = self._is_hit(t, r_emb)
        if hit:
            self._on_hit(t, r_emb, eid)
            ids = self.cached_object_ids()
            if self.augmented:
                return self._serve_from_ids(t, r_emb, ids)
            return self._answer_cost_local(t, r_emb, ids)
        ids, d2 = self.oracle.knn(t, self.k_prime)
        self._insert(r_emb, ids, d2)
        if self.augmented:
            res = self._serve_from_ids(t, r_emb, self.cached_object_ids())
            return StepResult(res.cost, res.gain, res.hit, res.served_local,
                              self.k_prime)
        return self._answer_cost_miss(t)


POLICIES = {p.name: p for p in (LRU, SimLRU, ClsLRU, RndLRU, QCache)}


def run_policy(policy: KeyValueCache, requests: np.ndarray):
    """Replay a trace; returns dict of per-step metric arrays."""
    t_total = requests.shape[0]
    gain = np.zeros(t_total)
    cost = np.zeros(t_total)
    hits = np.zeros(t_total, bool)
    fetched = np.zeros(t_total, np.int32)
    for t in range(t_total):
        res = policy.step(t, requests[t])
        gain[t], cost[t], hits[t], fetched[t] = res.gain, res.cost, res.hit, res.fetched
    return {"gain": gain, "cost": cost, "hit": hits, "fetched": fetched}


def nag(gains: np.ndarray, k: int, c_f: float) -> np.ndarray:
    """Normalised average gain curve, Eq. (11)."""
    return np.cumsum(gains) / (k * c_f * np.arange(1, gains.shape[0] + 1))
