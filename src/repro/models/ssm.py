"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer in pure JAX.

Chunked SSD algorithm (the "minimal" formulation of the paper):
  1. intra-chunk (quadratic within a Q-token chunk, matmul-friendly — this
     is what lands on the MXU),
  2. per-chunk final states,
  3. inter-chunk linear recurrence on the chunk states,
  4. state->output correction.

Train/prefill run the chunked scan; decode is the O(1)-per-token recurrent
update on (conv, ssm) caches — the reason mamba2/jamba serve the long_500k
shape while full-attention models cannot.

Head layout follows the reference: x (B,L,H,P), scalar A per head,
B/C shared across heads (ngroups=1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, rms_norm


def _segsum(x: jax.Array) -> jax.Array:
    """(..., q) -> (..., q, q): S[i, j] = sum_{k=j+1..i} x[k], -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{k=j+1..i} = cs_i - cs_j
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, b, c, chunk: int):
    """SSD scan.

    x: (B, L, H, P)   inputs (already multiplied by dt)
    a: (B, L, H)      per-step log-decay (dt * A, negative)
    b: (B, L, N)      input projection (ngroups=1, shared across heads)
    c: (B, L, N)      output projection
    returns y: (B, L, H, P), final_state: (B, H, P, N)
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xc = x.reshape(bs, nc, chunk, h, p)
    ac = a.reshape(bs, nc, chunk, h).transpose(0, 3, 1, 2)  # (B,H,C,Q)
    bc = b.reshape(bs, nc, chunk, n)
    cc = c.reshape(bs, nc, chunk, n)

    a_cumsum = jnp.cumsum(ac, axis=-1)                      # (B,H,C,Q)

    # 1. intra-chunk
    el = jnp.exp(_segsum(ac))                               # (B,H,C,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", cc, bc)          # (B,C,Q,Q)
    y_diag = jnp.einsum("bcqs,bhcqs,bcshp->bcqhp",
                        scores.astype(jnp.float32), el, xc.astype(jnp.float32))

    # 2. chunk final states
    decay_states = jnp.exp(a_cumsum[..., -1:] - a_cumsum)   # (B,H,C,Q)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn",
                        bc.astype(jnp.float32), decay_states,
                        xc.astype(jnp.float32))             # (B,C,H,P,N)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cumsum[..., -1])                # (B,H,C)

    def scan_fn(prev, inp):
        st, dec = inp                                       # (B,H,P,N), (B,H)
        new = prev * dec[..., None, None] + st
        return new, prev                                    # emit state BEFORE chunk

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,C,H,P,N)

    # 4. state -> output
    state_decay = jnp.exp(a_cumsum)                         # (B,H,C,Q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp",
                       cc.astype(jnp.float32), prev_states, state_decay)

    y = (y_diag + y_off).reshape(bs, l, h, p)
    return y.astype(x.dtype), final


def init_mamba(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.ssm_heads
    conv_ch = di + 2 * ns
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ns + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_ch)) * 0.1
                   ).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[3], di, d, dt),
    }


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv1d. xbc: (B, L, CH); w: (K, CH).

    conv_state: (B, K-1, CH) history for incremental mode (or None)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                # (B, L+K-1, CH)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return out + b[None, None, :], new_state


def mamba_mixer(p, x, cfg: ModelConfig, cache=None):
    """x: (B, L, d_model). cache: None or {'conv': (B,K-1,CH), 'ssm': (B,H,P,N)}.

    Returns (y, new_cache)."""
    bs, l, _ = x.shape
    di, ns, nh, hp = cfg.d_inner, cfg.d_state, cfg.ssm_heads, cfg.ssm_head_dim

    proj = x @ p["in_proj"]
    z, xin, b_, c_, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)

    xbc = jnp.concatenate([xin, b_, c_], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin, b_, c_ = jnp.split(xbc, [di, di + ns], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,L,H)
    a = -jnp.exp(p["a_log"])                                      # (H,)
    xh = xin.reshape(bs, l, nh, hp)

    if cache is None or l > 1:
        # chunked scan (train / prefill); pad L to a chunk multiple
        chunk = min(cfg.ssd_chunk, l) if l % cfg.ssd_chunk else cfg.ssd_chunk
        pad = (-l) % chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, b_p, c_p = xh, dt, b_, c_
        y, final = ssd_chunked(
            xh_p * dt_p[..., None].astype(xh.dtype),
            dt_p * a[None, None, :], b_p, c_p, chunk)
        y = y[:, :l]
        new_cache = (None if cache is None
                     else {"conv": new_conv, "ssm": final})
    else:
        # O(1) decode: state' = state*exp(dt a) + dt * (b ⊗ x); y = c·state'
        st = cache["ssm"]                                     # (B,H,P,N)
        dt1 = dt[:, 0]                                        # (B,H)
        decay = jnp.exp(dt1 * a[None, :])                     # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, b_[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        st = st * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c_[:, 0].astype(jnp.float32), st)
        y = y[:, None].astype(x.dtype)                        # (B,1,H,P)
        new_cache = {"conv": new_conv, "ssm": st}

    y = y + (p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(bs, l, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    conv_ch = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.d_state), jnp.float32),
    }
