"""Serving-engine throughput microbenchmark (CPU, smoke-size model):
continuous batching tokens/s and semantic-cache hit economics."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.configs import SMOKE_ARCHS
from repro.models import init_params
from repro.serve import SemanticCachedLM, ServeEngine, generate


def main(full: bool = False, kind: str = "sift") -> None:
    cfg = SMOKE_ARCHS["qwen1.5-0.5b"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req, budget = (64, 16) if full else (16, 6)

    engine = ServeEngine(params, cfg, batch=4, s_max=48)
    for i in range(n_req):
        engine.submit(i, jnp.asarray(rng.integers(0, cfg.vocab, 12),
                                     jnp.int32), budget)
    t0 = time.time()
    steps = 0
    while engine.step():
        steps += 1
    dt = time.time() - t0
    toks = sum(len(v) for v in engine.done.values())
    common.emit("serve/engine/tokens_per_s", dt / max(toks, 1) * 1e6,
                f"{toks / dt:.1f}")
    common.emit("serve/engine/steps", 0.0, str(steps))

    catalog = jnp.asarray(rng.normal(size=(400, cfg.d_model)), jnp.float32)
    catalog = catalog / jnp.linalg.norm(catalog, axis=1, keepdims=True)
    lm = SemanticCachedLM(
        params, cfg, catalog, [str(i) for i in range(400)],
        generate_fn=lambda p: generate(params, cfg, p[None], steps=2),
        h=40, k=4)
    pool = [jnp.asarray(rng.integers(0, cfg.vocab, 12), jnp.int32)
            for _ in range(20)]
    w = (np.arange(20) + 1.0) ** -1.1
    t0 = time.time()
    for _ in range(n_req * 2):
        lm.query(pool[rng.choice(20, p=w / w.sum())])
    dt = (time.time() - t0) / (n_req * 2)
    s = lm.stats
    common.emit("serve/semantic_cache/NAG", dt * 1e6, f"{lm.nag:.3f}")
    common.emit("serve/semantic_cache/local_share", 0.0,
                f"{s.served_local / (s.requests * 4):.2f}")


if __name__ == "__main__":
    args = common.std_args(__doc__).parse_args()
    main(args.full, args.trace)
