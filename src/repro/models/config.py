"""Unified model configuration covering the 10 assigned architectures.

One frozen dataclass drives layer construction, parameter init, sharding
specs, KV/SSM cache layout and the dry-run input specs.  Families:

  dense GQA (minitron, yi, qwen2-72b, qwen1.5)      layer_pattern='attn'
  encoder   (hubert)                                causal=False
  MoE       (mixtral: SWA+8e, deepseek: MLA+256e)   n_experts>0
  SSM       (mamba2)                                layer_pattern='ssm'
  hybrid    (jamba: 1 attn : 7 mamba + MoE)         layer_pattern='jamba'
  VLM       (qwen2-vl: M-RoPE, stub patch frontend) pos_emb='mrope'
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                    # 0 -> d_model // n_heads

    # attention
    attn_type: str = "gqa"             # 'gqa' | 'mla'
    qkv_bias: bool = False
    sliding_window: int = 0            # 0 = full attention
    causal: bool = True                # False = bidirectional encoder
    rope_theta: float = 1e4
    pos_emb: str = "rope"              # 'rope' | 'mrope' | 'none'
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # MoE
    ffn_act: str = "swiglu"            # 'swiglu' | 'relu2' (nemotron)

    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                  # routed-expert FFN width
    moe_layer_start: int = 0           # leading dense layers (deepseek: 3)
    moe_every: int = 1                 # MoE every n-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (DeepSeek-V3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # SSM (Mamba2 SSD)
    layer_pattern: str = "attn"        # 'attn' | 'ssm' | 'jamba'
    attn_every: int = 8                # jamba: one attn layer per group of 8
    d_state: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2
    ssd_chunk: int = 256

    # extras
    mtp_depth: int = 0                 # DeepSeek multi-token prediction heads
    tie_embeddings: bool = False
    modality: str = "text"             # 'text' | 'audio' | 'vision'
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # substrate
    optimizer: str = "adamw"           # 'adamw' | 'adafactor'
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False                 # shard params over the data axis too

    # perf knobs (§Perf hillclimbing; defaults = paper-faithful baseline)
    flash_threshold: int = 8192        # KV length that triggers flash path
    flash_chunk: int = 2048            # flash KV chunk size
    moe_dp: int = 0                    # >0: two-stage local MoE dispatch
                                       # over this many data shards
    use_pallas_attention: bool = False  # TPU: VMEM-resident flash kernel
                                        # (repro.kernels.flash_attention)
    mla_absorbed_decode: bool = False   # MLA: absorb W_uk/W_uv into q/out
                                        # and attend in latent space (the
                                        # DeepSeek serving optimization)
    replicate_misaligned_heads: bool = False  # data-only sharding for
                                        # attention mats whose head counts
                                        # don't divide the model axis

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.layer_pattern == "attn":
            return True
        if self.layer_pattern == "ssm":
            return False
        # jamba: one attention layer per group of `attn_every`
        return i % self.attn_every == self.attn_every // 2

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return i >= self.moe_layer_start and (i % self.moe_every == 0)

    @property
    def has_decode(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """Supports the long_500k shape: SSM/hybrid or sliding-window."""
        return self.layer_pattern in ("ssm", "jamba") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        for i in range(self.n_layers):
            total += 2 * d  # norms
            if self.is_attn_layer(i):
                if self.attn_type == "mla":
                    qr = self.q_lora_rank or d
                    total += d * qr + qr * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    total += self.n_heads * self.v_head_dim * d
                else:
                    total += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    total += self.n_heads * hd * d
                    if self.qkv_bias:
                        total += (self.n_heads + 2 * self.n_kv_heads) * hd
            else:  # mamba2 mixer
                di, ns, nh = self.d_inner, self.d_state, self.ssm_heads
                conv_ch = di + 2 * ns
                total += d * (2 * di + 2 * ns + nh)  # in_proj
                total += conv_ch * self.d_conv + conv_ch
                total += 2 * nh + di  # A, D(+dt_bias) per head, skip
                total += di * d  # out_proj
            n_mats = 3 if self.ffn_act == "swiglu" else 2
            if self.is_moe_layer(i):
                e_ff = self.moe_d_ff or self.d_ff
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * e_ff
                total += self.n_shared_experts * 3 * d * e_ff
            else:
                total += n_mats * d * self.d_ff
        if self.mtp_depth:  # shared-embedding MTP head: proj + one block
            total += 2 * d * d + 3 * d * self.d_ff + 3 * d
            if self.attn_type == "mla":
                qr = self.q_lora_rank or d
                total += d * qr + qr * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                total += d * (self.kv_lora_rank + self.qk_rope_dim)
                total += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                total += self.n_heads * self.v_head_dim * d
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed-to experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        e_ff = self.moe_d_ff or self.d_ff
        inactive_per_moe_layer = (
            (self.n_experts - self.experts_per_token) * 3 * d * e_ff
        )
        n_moe = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        return self.param_count() - n_moe * inactive_per_moe_layer
